//! Tiny criterion-style timing harness: N warmup runs, M measured runs,
//! mean/std/min/percentiles, and a one-line report format used by every
//! `cargo bench` target.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Result of timing one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case label, as printed in the report line.
    pub name: String,
    /// Measured iterations (excluding warmup).
    pub iters: usize,
    /// Timing statistics over the measured iterations, in seconds.
    pub summary: Summary,
}

impl BenchResult {
    /// `name  mean ± std  [min … max]  (n iters)` with adaptive units.
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:<10} [{} … {}]  ({} iters)",
            self.name,
            fmt_secs(self.summary.mean),
            fmt_secs(self.summary.std),
            fmt_secs(self.summary.min),
            fmt_secs(self.summary.max),
            self.iters
        )
    }

    /// Mean wall-clock seconds per iteration.
    pub fn mean_secs(&self) -> f64 {
        self.summary.mean
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "n/a".to_string();
    }
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs.
/// The closure's return value is black-boxed to keep the optimizer honest.
pub fn bench_fn<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: iters.max(1),
        summary: Summary::of(&samples),
    }
}

/// Minimal black_box (std::hint::black_box is stable — use it).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Time a single run of `f` (used when one run is already seconds long).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_positive_time() {
        let r = bench_fn("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(r.iters, 5);
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.min <= r.summary.mean);
        assert!(r.report_line().contains("spin"));
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5e-6).ends_with("µs"));
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
