//! Machine-readable performance trajectory (`hst bench`).
//!
//! The paper's evaluation is a set of one-off tables; this module makes
//! performance a *tracked artifact* instead: one `BENCH_<pr>.json` per
//! PR, with one record per (engine, fixture) pair, so any two points of
//! the repo's history can be diffed mechanically
//! (`hst bench --diff OLD.json NEW.json`).
//!
//! Schema (`hst-bench-trajectory/1`):
//!
//! ```json
//! {
//!   "schema": "hst-bench-trajectory/1",
//!   "meta": { "tier": "quick", "scale_div": 8, "runs": 2, "seed": 7,
//!             "threads": 0, "kernel": "simd", "provenance": "measured" },
//!   "records": [
//!     { "engine": "hst", "table": "ECG 0606", "n": 480, "s": 120,
//!       "calls": 1234, "cps": 3.4, "prep_calls": 720, "wall_ms": 1.9 }
//!   ]
//! }
//! ```
//!
//! Per record: `engine` ∈ [`ALL_ENGINES`], `table` names the registry
//! fixture, `n` is the materialized series length in points, `s` the
//! sequence length, `calls`/`prep_calls` the seed-averaged distance-call
//! accounting, `cps` the paper's cost per sequence, `wall_ms` the
//! seed-averaged wall clock. A record may additionally carry a
//! `latency` object — the per-run wall-clock histogram summary
//! (`count`/`sum`/`mean`/`p50`/`p90`/`p99`, the
//! [`HistogramSnapshot::summary_json`](crate::obs::HistogramSnapshot::summary_json)
//! shape the service `metrics` command also embeds); sweeps emit it,
//! older files without it stay valid. Fixtures are the Tables 1/3/6 registry
//! datasets materialized at a **bounded** length (the quadratic baselines
//! `brute`/`brute-md`/`scamp` must stay tractable in one sweep) — the
//! paper-scale runs stay the job of `hst table`. Fixture sizes are pinned
//! by (tier, `scale_div`), so records from two PRs at the same
//! configuration compare like with like; [`diff`] refuses mismatched `n`.

use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::algo::{self, Algorithm, SearchReport, ALL_ENGINES};
use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::dist::Kernel;
use crate::metrics::cps;
use crate::tables::BenchConfig;
use crate::ts::datasets::registry;
use crate::ts::TimeSeries;
use crate::util::json::Json;

/// Schema id stamped into (and required of) every trajectory file.
pub const TRAJECTORY_SCHEMA: &str = "hst-bench-trajectory/1";

/// Fixture subset + length cap of the `--quick` CI tier: the three
/// small-`s` registry datasets, a few hundred points each — the full
/// all-engine sweep finishes in CI-smoke time.
const QUICK_FIXTURES: [&str; 3] = ["ECG 0606", "NPRS 43", "Shuttle TEK 14"];
const QUICK_CAP: usize = 600;
/// Length cap of the standard tier (all registry fixtures).
const STANDARD_CAP: usize = 6_000;

/// One measured (engine, fixture) cell of the trajectory.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// Engine id (∈ [`ALL_ENGINES`]).
    pub engine: String,
    /// Fixture id (the registry dataset name).
    pub table: String,
    /// Materialized series length in points.
    pub n: usize,
    /// Sequence (discord) length.
    pub s: usize,
    /// Seed-averaged distance calls (the paper's cost metric).
    pub calls: u64,
    /// Cost per sequence: `calls / (num_sequences · k)`.
    pub cps: f64,
    /// Seed-averaged distance calls spent on preparation.
    pub prep_calls: u64,
    /// Seed-averaged wall clock in milliseconds.
    pub wall_ms: f64,
    /// Optional per-run wall-clock histogram summary
    /// (`count`/`sum`/`mean`/`p50`/`p90`/`p99`). `None` in files from
    /// before the field existed.
    pub latency: Option<Json>,
}

impl BenchRecord {
    /// Serialize one record (the eight required schema keys, plus
    /// `latency` when present).
    pub fn to_json(&self) -> Json {
        let j = Json::obj()
            .set("engine", self.engine.as_str())
            .set("table", self.table.as_str())
            .set("n", self.n)
            .set("s", self.s)
            .set("calls", self.calls)
            .set("cps", self.cps)
            .set("prep_calls", self.prep_calls)
            .set("wall_ms", self.wall_ms);
        match &self.latency {
            Some(l) => j.set("latency", l.clone()),
            None => j,
        }
    }

    /// Parse and validate one record (see [`validate`] for the rules).
    pub fn from_json(j: &Json) -> Result<BenchRecord> {
        let field = |k: &str| j.get(k).ok_or_else(|| anyhow!("record missing key {k:?}"));
        let engine = field("engine")?
            .as_str()
            .ok_or_else(|| anyhow!("engine must be a string"))?
            .to_string();
        ensure!(
            ALL_ENGINES.contains(&engine.as_str()),
            "unknown engine id {engine:?} (not in ALL_ENGINES)"
        );
        let table = field("table")?
            .as_str()
            .ok_or_else(|| anyhow!("table must be a string"))?
            .to_string();
        let u = |k: &str| -> Result<u64> {
            field(k)?
                .as_u64()
                .ok_or_else(|| anyhow!("{k} must be a non-negative integer"))
        };
        let f = |k: &str| -> Result<f64> {
            field(k)?
                .as_f64()
                .ok_or_else(|| anyhow!("{k} must be a number"))
        };
        let latency = match j.get("latency") {
            None => None,
            Some(l) => {
                for k in ["count", "sum", "mean", "p50", "p90", "p99"] {
                    ensure!(
                        l.get(k).and_then(|v| v.as_f64()).is_some(),
                        "latency summary missing numeric key {k:?}"
                    );
                }
                Some(l.clone())
            }
        };
        let rec = BenchRecord {
            engine,
            table,
            n: u("n")? as usize,
            s: u("s")? as usize,
            calls: u("calls")?,
            cps: f("cps")?,
            prep_calls: u("prep_calls")?,
            wall_ms: f("wall_ms")?,
            latency,
        };
        ensure!(rec.n > 0 && rec.s > 0, "n and s must be positive");
        ensure!(rec.cps > 0.0, "cps must be > 0 (got {})", rec.cps);
        ensure!(rec.calls > 0, "calls must be > 0");
        ensure!(rec.wall_ms >= 0.0, "wall_ms must be >= 0");
        Ok(rec)
    }
}

/// Run metadata stamped into the file so two trajectories are only
/// compared when they measured the same thing.
#[derive(Debug, Clone)]
pub struct TrajectoryMeta {
    /// `"quick"` / `"standard"` / `"full"`.
    pub tier: String,
    /// The [`BenchConfig`] the sweep ran with.
    pub scale_div: usize,
    /// Seeds averaged per cell.
    pub runs: usize,
    /// Base seed.
    pub seed: u64,
    /// Worker-thread setting (0 = auto).
    pub threads: usize,
    /// Inner-loop kernel name ([`Kernel::name`]).
    pub kernel: String,
    /// `"measured"` when emitted by `hst bench`; anything else marks a
    /// hand-authored file (e.g. an offline estimate awaiting rerun).
    pub provenance: String,
}

impl TrajectoryMeta {
    /// Meta for a sweep about to run.
    pub fn measured(cfg: &BenchConfig, tier: &str, kernel: Kernel) -> TrajectoryMeta {
        TrajectoryMeta {
            tier: tier.to_string(),
            scale_div: cfg.scale_div,
            runs: cfg.runs,
            seed: cfg.seed,
            threads: cfg.threads,
            kernel: kernel.name().to_string(),
            provenance: "measured".to_string(),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj()
            .set("tier", self.tier.as_str())
            .set("scale_div", self.scale_div)
            .set("runs", self.runs)
            .set("seed", self.seed)
            .set("threads", self.threads)
            .set("kernel", self.kernel.as_str())
            .set("provenance", self.provenance.as_str())
    }
}

/// Assemble the full trajectory document.
pub fn trajectory_json(meta: &TrajectoryMeta, records: &[BenchRecord]) -> Json {
    Json::obj()
        .set("schema", TRAJECTORY_SCHEMA)
        .set("meta", meta.to_json())
        .set(
            "records",
            records.iter().map(|r| r.to_json()).collect::<Vec<_>>(),
        )
}

/// Validate a trajectory document against the schema: the schema id, a
/// `records` array, and per record all eight keys present, the engine id
/// in [`ALL_ENGINES`], `cps > 0`, `calls > 0`. Returns the parsed records.
pub fn validate(doc: &Json) -> Result<Vec<BenchRecord>> {
    let schema = doc
        .get("schema")
        .and_then(|s| s.as_str())
        .ok_or_else(|| anyhow!("missing schema key"))?;
    ensure!(
        schema == TRAJECTORY_SCHEMA,
        "schema {schema:?}, expected {TRAJECTORY_SCHEMA:?}"
    );
    ensure!(doc.get("meta").is_some(), "missing meta object");
    let records = doc
        .get("records")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| anyhow!("missing records array"))?;
    ensure!(!records.is_empty(), "records array is empty");
    records
        .iter()
        .enumerate()
        .map(|(i, r)| BenchRecord::from_json(r).with_context(|| format!("record {i}")))
        .collect()
}

/// One fixture of the sweep.
struct Fixture {
    name: String,
    ts: TimeSeries,
    params: SearchParams,
}

/// Materialize the tier's fixtures: registry datasets at
/// `paper_len / scale_div`, clamped to `[4·s, cap]` (the floor keeps
/// every engine's `n >= 2` precondition; the cap keeps the quadratic
/// baselines tractable — `hst table --full` remains the paper-scale path).
fn fixtures(cfg: &BenchConfig, quick: bool) -> Vec<Fixture> {
    let cap = if quick { QUICK_CAP } else { STANDARD_CAP };
    registry()
        .into_iter()
        .filter(|d| !quick || QUICK_FIXTURES.contains(&d.name))
        .map(|d| {
            let floor = 4 * d.s;
            let n = (d.paper_len / cfg.scale_div.max(1)).clamp(floor, cap.max(floor));
            Fixture {
                name: d.name.to_string(),
                ts: d.generate_len(n),
                params: SearchParams::new(d.s, d.p, d.alphabet)
                    .with_discords(1)
                    .with_threads(cfg.threads),
            }
        })
        .collect()
}

/// One engine run on a cold, kernel-pinned context. `dadd` needs its
/// defining range `r` up front, so it is calibrated from an HST run on a
/// *separate* context (its calls are excluded from the record, exactly as
/// the paper excludes the exact-nnd precomputation from the Table 7
/// timings).
fn run_engine(
    engine: &str,
    ts: &TimeSeries,
    params: &SearchParams,
    kernel: Kernel,
) -> Result<SearchReport> {
    let ctx = SearchContext::builder(ts).kernel(kernel).build();
    if engine == "dadd" {
        let cal_ctx = SearchContext::builder(ts).kernel(kernel).build();
        let hst = algo::hst::HstSearch::default().run_ctx(&cal_ctx, params)?;
        let top = hst
            .discords
            .last()
            .ok_or_else(|| anyhow!("no discord to calibrate dadd's r from"))?;
        let dadd = algo::dadd::Dadd {
            // strict: keep the k-th discord >= r (Table 7's 0.99·exact arm)
            r: top.nnd * 0.99 * 0.999_999,
            page_size: 10_000,
        };
        return dadd.run_ctx(&ctx, params);
    }
    let eng = algo::by_name(engine).ok_or_else(|| anyhow!("unknown engine {engine:?}"))?;
    eng.run_ctx(&ctx, params)
}

/// Sweep `engines` over the tier's fixtures: every cell is `cfg.runs`
/// cold runs (fresh context each — no warm-profile carry-over between
/// engines) with distinct seeds, averaged. Pass [`ALL_ENGINES`] for the
/// full trajectory.
pub fn run_trajectory_filtered(
    cfg: &BenchConfig,
    quick: bool,
    kernel: Kernel,
    engines: &[&str],
) -> Result<Vec<BenchRecord>> {
    let mut records = Vec::new();
    for fx in fixtures(cfg, quick) {
        let n_seq = fx.ts.num_sequences(fx.params.sax.s);
        ensure!(
            n_seq >= 2,
            "fixture {} too short for s={}",
            fx.name,
            fx.params.sax.s
        );
        for &engine in engines {
            let runs = cfg.runs.max(1);
            let (mut calls, mut prep, mut ms) = (0u128, 0u128, 0.0f64);
            let mut k = 1usize;
            // per-cell latency histogram: one observation per run, so
            // the record carries quantiles alongside the mean wall_ms
            let obs = crate::obs::Registry::new();
            let hist =
                obs.histogram("bench_wall_ms", &crate::obs::LATENCY_BUCKETS_MS);
            for r in 0..runs {
                let p = fx
                    .params
                    .clone()
                    .with_seed(cfg.seed + r as u64 * 1_000_003);
                let t0 = Instant::now();
                let rep = run_engine(engine, &fx.ts, &p, kernel)
                    .with_context(|| format!("{engine} on {}", fx.name))?;
                let run_ms = t0.elapsed().as_secs_f64() * 1e3;
                ms += run_ms;
                hist.observe(run_ms);
                calls += rep.distance_calls as u128;
                prep += rep.prep_calls as u128;
                k = rep.discords.len().max(1);
            }
            let mean_calls = (calls as f64 / runs as f64).round() as u64;
            records.push(BenchRecord {
                engine: engine.to_string(),
                table: fx.name.clone(),
                n: fx.ts.n_total(),
                s: fx.params.sax.s,
                calls: mean_calls,
                cps: cps(mean_calls, n_seq, k),
                prep_calls: (prep as f64 / runs as f64).round() as u64,
                wall_ms: ms / runs as f64,
                latency: Some(hist.snapshot().summary_json()),
            });
        }
    }
    Ok(records)
}

/// The full trajectory: all of [`ALL_ENGINES`] over the tier's fixtures.
pub fn run_trajectory(cfg: &BenchConfig, quick: bool, kernel: Kernel) -> Result<Vec<BenchRecord>> {
    run_trajectory_filtered(cfg, quick, kernel, &ALL_ENGINES)
}

/// Compare two trajectories cell by cell (keyed by `(engine, table)`).
/// Returns human-readable lines: per shared cell the calls and wall-clock
/// ratios (new / old), plus a line for every cell present on one side
/// only. Errors when a shared cell measured different fixture sizes —
/// ratios across different `n` are meaningless.
pub fn diff(old: &[BenchRecord], new: &[BenchRecord]) -> Result<Vec<String>> {
    let key = |r: &BenchRecord| (r.engine.clone(), r.table.clone());
    let old_map: std::collections::BTreeMap<_, _> =
        old.iter().map(|r| (key(r), r)).collect();
    let new_map: std::collections::BTreeMap<_, _> =
        new.iter().map(|r| (key(r), r)).collect();
    let mut out = Vec::new();
    for ((engine, table), o) in &old_map {
        match new_map.get(&(engine.clone(), table.clone())) {
            Some(n) => {
                if o.n != n.n || o.s != n.s {
                    bail!(
                        "{engine} @ {table}: fixture mismatch \
                         (n {} vs {}, s {} vs {}) — rerun both sides at one \
                         configuration",
                        o.n,
                        n.n,
                        o.s,
                        n.s
                    );
                }
                out.push(format!(
                    "{engine} @ {table}: calls {} -> {} (x{:.3}), \
                     wall_ms {:.2} -> {:.2} (x{:.3})",
                    o.calls,
                    n.calls,
                    n.calls as f64 / o.calls.max(1) as f64,
                    o.wall_ms,
                    n.wall_ms,
                    if o.wall_ms > 0.0 { n.wall_ms / o.wall_ms } else { f64::NAN },
                ));
            }
            None => out.push(format!("{engine} @ {table}: removed (old only)")),
        }
    }
    for (engine, table) in new_map.keys() {
        if !old_map.contains_key(&(engine.clone(), table.clone())) {
            out.push(format!("{engine} @ {table}: added (new only)"));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> BenchRecord {
        BenchRecord {
            engine: "hst".into(),
            table: "ECG 0606".into(),
            n: 480,
            s: 120,
            calls: 1_234,
            cps: 3.4,
            prep_calls: 720,
            wall_ms: 1.9,
            latency: None,
        }
    }

    #[test]
    fn record_roundtrips_through_json() {
        let r = record();
        let back = BenchRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(r, back);
        // with a latency summary attached, it roundtrips too
        let mut r = record();
        r.latency = Some(
            Json::obj()
                .set("count", 3u64)
                .set("sum", 5.7)
                .set("mean", 1.9)
                .set("p50", 1.8)
                .set("p90", 2.4)
                .set("p99", 2.5),
        );
        let back = BenchRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(r, back);
        // a latency object missing a quantile key is rejected by name
        let bad = r.to_json().set("latency", Json::obj().set("count", 3u64));
        let err = BenchRecord::from_json(&bad).unwrap_err().to_string();
        assert!(err.contains("\"sum\""), "{err}");
    }

    #[test]
    fn validate_accepts_a_well_formed_document() {
        let meta = TrajectoryMeta::measured(
            &BenchConfig::smoke(),
            "quick",
            Kernel::Scalar,
        );
        let doc = trajectory_json(&meta, &[record()]);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let records = validate(&parsed).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].engine, "hst");
    }

    #[test]
    fn validate_rejects_schema_violations() {
        let meta =
            TrajectoryMeta::measured(&BenchConfig::smoke(), "quick", Kernel::Simd);
        // wrong schema id
        let doc = trajectory_json(&meta, &[record()]).set("schema", "nope/9");
        assert!(validate(&doc).is_err());
        // unknown engine id
        let mut bad = record();
        bad.engine = "warp-drive".into();
        assert!(validate(&trajectory_json(&meta, &[bad])).is_err());
        // cps must be positive
        let mut bad = record();
        bad.cps = 0.0;
        assert!(validate(&trajectory_json(&meta, &[bad])).is_err());
        // every schema key must be present
        let stripped = match record().to_json() {
            Json::Obj(mut m) => {
                m.remove("wall_ms");
                Json::Obj(m)
            }
            _ => unreachable!(),
        };
        let doc = Json::obj()
            .set("schema", TRAJECTORY_SCHEMA)
            .set("meta", meta.to_json())
            .set("records", vec![stripped]);
        let err = validate(&doc).unwrap_err().to_string();
        assert!(err.contains("record 0"), "{err}");
        // empty records
        assert!(validate(&trajectory_json(&meta, &[])).is_err());
    }

    #[test]
    fn diff_reports_ratios_and_refuses_mismatched_fixtures() {
        let a = record();
        let mut b = record();
        b.calls = 2_468;
        let lines = diff(&[a.clone()], &[b.clone()]).unwrap();
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("x2.000"), "{}", lines[0]);
        // one-sided cells are reported, not dropped
        let mut c = record();
        c.engine = "brute".into();
        let lines = diff(&[a.clone()], &[b.clone(), c]).unwrap();
        assert!(lines.iter().any(|l| l.contains("added")));
        // different n must refuse
        b.n = 960;
        assert!(diff(&[a], &[b]).is_err());
    }

    #[test]
    fn smoke_sweep_emits_valid_records() {
        // a two-engine micro sweep through the real machinery; the full
        // all-engine sweep is the ci/verify.sh `bench --quick` smoke step
        let cfg = BenchConfig::smoke();
        let records =
            run_trajectory_filtered(&cfg, true, Kernel::active(), &["hst", "hotsax"])
                .unwrap();
        assert_eq!(records.len(), 2 * QUICK_FIXTURES.len());
        let meta = TrajectoryMeta::measured(&cfg, "quick", Kernel::active());
        let doc = trajectory_json(&meta, &records);
        let back = validate(&doc).unwrap();
        assert_eq!(back.len(), records.len());
        for r in &back {
            assert!(r.cps > 0.0 && r.calls > 0, "{r:?}");
            assert!(r.n <= QUICK_CAP);
            // sweeps emit the latency summary: one observation per run
            let lat = r.latency.as_ref().expect("sweep records carry latency");
            assert_eq!(
                lat.get("count").unwrap().as_u64(),
                Some(cfg.runs.max(1) as u64)
            );
        }
    }
}
