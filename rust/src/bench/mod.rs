//! Micro-benchmark harness (the offline registry has no criterion, so the
//! crate ships its own: warmup, timed iterations, summary statistics) and
//! the machine-readable performance trajectory behind `hst bench`.

pub mod harness;
pub mod trajectory;

pub use harness::{bench_fn, BenchResult};
pub use trajectory::{
    diff, run_trajectory, run_trajectory_filtered, trajectory_json, validate,
    BenchRecord, TrajectoryMeta, TRAJECTORY_SCHEMA,
};
