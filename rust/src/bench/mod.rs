//! Micro-benchmark harness (the offline registry has no criterion, so the
//! crate ships its own: warmup, timed iterations, summary statistics).

pub mod harness;

pub use harness::{bench_fn, BenchResult};
