//! Structured search tracing: the span-shaped [`TraceSink`], the JSONL
//! trace writer, and the trace validator.
//!
//! A trace is a flat stream of events with an implied span tree:
//!
//! ```text
//! search_start ─┬─ phase "prepare" ── pass* ─┐
//!               └─ phase "search"  ── pass* ─┴─ discord* ── search_end
//! ```
//!
//! Every [`PassEvent`] carries the *delta* of distance calls spent inside
//! it, so the pass call-counts of a well-formed trace sum exactly to the
//! `distance_calls` its `search_end` reports — [`validate_trace`] checks
//! that, and `ci/verify.sh` gates on it. Sinks are read-only by contract:
//! they observe values the engines already maintain, never influence
//! them (the observability-neutrality property of
//! `tests/integration_obs.rs`).

use std::io::Write;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::discord::Discord;
use crate::util::json::Json;

/// Schema identifier written as the first line of every JSONL trace.
pub const TRACE_SCHEMA: &str = "hst-trace/1";

/// One pass of an engine's outer loop (or one whole phase, for engines
/// without a per-discord pass structure). All fields are deltas or
/// point-in-time reads of state the engine maintains anyway.
#[derive(Debug, Clone, Copy)]
pub struct PassEvent<'a> {
    /// Engine id (`"hst"`, `"brute"`, …).
    pub engine: &'a str,
    /// Phase this pass belongs to (`"prepare"` or `"search"`).
    pub phase: &'a str,
    /// 0-based pass index within the search (discord rank for the
    /// per-discord engines, scan step for the variable-length ones).
    pub index: usize,
    /// Outer-loop candidates visited during the pass.
    pub candidates: u64,
    /// Early-abandoned distance evaluations during the pass (delta of
    /// [`Distance::abandons`](crate::dist::Distance::abandons)).
    pub abandons: u64,
    /// Distance calls spent during the pass (delta of
    /// [`Distance::calls`](crate::dist::Distance::calls)); pass deltas
    /// sum to the report's `distance_calls`.
    pub calls: u64,
    /// Best-so-far bound when the pass ended (the discord's nnd for
    /// per-discord passes); `NaN` when the engine tracks no bound.
    pub best: f64,
}

/// The span-shaped extension of
/// [`SearchObserver`](crate::context::SearchObserver): a sink receives
/// the full search → phase → pass event stream. All methods default to
/// no-ops, so the absent sink compiles to nothing observable on results
/// and a partial sink implements only what it needs.
pub trait TraceSink: Send + Sync {
    /// A search span opened.
    fn on_search_start(&self, _engine: &str, _n: usize, _s: usize, _k: usize) {}

    /// The search entered a named phase (`"prepare"`, `"search"`).
    fn on_phase(&self, _engine: &str, _phase: &str) {}

    /// One outer-loop pass completed.
    fn on_pass(&self, _pass: &PassEvent<'_>) {}

    /// A discord was confirmed (`rank` is 0-based).
    fn on_discord(&self, _rank: usize, _discord: &Discord) {}

    /// The search span closed with its final call accounting.
    fn on_search_end(&self, _engine: &str, _distance_calls: u64, _prep_calls: u64) {}
}

/// Streams trace events as JSON lines (schema [`TRACE_SCHEMA`]).
///
/// The first line is the schema header; every later line is one event
/// object with an `"event"` discriminator. Writes go through one mutex —
/// events are per-pass, not per-distance-call, so the lock is far off
/// the hot path. IO errors are counted, not raised: a full disk must
/// fail the trace, never the search.
pub struct JsonlTraceWriter {
    out: Mutex<Box<dyn Write + Send>>,
    errors: Mutex<u64>,
}

impl JsonlTraceWriter {
    /// Create (truncate) `path` and write the schema header.
    pub fn create(path: &std::path::Path) -> Result<JsonlTraceWriter> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        Ok(JsonlTraceWriter::to_writer(Box::new(
            std::io::BufWriter::new(file),
        )))
    }

    /// Wrap any writer (tests trace into a `Vec<u8>` behind a pipe).
    pub fn to_writer(mut w: Box<dyn Write + Send>) -> JsonlTraceWriter {
        let header = Json::obj().set("schema", TRACE_SCHEMA);
        let _ = writeln!(w, "{header}");
        JsonlTraceWriter {
            out: Mutex::new(w),
            errors: Mutex::new(0),
        }
    }

    fn emit(&self, event: Json) {
        let mut out = self.out.lock().unwrap();
        if writeln!(out, "{event}").is_err() {
            *self.errors.lock().unwrap() += 1;
        }
    }

    /// Flush the underlying writer; returns how many event writes failed
    /// (0 for a healthy trace).
    pub fn finish(&self) -> Result<u64> {
        self.out.lock().unwrap().flush().context("flushing trace")?;
        Ok(*self.errors.lock().unwrap())
    }
}

/// Format an f64 for the trace: finite values verbatim, `NaN` as null
/// (JSON has no NaN literal).
fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

impl TraceSink for JsonlTraceWriter {
    fn on_search_start(&self, engine: &str, n: usize, s: usize, k: usize) {
        self.emit(
            Json::obj()
                .set("event", "search_start")
                .set("engine", engine)
                .set("n", n)
                .set("s", s)
                .set("k", k),
        );
    }

    fn on_phase(&self, engine: &str, phase: &str) {
        self.emit(
            Json::obj()
                .set("event", "phase")
                .set("engine", engine)
                .set("phase", phase),
        );
    }

    fn on_pass(&self, pass: &PassEvent<'_>) {
        self.emit(
            Json::obj()
                .set("event", "pass")
                .set("engine", pass.engine)
                .set("phase", pass.phase)
                .set("index", pass.index)
                .set("candidates", pass.candidates)
                .set("abandons", pass.abandons)
                .set("calls", pass.calls)
                .set("best", num(pass.best)),
        );
    }

    fn on_discord(&self, rank: usize, discord: &Discord) {
        self.emit(
            Json::obj()
                .set("event", "discord")
                .set("rank", rank)
                .set("position", discord.position)
                .set("neighbor", discord.neighbor)
                .set("nnd", num(discord.nnd))
                .set("nnd_bits", format!("{:016x}", discord.nnd.to_bits())),
        );
    }

    fn on_search_end(&self, engine: &str, distance_calls: u64, prep_calls: u64) {
        self.emit(
            Json::obj()
                .set("event", "search_end")
                .set("engine", engine)
                .set("distance_calls", distance_calls)
                .set("prep_calls", prep_calls),
        );
    }
}

/// What [`validate_trace`] found in a well-formed trace.
#[derive(Debug, Clone, Default)]
pub struct TraceSummary {
    /// Completed search spans.
    pub searches: usize,
    /// Pass events across all spans.
    pub passes: usize,
    /// Discord events across all spans.
    pub discords: usize,
    /// Sum of `distance_calls` over every `search_end`.
    pub distance_calls: u64,
    /// Sum of `prep_calls` over every `search_end`.
    pub prep_calls: u64,
}

impl TraceSummary {
    /// Serialize (the `hst trace` CLI prints this).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("schema", TRACE_SCHEMA)
            .set("searches", self.searches)
            .set("passes", self.passes)
            .set("discords", self.discords)
            .set("distance_calls", self.distance_calls)
            .set("prep_calls", self.prep_calls)
    }
}

/// Validate a JSONL trace: the header carries [`TRACE_SCHEMA`], every
/// line parses, spans nest (events only inside an open `search_start` …
/// `search_end` pair, spans never interleave), and within each span the
/// pass `calls` sum exactly to the `distance_calls` its `search_end`
/// reports. Returns a [`TraceSummary`] on success.
pub fn validate_trace(text: &str) -> Result<TraceSummary, String> {
    let mut lines = text.lines().enumerate();
    let (_, header) = lines.next().ok_or("empty trace")?;
    let header = Json::parse(header).map_err(|e| format!("header: {e}"))?;
    match header.get("schema").and_then(|s| s.as_str()) {
        Some(TRACE_SCHEMA) => {}
        Some(other) => {
            return Err(format!(
                "schema {other:?} (this validator speaks {TRACE_SCHEMA:?})"
            ))
        }
        None => return Err("header line has no `schema` field".into()),
    }
    let mut summary = TraceSummary::default();
    let mut open: Option<String> = None; // engine of the open span
    let mut span_pass_calls: u64 = 0;
    for (ln, line) in lines {
        let ln = ln + 1;
        if line.trim().is_empty() {
            continue;
        }
        let ev = Json::parse(line).map_err(|e| format!("line {ln}: {e}"))?;
        let kind = ev
            .get("event")
            .and_then(|e| e.as_str())
            .ok_or(format!("line {ln}: no `event` field"))?;
        let engine = ev.get("engine").and_then(|e| e.as_str());
        match kind {
            "search_start" => {
                if let Some(o) = &open {
                    return Err(format!(
                        "line {ln}: search_start while `{o}` span is open \
                         (spans must not interleave)"
                    ));
                }
                open = Some(
                    engine
                        .ok_or(format!("line {ln}: search_start needs `engine`"))?
                        .to_string(),
                );
                span_pass_calls = 0;
            }
            "phase" | "pass" | "discord" => {
                let Some(o) = &open else {
                    return Err(format!(
                        "line {ln}: `{kind}` outside any search span"
                    ));
                };
                if let Some(e) = engine {
                    if e != o {
                        return Err(format!(
                            "line {ln}: `{kind}` names engine `{e}` inside \
                             the `{o}` span"
                        ));
                    }
                }
                if kind == "pass" {
                    let calls = ev
                        .get("calls")
                        .and_then(|c| c.as_u64())
                        .ok_or(format!("line {ln}: pass needs `calls`"))?;
                    span_pass_calls += calls;
                    summary.passes += 1;
                } else if kind == "discord" {
                    let bits = ev
                        .get("nnd_bits")
                        .and_then(|b| b.as_str())
                        .ok_or(format!("line {ln}: discord needs `nnd_bits`"))?;
                    if bits.len() != 16
                        || !bits.bytes().all(|b| b.is_ascii_hexdigit())
                    {
                        return Err(format!(
                            "line {ln}: `nnd_bits` must be 16 hex chars, got \
                             {bits:?}"
                        ));
                    }
                    summary.discords += 1;
                }
            }
            "search_end" => {
                let Some(o) = open.take() else {
                    return Err(format!(
                        "line {ln}: search_end without search_start"
                    ));
                };
                if let Some(e) = engine {
                    if e != o {
                        return Err(format!(
                            "line {ln}: search_end names engine `{e}`, span \
                             opened as `{o}`"
                        ));
                    }
                }
                let calls = ev
                    .get("distance_calls")
                    .and_then(|c| c.as_u64())
                    .ok_or(format!("line {ln}: search_end needs `distance_calls`"))?;
                if span_pass_calls != calls {
                    return Err(format!(
                        "line {ln}: pass calls sum to {span_pass_calls} but \
                         search_end reports {calls} distance calls"
                    ));
                }
                summary.distance_calls += calls;
                summary.prep_calls += ev
                    .get("prep_calls")
                    .and_then(|c| c.as_u64())
                    .unwrap_or(0);
                summary.searches += 1;
            }
            other => {
                return Err(format!("line {ln}: unknown event {other:?}"));
            }
        }
    }
    if let Some(o) = open {
        return Err(format!("trace ends inside an open `{o}` span"));
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// A writer that shares its buffer so the test can read it back.
    #[derive(Clone, Default)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn trace_of(events: impl FnOnce(&JsonlTraceWriter)) -> String {
        let buf = SharedBuf::default();
        let w = JsonlTraceWriter::to_writer(Box::new(buf.clone()));
        events(&w);
        assert_eq!(w.finish().unwrap(), 0);
        String::from_utf8(buf.0.lock().unwrap().clone()).unwrap()
    }

    fn demo_discord() -> Discord {
        Discord {
            position: 120,
            nnd: 1.5,
            neighbor: 740,
        }
    }

    #[test]
    fn writer_emits_a_valid_trace() {
        let text = trace_of(|w| {
            w.on_search_start("hst", 1_000, 64, 1);
            w.on_phase("hst", "prepare");
            w.on_pass(&PassEvent {
                engine: "hst",
                phase: "prepare",
                index: 0,
                candidates: 1_000,
                abandons: 0,
                calls: 2_000,
                best: f64::NAN,
            });
            w.on_phase("hst", "search");
            w.on_pass(&PassEvent {
                engine: "hst",
                phase: "search",
                index: 0,
                candidates: 950,
                abandons: 800,
                calls: 1_234,
                best: 1.5,
            });
            w.on_discord(0, &demo_discord());
            w.on_search_end("hst", 3_234, 2_000);
        });
        assert!(text.starts_with("{\"schema\":\"hst-trace/1\"}\n"));
        let s = validate_trace(&text).unwrap();
        assert_eq!(s.searches, 1);
        assert_eq!(s.passes, 2);
        assert_eq!(s.discords, 1);
        assert_eq!(s.distance_calls, 3_234);
        assert_eq!(s.prep_calls, 2_000);
    }

    #[test]
    fn validator_rejects_mismatched_call_sums() {
        let text = trace_of(|w| {
            w.on_search_start("hst", 100, 8, 1);
            w.on_pass(&PassEvent {
                engine: "hst",
                phase: "search",
                index: 0,
                candidates: 10,
                abandons: 0,
                calls: 5,
                best: 1.0,
            });
            w.on_search_end("hst", 6, 0);
        });
        let err = validate_trace(&text).unwrap_err();
        assert!(err.contains("sum to 5"), "{err}");
    }

    #[test]
    fn validator_rejects_structural_breaks() {
        // event outside a span
        let text = trace_of(|w| w.on_phase("hst", "search"));
        assert!(validate_trace(&text).unwrap_err().contains("outside"));
        // unterminated span
        let text = trace_of(|w| w.on_search_start("hst", 10, 4, 1));
        assert!(validate_trace(&text).unwrap_err().contains("open"));
        // interleaved spans
        let text = trace_of(|w| {
            w.on_search_start("hst", 10, 4, 1);
            w.on_search_start("brute", 10, 4, 1);
        });
        assert!(validate_trace(&text).unwrap_err().contains("interleave"));
        // wrong engine inside a span
        let text = trace_of(|w| {
            w.on_search_start("hst", 10, 4, 1);
            w.on_phase("brute", "search");
        });
        assert!(validate_trace(&text).unwrap_err().contains("brute"));
        // wrong schema
        assert!(validate_trace("{\"schema\":\"hst-trace/999\"}\n")
            .unwrap_err()
            .contains("hst-trace/1"));
        assert!(validate_trace("").is_err());
    }

    #[test]
    fn nan_best_serializes_as_null() {
        let text = trace_of(|w| {
            w.on_search_start("hst", 10, 4, 1);
            w.on_pass(&PassEvent {
                engine: "hst",
                phase: "prepare",
                index: 0,
                candidates: 1,
                abandons: 0,
                calls: 0,
                best: f64::NAN,
            });
            w.on_search_end("hst", 0, 0);
        });
        assert!(text.contains("\"best\":null"), "{text}");
        validate_trace(&text).unwrap();
    }

    #[test]
    fn empty_span_with_no_calls_validates() {
        let text = trace_of(|w| {
            w.on_search_start("brute", 0, 4, 1);
            w.on_search_end("brute", 0, 0);
        });
        let s = validate_trace(&text).unwrap();
        assert_eq!(s.searches, 1);
        assert_eq!(s.passes, 0);
    }
}
