//! The metrics registry: named counters, gauges, and fixed-bucket
//! histograms with consistent snapshots and Prometheus/JSON exposition.
//!
//! Registration (name → handle) takes a mutex once per metric; every
//! record after that is a relaxed atomic op on a handle the caller keeps,
//! so the hot path never contends on the registry itself. Handles are
//! idempotent: asking for the same `(name, label)` again returns the same
//! underlying metric, which is what lets `CoordinatorStats` be a *view*
//! over the registry instead of a second set of counters.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Default latency buckets, in milliseconds: sub-millisecond searches up
/// to ten-second jobs, roughly 2.5× apart (the Prometheus default grid).
pub const LATENCY_BUCKETS_MS: [f64; 14] = [
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
    1_000.0, 2_500.0, 10_000.0,
];

/// Default size buckets (dimensionless: cps values, counts): powers of
/// two from 1 to 8192. A perfect-magic search (cps ≈ 2) lands in the
/// second bucket; brute force walks off the top.
pub const SIZE_BUCKETS: [f64; 14] = [
    1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1_024.0,
    2_048.0, 4_096.0, 8_192.0,
];

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Raise the counter to `v` if it is below it (absorbing an external
    /// monotonic source — e.g. the stream registry's own ingest atomics —
    /// without ever moving backwards).
    pub fn record_absolute(&self, v: u64) {
        self.value.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A gauge: a value that can go up and down (queue depths, open streams).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    /// Set the gauge.
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A fixed-bucket histogram. Bucket bounds are upper bounds (`le`), with
/// an implicit `+Inf` bucket at the end; `observe` is two relaxed
/// fetch-adds plus one CAS loop for the f64 sum — lock-free and
/// wait-free except under sum contention.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>, // one per bound, plus +Inf at the end
    count: AtomicU64,
    sum_bits: AtomicU64, // f64 bits, updated by CAS
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// Record one observation.
    pub fn observe(&self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    /// A point-in-time copy of the histogram state.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut cumulative = Vec::with_capacity(self.counts.len());
        let mut acc = 0u64;
        for c in &self.counts {
            acc += c.load(Ordering::Relaxed);
            cumulative.push(acc);
        }
        HistogramSnapshot {
            bounds: self.bounds.clone(),
            cumulative,
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
        }
    }
}

/// A consistent copy of one histogram: cumulative bucket counts
/// (`cumulative[i]` = observations ≤ `bounds[i]`; the final entry is the
/// `+Inf` bucket, equal to `count`), total count, and sum.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    /// Bucket upper bounds (`le`), strictly increasing, `+Inf` implicit.
    pub bounds: Vec<f64>,
    /// Cumulative count per bucket, `+Inf` last.
    pub cumulative: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Sum of all observed values.
    pub sum: f64,
}

impl HistogramSnapshot {
    /// Derive the `q`-quantile (`0 < q ≤ 1`) by linear interpolation
    /// inside the bucket holding the target rank — the same estimate
    /// Prometheus's `histogram_quantile` computes. Returns 0 when empty;
    /// observations in the `+Inf` bucket clamp to the highest finite
    /// bound (there is nothing better to interpolate against).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile must be in [0, 1]");
        if self.count == 0 {
            return 0.0;
        }
        let rank = q * self.count as f64;
        let idx = self
            .cumulative
            .iter()
            .position(|&c| c as f64 >= rank)
            .unwrap_or(self.cumulative.len() - 1);
        if idx >= self.bounds.len() {
            // +Inf bucket: clamp to the largest finite bound
            return *self.bounds.last().unwrap();
        }
        let hi = self.bounds[idx];
        let lo = if idx == 0 { 0.0 } else { self.bounds[idx - 1] };
        let below = if idx == 0 { 0 } else { self.cumulative[idx - 1] };
        let in_bucket = self.cumulative[idx] - below;
        if in_bucket == 0 {
            return hi;
        }
        lo + (hi - lo) * ((rank - below as f64) / in_bucket as f64)
    }

    /// p50 / p90 / p99 as a JSON object (plus count, sum, mean) — the
    /// summary shape the bench trajectory and the `metrics` command both
    /// embed.
    pub fn summary_json(&self) -> Json {
        let mean = if self.count > 0 {
            self.sum / self.count as f64
        } else {
            0.0
        };
        Json::obj()
            .set("count", self.count)
            .set("sum", self.sum)
            .set("mean", mean)
            .set("p50", self.quantile(0.50))
            .set("p90", self.quantile(0.90))
            .set("p99", self.quantile(0.99))
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn type_name(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// Key of one metric instance: base name plus at most one label pair
/// (`hst_job_latency_ms{engine="hst"}`). `BTreeMap` keeps snapshots in
/// a deterministic order.
type MetricKey = (String, Option<(String, String)>);

/// The metrics registry (see the [module docs](self)).
#[derive(Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<MetricKey, Metric>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn entry<T, F: FnOnce() -> Metric>(
        &self,
        name: &str,
        label: Option<(&str, &str)>,
        make: F,
        pick: impl FnOnce(&Metric) -> Option<Arc<T>>,
    ) -> Arc<T> {
        let key = (
            name.to_string(),
            label.map(|(k, v)| (k.to_string(), v.to_string())),
        );
        let mut g = self.inner.lock().unwrap();
        let metric = g.entry(key).or_insert_with(make);
        pick(metric).unwrap_or_else(|| {
            panic!(
                "metric `{name}` already registered as a {}",
                metric.type_name()
            )
        })
    }

    /// The counter named `name`, registering it on first use.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.labeled_counter_opt(name, None)
    }

    /// The counter `name{key="val"}`, registering it on first use.
    pub fn labeled_counter(&self, name: &str, key: &str, val: &str) -> Arc<Counter> {
        self.labeled_counter_opt(name, Some((key, val)))
    }

    fn labeled_counter_opt(
        &self,
        name: &str,
        label: Option<(&str, &str)>,
    ) -> Arc<Counter> {
        self.entry(
            name,
            label,
            || Metric::Counter(Arc::new(Counter::default())),
            |m| match m {
                Metric::Counter(c) => Some(Arc::clone(c)),
                _ => None,
            },
        )
    }

    /// The gauge named `name`, registering it on first use.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.entry(
            name,
            None,
            || Metric::Gauge(Arc::new(Gauge::default())),
            |m| match m {
                Metric::Gauge(g) => Some(Arc::clone(g)),
                _ => None,
            },
        )
    }

    /// The histogram named `name` with `bounds` as bucket upper bounds,
    /// registering it on first use (later calls keep the first bounds).
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Arc<Histogram> {
        self.labeled_histogram_opt(name, None, bounds)
    }

    /// The histogram `name{key="val"}`, registering it on first use.
    pub fn labeled_histogram(
        &self,
        name: &str,
        key: &str,
        val: &str,
        bounds: &[f64],
    ) -> Arc<Histogram> {
        self.labeled_histogram_opt(name, Some((key, val)), bounds)
    }

    fn labeled_histogram_opt(
        &self,
        name: &str,
        label: Option<(&str, &str)>,
        bounds: &[f64],
    ) -> Arc<Histogram> {
        self.entry(
            name,
            label,
            || Metric::Histogram(Arc::new(Histogram::new(bounds))),
            |m| match m {
                Metric::Histogram(h) => Some(Arc::clone(h)),
                _ => None,
            },
        )
    }

    /// A consistent, sorted snapshot of every registered metric.
    ///
    /// "Consistent" per metric: each counter/gauge is one atomic load and
    /// each histogram's buckets are summed in one pass — a histogram can
    /// lag a concurrent `observe` by at most that one in-flight op, and a
    /// snapshot never observes a partially-registered metric.
    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        Snapshot {
            metrics: g
                .iter()
                .map(|((name, label), metric)| MetricSnapshot {
                    name: name.clone(),
                    label: label.clone(),
                    value: match metric {
                        Metric::Counter(c) => MetricValue::Counter(c.get()),
                        Metric::Gauge(v) => MetricValue::Gauge(v.get()),
                        Metric::Histogram(h) => {
                            MetricValue::Histogram(h.snapshot())
                        }
                    },
                })
                .collect(),
        }
    }

    /// Base names of every registered metric, sorted and deduplicated
    /// (label variants collapse onto one name). The docs-consistency
    /// tests pin `docs/OBSERVABILITY.md`'s metric table against this.
    pub fn names(&self) -> Vec<String> {
        let g = self.inner.lock().unwrap();
        let mut names: Vec<String> =
            g.keys().map(|(name, _)| name.clone()).collect();
        names.dedup();
        names
    }
}

/// One metric in a [`Snapshot`].
#[derive(Debug, Clone)]
pub struct MetricSnapshot {
    /// Base metric name.
    pub name: String,
    /// Optional label pair.
    pub label: Option<(String, String)>,
    /// The value at snapshot time.
    pub value: MetricValue,
}

/// The value of one metric at snapshot time.
#[derive(Debug, Clone)]
pub enum MetricValue {
    /// Monotonic counter value.
    Counter(u64),
    /// Gauge value.
    Gauge(u64),
    /// Histogram state.
    Histogram(HistogramSnapshot),
}

/// A consistent read of the whole registry (see [`Registry::snapshot`]),
/// sorted by `(name, label)`.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Every metric, in deterministic order.
    pub metrics: Vec<MetricSnapshot>,
}

fn label_suffix(label: &Option<(String, String)>, extra: Option<(&str, String)>) -> String {
    let mut pairs: Vec<String> = Vec::new();
    if let Some((k, v)) = label {
        pairs.push(format!("{k}=\"{v}\""));
    }
    if let Some((k, v)) = extra {
        pairs.push(format!("{k}=\"{v}\""));
    }
    if pairs.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", pairs.join(","))
    }
}

fn fmt_f64(v: f64) -> String {
    // integers print without a fraction so counters round-trip exactly
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

impl Snapshot {
    /// Render as Prometheus text exposition format (version 0.0.4): a
    /// `# TYPE` line per base name, then one sample per value, histograms
    /// expanded into `_bucket{le=…}` / `_sum` / `_count` series.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_type_for: Option<&str> = None;
        for m in &self.metrics {
            let type_name = match &m.value {
                MetricValue::Counter(_) => "counter",
                MetricValue::Gauge(_) => "gauge",
                MetricValue::Histogram(_) => "histogram",
            };
            if last_type_for != Some(m.name.as_str()) {
                out.push_str(&format!("# TYPE {} {}\n", m.name, type_name));
                last_type_for = Some(m.name.as_str());
            }
            match &m.value {
                MetricValue::Counter(v) | MetricValue::Gauge(v) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        m.name,
                        label_suffix(&m.label, None),
                        v
                    ));
                }
                MetricValue::Histogram(h) => {
                    for (i, c) in h.cumulative.iter().enumerate() {
                        let le = if i < h.bounds.len() {
                            fmt_f64(h.bounds[i])
                        } else {
                            "+Inf".to_string()
                        };
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            m.name,
                            label_suffix(&m.label, Some(("le", le))),
                            c
                        ));
                    }
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        m.name,
                        label_suffix(&m.label, None),
                        fmt_f64(h.sum)
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        m.name,
                        label_suffix(&m.label, None),
                        h.count
                    ));
                }
            }
        }
        out
    }

    /// Render as a JSON object: metric name (with `{key="val"}` suffix
    /// for labeled instances) → value; histograms become their
    /// [`summary_json`](HistogramSnapshot::summary_json) plus raw
    /// buckets.
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for m in &self.metrics {
            let key = format!("{}{}", m.name, label_suffix(&m.label, None));
            let val = match &m.value {
                MetricValue::Counter(v) => {
                    Json::obj().set("type", "counter").set("value", *v)
                }
                MetricValue::Gauge(v) => {
                    Json::obj().set("type", "gauge").set("value", *v)
                }
                MetricValue::Histogram(h) => {
                    let buckets: Vec<Json> = h
                        .bounds
                        .iter()
                        .map(|b| Json::from(*b))
                        .collect();
                    let counts: Vec<Json> = h
                        .cumulative
                        .iter()
                        .map(|c| Json::from(*c))
                        .collect();
                    Json::obj()
                        .set("type", "histogram")
                        .set("summary", h.summary_json())
                        .set("le", buckets)
                        .set("cumulative", counts)
                }
            };
            obj = obj.set(&key, val);
        }
        obj
    }
}

/// Parse Prometheus text exposition back into `sample name (with
/// labels) → value` pairs, skipping comments. Strict on shape — a line
/// that is neither a comment nor `name[{labels}] value` is an error.
/// This is the round-trip half of [`Snapshot::to_prometheus`]: the
/// conformance tests (and `ci/verify.sh`'s metrics smoke) re-parse the
/// service's exposition and compare it against the snapshot.
pub fn parse_prometheus(text: &str) -> Result<BTreeMap<String, f64>, String> {
    let mut out = BTreeMap::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value: {line:?}", ln + 1))?;
        let v: f64 = value
            .parse()
            .map_err(|e| format!("line {}: bad value {value:?}: {e}", ln + 1))?;
        if out.insert(name.to_string(), v).is_some() {
            return Err(format!("line {}: duplicate sample {name:?}", ln + 1));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_register_once_and_accumulate() {
        let r = Registry::new();
        let a = r.counter("hst_test_total");
        let b = r.counter("hst_test_total");
        a.inc();
        b.add(4);
        assert_eq!(a.get(), 5, "handles must share one counter");
        a.record_absolute(3);
        assert_eq!(a.get(), 5, "record_absolute never moves backwards");
        a.record_absolute(9);
        assert_eq!(a.get(), 9);
        let g = r.gauge("hst_test_depth");
        g.set(7);
        assert_eq!(r.gauge("hst_test_depth").get(), 7);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn type_conflicts_panic() {
        let r = Registry::new();
        let _ = r.counter("hst_conflict");
        let _ = r.gauge("hst_conflict");
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new(&[1.0, 2.0, 4.0, 8.0]);
        for v in [0.5, 1.5, 1.5, 3.0, 7.0, 100.0] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.cumulative, vec![1, 3, 4, 5, 6]);
        assert!((s.sum - 113.5).abs() < 1e-9);
        // p50: rank 3.0 falls in the (1,2] bucket → interpolated ≤ 2
        let p50 = s.quantile(0.50);
        assert!(p50 > 1.0 && p50 <= 2.0, "p50 = {p50}");
        // p99: rank 5.94 falls in the +Inf bucket → clamps to 8
        assert_eq!(s.quantile(0.99), 8.0);
        // empty histogram
        assert_eq!(Histogram::new(&[1.0]).snapshot().quantile(0.9), 0.0);
    }

    #[test]
    fn quantile_interpolation_on_known_input() {
        // 100 observations spread uniformly over (0, 10] in the single
        // bucket (0, 10]: quantile(q) ≈ 10q by linear interpolation
        let h = Histogram::new(&[10.0, 20.0]);
        for i in 0..100 {
            h.observe(0.05 + (i as f64) * 0.1);
        }
        let s = h.snapshot();
        assert!((s.quantile(0.50) - 5.0).abs() < 1e-9);
        assert!((s.quantile(0.99) - 9.9).abs() < 1e-9);
        assert!((s.quantile(0.90) - 9.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_is_sorted_and_renders_both_formats() {
        let r = Registry::new();
        r.counter("hst_b_total").add(2);
        r.counter("hst_a_total").inc();
        r.labeled_histogram("hst_lat_ms", "engine", "hst", &[1.0, 10.0])
            .observe(3.0);
        let snap = r.snapshot();
        let names: Vec<&str> =
            snap.metrics.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, vec!["hst_a_total", "hst_b_total", "hst_lat_ms"]);
        let text = snap.to_prometheus();
        assert!(text.contains("# TYPE hst_a_total counter"));
        assert!(text.contains("hst_b_total 2"));
        assert!(text.contains("hst_lat_ms_bucket{engine=\"hst\",le=\"10\"} 1"));
        assert!(text.contains("hst_lat_ms_count{engine=\"hst\"} 1"));
        let json = snap.to_json();
        assert_eq!(
            json.get("hst_a_total").unwrap().get("value").unwrap().as_u64(),
            Some(1)
        );
        let hist = json.get("hst_lat_ms{engine=\"hst\"}").unwrap();
        assert_eq!(
            hist.get("summary").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
    }

    #[test]
    fn prometheus_text_round_trips_the_snapshot() {
        let r = Registry::new();
        r.counter("hst_jobs_total").add(11);
        r.gauge("hst_queued").set(3);
        let h = r.labeled_histogram(
            "hst_job_latency_ms",
            "engine",
            "hst",
            &LATENCY_BUCKETS_MS,
        );
        for v in [0.3, 2.0, 40.0] {
            h.observe(v);
        }
        let snap = r.snapshot();
        let parsed = parse_prometheus(&snap.to_prometheus()).unwrap();
        assert_eq!(parsed["hst_jobs_total"], 11.0);
        assert_eq!(parsed["hst_queued"], 3.0);
        assert_eq!(
            parsed["hst_job_latency_ms_count{engine=\"hst\"}"],
            3.0
        );
        assert_eq!(
            parsed["hst_job_latency_ms_bucket{engine=\"hst\",le=\"+Inf\"}"],
            3.0
        );
        assert_eq!(
            parsed["hst_job_latency_ms_bucket{engine=\"hst\",le=\"0.5\"}"],
            1.0
        );
        let sum = parsed["hst_job_latency_ms_sum{engine=\"hst\"}"];
        assert!((sum - 42.3).abs() < 1e-9);
        // every snapshot sample must appear in the parsed map
        let sample_count: usize = snap
            .metrics
            .iter()
            .map(|m| match &m.value {
                MetricValue::Histogram(h) => h.cumulative.len() + 2,
                _ => 1,
            })
            .sum();
        assert_eq!(parsed.len(), sample_count);
    }

    #[test]
    fn names_deduplicate_label_variants() {
        let r = Registry::new();
        r.labeled_counter("hst_x_total", "engine", "a").inc();
        r.labeled_counter("hst_x_total", "engine", "b").inc();
        r.counter("hst_y_total").inc();
        assert_eq!(r.names(), vec!["hst_x_total", "hst_y_total"]);
    }

    #[test]
    fn concurrent_observes_lose_nothing() {
        let r = Arc::new(Registry::new());
        let h = r.histogram("hst_conc_ms", &LATENCY_BUCKETS_MS);
        let c = r.counter("hst_conc_total");
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = Arc::clone(&h);
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for i in 0..1_000 {
                    h.observe((t * 1_000 + i) as f64 % 97.0);
                    c.inc();
                }
            }));
        }
        for hnd in handles {
            hnd.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4_000);
        assert_eq!(*s.cumulative.last().unwrap(), 4_000);
        assert_eq!(c.get(), 4_000);
    }
}
