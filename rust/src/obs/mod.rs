//! Observability: the metrics registry and structured search tracing.
//!
//! The paper's central measurement claim — cost per sequence (Sec. 4.2) —
//! is only auditable if you can see *where* the distance calls go:
//! warm-up vs. passes, abandons vs. full evaluations, how the best-so-far
//! bound evolves. This module turns those one-off bench assertions into
//! continuously observable, machine-readable facts:
//!
//! * [`Registry`] — named counters, gauges, and fixed-bucket histograms.
//!   Atomic and lock-free on the hot path (registration takes a mutex
//!   once; recording touches only `AtomicU64`s behind an `Arc`);
//!   [`Registry::snapshot`] gives a consistent, sorted read with
//!   p50/p90/p99 derivation, rendered as JSON or Prometheus text
//!   exposition.
//! * [`TraceSink`] — the span-shaped extension of
//!   [`SearchObserver`](crate::context::SearchObserver): search → phase →
//!   pass events carrying candidates visited, early abandons, distance
//!   calls, and the running best-so-far bound, with the prep vs. search
//!   split explicit. [`JsonlTraceWriter`] streams the events as JSON
//!   lines (schema [`TRACE_SCHEMA`], `hst ... --trace FILE`);
//!   [`validate_trace`] checks a trace nests correctly and that its pass
//!   call-counts sum to the report total.
//!
//! The hard invariant of the whole layer: **instrumentation never changes
//! engine output or call counts**. Sinks only *read* values the engines
//! already maintain; `tests/integration_obs.rs` enforces bit-identity
//! (positions, nnd bits, distance/prep calls) between traced+metered and
//! uninstrumented runs for every engine in
//! [`ALL_ENGINES`](crate::algo::ALL_ENGINES).

pub mod registry;
pub mod trace;

pub use registry::{
    parse_prometheus, Counter, Gauge, Histogram, HistogramSnapshot,
    MetricValue, Registry, Snapshot, LATENCY_BUCKETS_MS, SIZE_BUCKETS,
};
pub use trace::{
    validate_trace, JsonlTraceWriter, PassEvent, TraceSink, TraceSummary,
    TRACE_SCHEMA,
};
