//! Online discord monitoring — the streaming deployment mode the authors'
//! companion work ("significant online discords", Avogadro et al. 2020)
//! motivates and the paper's Sec. 4.5 alludes to.
//!
//! [`OnlineMonitor`] holds a sliding window of the most recent `window`
//! points; every `batch` arrivals it re-runs HST over the window **from
//! scratch**, fits the significance test on the window's exact profile,
//! and reports significant discords with *global* positions.
//!
//! Rerunning-from-scratch is the honest *baseline* for streaming HST —
//! the fully incremental variant is
//! [`StreamingMonitor`](crate::stream::StreamingMonitor), which shifts
//! the warm nnd profile across window advances so each refresh is a warm
//! search with bit-identical results (`benches/stream_refresh.rs`
//! measures the two against each other). This monitor stays as the
//! significance-testing front end and the cold-cost reference.

use anyhow::Result;

use crate::algo::{hst::HstSearch, Algorithm};
use crate::config::SearchParams;
use crate::discord::significance::SignificanceTest;
use crate::discord::Discord;
use crate::ts::{SeqStats, TimeSeries};

/// A discord reported by the monitor, in global stream coordinates.
#[derive(Debug, Clone, PartialEq)]
pub struct OnlineAlert {
    /// Global position of the anomalous sequence's first point.
    pub global_position: usize,
    /// Exact nearest-neighbor distance within the evaluation window.
    pub nnd: f64,
    /// Was it flagged significant by the Tukey fence?
    pub significant: bool,
}

/// Streaming discord monitor.
pub struct OnlineMonitor {
    params: SearchParams,
    /// Window capacity in points.
    window: usize,
    /// Re-evaluate every `batch` appended points.
    batch: usize,
    buf: Vec<f64>,
    /// Points consumed so far (global clock).
    consumed: usize,
    /// Points seen since the last evaluation.
    pending: usize,
}

impl OnlineMonitor {
    /// `window` must hold at least 4 sequences of `params.sax.s`.
    pub fn new(params: SearchParams, window: usize, batch: usize) -> OnlineMonitor {
        assert!(window >= 4 * params.sax.s, "window too small for s");
        assert!(batch >= 1);
        OnlineMonitor {
            params,
            window,
            batch,
            buf: Vec::new(),
            consumed: 0,
            pending: 0,
        }
    }

    /// Number of points currently buffered.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Append points; returns the alerts produced by any evaluations they
    /// triggered (one evaluation per completed batch).
    pub fn push(&mut self, points: &[f64]) -> Result<Vec<OnlineAlert>> {
        let mut alerts = Vec::new();
        for &p in points {
            self.buf.push(p);
            if self.buf.len() > self.window {
                self.buf.remove(0); // fine at these window sizes; a ring
                                    // buffer is a micro-optimization here
            }
            self.consumed += 1;
            self.pending += 1;
            if self.pending >= self.batch && self.buf.len() >= 4 * self.params.sax.s {
                self.pending = 0;
                alerts.extend(self.evaluate()?);
            }
        }
        Ok(alerts)
    }

    /// Force an evaluation of the current window.
    pub fn evaluate(&self) -> Result<Vec<OnlineAlert>> {
        let ts = TimeSeries::new("online-window", self.buf.clone());
        let rep = HstSearch::default().run(&ts, &self.params)?;
        // significance fitted on the window's exact profile (cheap at
        // monitor window sizes); the discords re-use HST's exact nnds
        let stats = SeqStats::compute(&ts, self.params.sax.s);
        let (profile, _) = crate::algo::scamp::Scamp::matrix_profile(&ts, &stats);
        let test = SignificanceTest::fit_default(&profile);
        let offset = self.consumed - self.buf.len();
        Ok(rep
            .discords
            .iter()
            .map(|d: &Discord| OnlineAlert {
                global_position: offset + d.position,
                nnd: d.nnd,
                significant: test.is_significant(d),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;

    fn monitor(s: usize, window: usize, batch: usize) -> OnlineMonitor {
        OnlineMonitor::new(SearchParams::new(s, 4, 4).with_seed(1), window, batch)
    }

    #[test]
    fn detects_anomaly_after_it_streams_in() {
        let s = 64;
        let mut m = monitor(s, 1_200, 400);
        // clean background
        let clean = generators::sine_with_noise(1_200, 0.05, 800);
        let alerts = m.push(&clean).unwrap();
        let clean_significant = alerts.iter().filter(|a| a.significant).count();

        // stream in a window containing a bump
        let mut burst = generators::sine_with_noise(800, 0.05, 801);
        let mut rng = crate::util::rng::Rng64::new(5);
        generators::inject(&mut burst, 400, s, generators::Anomaly::Bump, &mut rng);
        let alerts = m.push(&burst).unwrap();
        let hits: Vec<&OnlineAlert> =
            alerts.iter().filter(|a| a.significant).collect();
        assert!(
            hits.len() > clean_significant,
            "bump must raise significant alerts ({} vs baseline {})",
            hits.len(),
            clean_significant
        );
        // the alert's global position points at the bump region
        let bump_global = 1_200 + 400;
        assert!(
            hits.iter()
                .any(|a| a.global_position.abs_diff(bump_global) <= 2 * s),
            "no alert near global bump at {bump_global}: {hits:?}"
        );
    }

    #[test]
    fn global_positions_advance_with_the_stream() {
        let s = 64;
        let mut m = monitor(s, 800, 800);
        let a1 = m.push(&generators::sine_with_noise(800, 0.3, 802)).unwrap();
        let a2 = m.push(&generators::sine_with_noise(800, 0.3, 803)).unwrap();
        assert!(!a1.is_empty() && !a2.is_empty());
        let max1 = a1.iter().map(|a| a.global_position).max().unwrap();
        let min2 = a2.iter().map(|a| a.global_position).min().unwrap();
        assert!(min2 > max1.saturating_sub(800), "positions move forward");
    }

    #[test]
    fn window_capacity_is_respected() {
        let mut m = monitor(64, 600, 10_000);
        m.push(&generators::random_walk(5_000, 1.0, 804)).unwrap();
        assert_eq!(m.buffered(), 600);
    }

    #[test]
    #[should_panic(expected = "window too small")]
    fn rejects_tiny_window() {
        monitor(128, 256, 10);
    }
}
