//! Batch discord-search service: the deployment-facing coordinator.
//!
//! A thread-pool job runner with bounded-queue backpressure behind a TCP
//! front end that speaks two encodings over one port: JSON lines for
//! commands, and length-prefixed binary [`frame`]s for high-rate stream
//! ingest (negotiated with a versioned `hello`). The server is a
//! readiness-driven reactor — one thread multiplexes every connection,
//! parking blocked `wait`/`subscribe` replies as polled slots instead of
//! pinning a thread each. (The offline registry has no tokio; reactor,
//! coordinator, and stream drain workers are std threads + condvars —
//! the concurrency pattern, not the framework, is what matters at this
//! scale.)
//!
//! Protocol sketch (one JSON object per line; the **complete reference**
//! — every command, field, error shape, the binary frame layout, and a
//! worked TCP transcript — is `docs/PROTOCOL.md` at the repository root,
//! kept in sync with [`server::COMMANDS`] and the frame codec by
//! `tests/docs_consistency.rs`):
//!
//! ```text
//! → {"cmd":"hello","version":1}
//! ← {"ok":true,"frames":{"version":1,"magic":[181,72],"header_len":12,"max_points":65536}}
//! → {"cmd":"submit","dataset":"ECG 300","scale_div":8,"algo":"hst","params":{"s":300,"p":4,"alphabet":4,"k":3}}
//! ← {"ok":true,"job":1}
//! → {"cmd":"batch","jobs":[{"dataset":"ECG 300","algo":"hst-par","threads":4,"params":{"s":300}}, …]}
//! ← {"ok":true,"jobs":[2,3]}
//! → {"cmd":"mdim","dataset":"synthetic-md:channels=3,n=8000,len=128","algo":"hst-md","params":{"s":128,"channels":["c0","c2"]}}
//! ← {"ok":true,"job":4}
//! → {"cmd":"vl","dataset":"ECG 300","scale_div":8,"params":{"s":300,"s_min":150,"s_max":300,"s_step":25}}
//! ← {"ok":true,"job":5}
//! → {"cmd":"status","job":1}
//! ← {"ok":true,"job":1,"state":"done","report":{...}}
//! → {"cmd":"wait","job":1,"timeout_ms":250}
//! ← {"ok":true,"job":1,"state":"running","timed_out":true}   (on expiry)
//! → {"cmd":"stats"}
//! ← {"ok":true,"queued":0,"running":1,"workers":4,…,"conns":3,"pending":1,"frames_rx":128,"frames_shed":0,…}
//! → {"cmd":"stream_open","stream":"sensor-7","window":4000,"refresh_every":500,"params":{"s":64}}
//! ← {"ok":true,"stream":"sensor-7","stream_id":1}
//! → [0xB5 0x48 v=1 kind=data stream_id=1 payload_len=4000] + 500 × f64 LE   (binary, no reply)
//! ← [0xB5 0x48 v=1 kind=shed stream_id=1] + dropped/reason               (only on overload)
//! → {"cmd":"append","stream":"sensor-7","points":[0.93,1.02, …]}
//! ← {"ok":true,"stream":"sensor-7","appended":500,"updates":[{"refresh":1,"discords":[…], …}]}
//! → {"cmd":"subscribe","stream":"sensor-7","after":1,"timeout_ms":250}
//! ← {"ok":true,"stream":"sensor-7","seq":2,"update":{…}}      (or timed_out)
//! → {"cmd":"stream_close","stream":"sensor-7"} | {"cmd":"list"} | {"cmd":"shutdown"}
//! ```
//!
//! Unknown request fields (job-level, stream-level, or inside `params`)
//! are rejected by name, and a per-job `threads` field (or
//! `params.threads`) selects the worker count of the parallel engines
//! (`hst-par`, `scamp-par`) through the shared
//! [`ExecPolicy`](crate::exec::ExecPolicy). A `batch` is atomic: either
//! the queue admits every job of the array or none.
//!
//! Workers run jobs through a shared LRU of prepared
//! [`SearchContext`](crate::context::SearchContext)s keyed by
//! `(dataset, scale_div, SaxParams)`: repeated jobs on the same series
//! skip series generation and preparation. Reports carry
//! `ctx_cache: "hit" | "miss"` and the engine's `prep_calls` so callers
//! can observe the reuse.
//!
//! Streaming state lives in the coordinator's bounded [`StreamRegistry`]
//! alongside that LRU: each open stream is one incremental
//! [`StreamingMonitor`](crate::stream::StreamingMonitor) plus a bounded
//! ingest queue of raw binary batches serviced by drain workers, so
//! every append pays only the window delta and each refresh is a warm
//! search — bit-identical whichever encoding delivered the points (see
//! the [`stream`](crate::stream) module for the exactness argument, and
//! [`streams`] for the backpressure bounds).

pub mod coordinator;
pub mod frame;
pub mod online;
pub mod server;
pub mod streams;

pub use coordinator::{
    Coordinator, CoordinatorConfig, CoordinatorStats, JobSpec, JobState,
    MdimJobSpec, SnapshotRestoreReport, SnapshotSaveReport, VlJobSpec,
};
pub use server::{
    serve, serve_config, Client, ServeConfig, ShedNotice, CLIENT_INFLIGHT_QUOTA,
};
pub use streams::{Enqueue, IngestStats, StreamRegistry};
