//! Batch discord-search service: the deployment-facing coordinator.
//!
//! A thread-pool job runner with bounded-queue backpressure plus a TCP
//! JSON-lines front end. (The offline registry has no tokio; the
//! coordinator uses std threads + condvar — the concurrency pattern, not
//! the framework, is what matters at this scale.)
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"cmd":"submit","dataset":"ECG 300","scale_div":8,"algo":"hst","params":{"s":300,"p":4,"alphabet":4,"k":3}}
//! ← {"ok":true,"job":1}
//! → {"cmd":"status","job":1}
//! ← {"ok":true,"job":1,"state":"done","report":{...}}
//! → {"cmd":"list"} | {"cmd":"shutdown"}
//! ```
//!
//! Workers run jobs through a shared LRU of prepared
//! [`SearchContext`](crate::context::SearchContext)s keyed by
//! `(dataset, scale_div, SaxParams)`: repeated jobs on the same series
//! skip series generation and preparation. Reports carry
//! `ctx_cache: "hit" | "miss"` and the engine's `prep_calls` so callers
//! can observe the reuse.

pub mod coordinator;
pub mod online;
pub mod server;

pub use coordinator::{Coordinator, JobSpec, JobState};
pub use server::{serve, Client};
