//! Batch discord-search service: the deployment-facing coordinator.
//!
//! A thread-pool job runner with bounded-queue backpressure plus a TCP
//! JSON-lines front end. (The offline registry has no tokio; the
//! coordinator uses std threads + condvar — the concurrency pattern, not
//! the framework, is what matters at this scale.)
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"cmd":"submit","dataset":"ECG 300","scale_div":8,"algo":"hst","params":{"s":300,"p":4,"alphabet":4,"k":3}}
//! ← {"ok":true,"job":1}
//! → {"cmd":"batch","jobs":[{"dataset":"ECG 300","algo":"hst-par","threads":4,"params":{"s":300}}, …]}
//! ← {"ok":true,"jobs":[2,3]}
//! → {"cmd":"status","job":1}
//! ← {"ok":true,"job":1,"state":"done","report":{...}}
//! → {"cmd":"wait","job":1,"timeout_ms":250}
//! ← {"ok":true,"job":1,"state":"running","timed_out":true}   (on expiry)
//! → {"cmd":"stats"}
//! ← {"ok":true,"queued":0,"running":1,"workers":4,"jobs_total":3,"queue_capacity":64,"ctx_cache_entries":1}
//! → {"cmd":"list"} | {"cmd":"shutdown"}
//! ```
//!
//! Unknown request fields (job-level or inside `params`) are rejected by
//! name, and a per-job `threads` field (or `params.threads`) selects the
//! worker count of the parallel engines (`hst-par`, `scamp-par`) through
//! the shared [`ExecPolicy`](crate::exec::ExecPolicy). A `batch` is
//! atomic: either the queue admits every job of the array or none.
//!
//! Workers run jobs through a shared LRU of prepared
//! [`SearchContext`](crate::context::SearchContext)s keyed by
//! `(dataset, scale_div, SaxParams)`: repeated jobs on the same series
//! skip series generation and preparation. Reports carry
//! `ctx_cache: "hit" | "miss"` and the engine's `prep_calls` so callers
//! can observe the reuse.

pub mod coordinator;
pub mod online;
pub mod server;

pub use coordinator::{Coordinator, CoordinatorStats, JobSpec, JobState};
pub use server::{serve, Client};
