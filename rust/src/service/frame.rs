//! Length-prefixed binary frames for stream ingest.
//!
//! SNIPPETS.md's feagi serialization docs put it bluntly: JSON parsing
//! overhead makes a line protocol unfit "for any sort of real time data
//! streaming". This module is the repo's answer — a fixed 12-byte header
//! (magic + version + frame kind + stream id + payload length) followed
//! by raw little-endian `f64` points, decoded straight into the monitor
//! deques with no per-point text parsing.
//!
//! Framing is negotiated per connection via a tiss-style versioned JSON
//! `hello` (see `docs/PROTOCOL.md` § Binary framing); JSON lines and
//! binary frames then share the socket. The two are distinguished by the
//! first byte: [`MAGIC`]'s leading byte is `0xB5`, outside ASCII, so it
//! can never open a JSON line (`{`), and the reactor routes on it.
//!
//! Wire layout (all multi-byte integers little-endian):
//!
//! ```text
//! offset  size  field        value
//! ------  ----  -----------  ------------------------------------------
//!      0     2  magic        0xB5 0x48
//!      2     1  version      1 (FRAME_VERSION)
//!      3     1  kind         FrameKind code (1 = data, 2 = shed)
//!      4     4  stream_id    u32, assigned by `stream_open`
//!      8     4  payload_len  u32, payload bytes that follow (bounded)
//!     12     …  payload      kind-specific (data: packed LE f64 points)
//! ```
//!
//! Every decode error names the offending field and its value
//! ([`FrameError`]); a hostile `payload_len` is rejected *before* any
//! allocation (`MAX_FRAME_POINTS` caps it), upholding the repo-wide rule
//! that a network-supplied size must never drive an unbounded
//! allocation.

use std::fmt;

/// Leading two bytes of every frame. The first byte is deliberately
/// non-ASCII so a frame can never be confused with a JSON line on the
/// shared socket.
pub const MAGIC: [u8; 2] = [0xB5, 0x48];

/// The one frame-layout version this build speaks; negotiated by the
/// JSON `hello` command.
pub const FRAME_VERSION: u8 = 1;

/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 12;

/// Most points one `data` frame may carry (bounds `payload_len` at
/// 512 KiB, so a hostile header cannot size an allocation unbounded).
pub const MAX_FRAME_POINTS: usize = 65_536;

/// Largest admissible `payload_len` ([`MAX_FRAME_POINTS`] × 8 bytes).
pub const MAX_PAYLOAD_LEN: usize = MAX_FRAME_POINTS * 8;

/// What a frame carries. `docs/PROTOCOL.md`'s Binary framing table is
/// pinned to this enum by `tests/docs_consistency.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Client → server: packed little-endian `f64` points to append to
    /// the stream named by `stream_id`.
    Data,
    /// Server → client: a shed-load notice — the points of one `data`
    /// frame were dropped (payload: dropped count + reason code).
    Shed,
}

impl FrameKind {
    /// Every kind, in wire-code order.
    pub const ALL: [FrameKind; 2] = [FrameKind::Data, FrameKind::Shed];

    /// Wire code of this kind (the header's `kind` byte).
    pub fn code(self) -> u8 {
        match self {
            FrameKind::Data => 1,
            FrameKind::Shed => 2,
        }
    }

    /// Protocol-facing name (what the docs table and errors print).
    pub fn name(self) -> &'static str {
        match self {
            FrameKind::Data => "data",
            FrameKind::Shed => "shed",
        }
    }

    /// Inverse of [`code`](Self::code).
    pub fn from_code(code: u8) -> Option<FrameKind> {
        FrameKind::ALL.into_iter().find(|k| k.code() == code)
    }
}

/// Why a `shed` frame dropped a `data` frame's points.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The stream's bounded append queue was full.
    QueueFull,
    /// The sending connection exceeded its in-flight point quota.
    ClientQuota,
    /// The `stream_id` names no open stream.
    NoSuchStream,
}

impl ShedReason {
    /// Every reason, in wire-code order.
    pub const ALL: [ShedReason; 3] = [
        ShedReason::QueueFull,
        ShedReason::ClientQuota,
        ShedReason::NoSuchStream,
    ];

    /// Wire code (first payload byte after the dropped count).
    pub fn code(self) -> u8 {
        match self {
            ShedReason::QueueFull => 1,
            ShedReason::ClientQuota => 2,
            ShedReason::NoSuchStream => 3,
        }
    }

    /// Protocol-facing name (mirrored into `stats` counters and docs).
    pub fn name(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::ClientQuota => "client_quota",
            ShedReason::NoSuchStream => "no_such_stream",
        }
    }

    /// Inverse of [`code`](Self::code).
    pub fn from_code(code: u8) -> Option<ShedReason> {
        ShedReason::ALL.into_iter().find(|r| r.code() == code)
    }
}

/// A decoded fixed header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    /// Layout version (must equal [`FRAME_VERSION`] to decode).
    pub version: u8,
    /// What the payload carries.
    pub kind: FrameKind,
    /// Stream the frame addresses (from `stream_open`'s reply).
    pub stream_id: u32,
    /// Payload bytes following the header (≤ [`MAX_PAYLOAD_LEN`]).
    pub payload_len: usize,
}

/// A complete frame borrowed out of a receive buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Frame<'a> {
    /// The decoded header.
    pub header: FrameHeader,
    /// The raw payload bytes (exactly `header.payload_len` of them).
    pub payload: &'a [u8],
}

/// Decode failures, each naming the offending field and value — a
/// malformed frame is rejected loudly, never panicked on, and never
/// drives an allocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The first two bytes are not [`MAGIC`].
    BadMagic {
        /// The bytes actually found.
        found: [u8; 2],
    },
    /// The `version` byte is not [`FRAME_VERSION`].
    BadVersion {
        /// The version actually found.
        found: u8,
    },
    /// The `kind` byte maps to no [`FrameKind`].
    BadKind {
        /// The code actually found.
        found: u8,
    },
    /// The `payload_len` field exceeds [`MAX_PAYLOAD_LEN`].
    Oversized {
        /// The length actually requested.
        payload_len: usize,
    },
    /// The buffer ends before the frame does (header or payload).
    Truncated {
        /// Bytes the complete frame needs.
        needed: usize,
        /// Bytes actually available.
        have: usize,
    },
    /// A `data` payload whose byte length is not a multiple of 8.
    PayloadAlign {
        /// The misaligned payload length.
        payload_len: usize,
    },
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::BadMagic { found } => write!(
                f,
                "frame field `magic` is [{:#04x}, {:#04x}], expected \
                 [{:#04x}, {:#04x}]",
                found[0], found[1], MAGIC[0], MAGIC[1]
            ),
            FrameError::BadVersion { found } => write!(
                f,
                "frame field `version` is {found}, this server speaks \
                 {FRAME_VERSION}"
            ),
            FrameError::BadKind { found } => write!(
                f,
                "frame field `kind` is {found}, known kinds: {}",
                FrameKind::ALL
                    .map(|k| format!("{} = {}", k.name(), k.code()))
                    .join(", ")
            ),
            FrameError::Oversized { payload_len } => write!(
                f,
                "frame field `payload_len` is {payload_len}, cap is \
                 {MAX_PAYLOAD_LEN} bytes ({MAX_FRAME_POINTS} points)"
            ),
            FrameError::Truncated { needed, have } => write!(
                f,
                "frame truncated: field `payload_len` promises {needed} \
                 bytes total, only {have} arrived"
            ),
            FrameError::PayloadAlign { payload_len } => write!(
                f,
                "frame field `payload_len` is {payload_len}, which is not \
                 a multiple of 8 (packed f64 points)"
            ),
        }
    }
}

impl std::error::Error for FrameError {}

/// Encode a header. `payload_len` is the caller's responsibility to
/// keep within [`MAX_PAYLOAD_LEN`] (encoders below do).
pub fn encode_header(
    kind: FrameKind,
    stream_id: u32,
    payload_len: usize,
) -> [u8; HEADER_LEN] {
    debug_assert!(payload_len <= MAX_PAYLOAD_LEN);
    let mut h = [0u8; HEADER_LEN];
    h[..2].copy_from_slice(&MAGIC);
    h[2] = FRAME_VERSION;
    h[3] = kind.code();
    h[4..8].copy_from_slice(&stream_id.to_le_bytes());
    h[8..12].copy_from_slice(&(payload_len as u32).to_le_bytes());
    h
}

/// Encode one `data` frame: header plus the points packed as
/// little-endian `f64`. Panics (debug) if `points` exceeds
/// [`MAX_FRAME_POINTS`]; callers chunk first.
pub fn encode_data(stream_id: u32, points: &[f64]) -> Vec<u8> {
    debug_assert!(points.len() <= MAX_FRAME_POINTS);
    let mut out = Vec::with_capacity(HEADER_LEN + points.len() * 8);
    out.extend_from_slice(&encode_header(
        FrameKind::Data,
        stream_id,
        points.len() * 8,
    ));
    for &x in points {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Encode one `shed` frame: 8-byte payload = dropped point count (u32
/// LE) + reason code (u8) + three reserved zero bytes.
pub fn encode_shed(stream_id: u32, dropped: u32, reason: ShedReason) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + 8);
    out.extend_from_slice(&encode_header(FrameKind::Shed, stream_id, 8));
    out.extend_from_slice(&dropped.to_le_bytes());
    out.push(reason.code());
    out.extend_from_slice(&[0u8; 3]);
    out
}

/// Decode the fixed header from the front of `buf`. Validates magic,
/// version, kind, and the payload-length cap — everything that can be
/// checked *before* waiting for (or allocating) the payload.
pub fn decode_header(buf: &[u8]) -> Result<FrameHeader, FrameError> {
    if buf.len() < HEADER_LEN {
        return Err(FrameError::Truncated {
            needed: HEADER_LEN,
            have: buf.len(),
        });
    }
    if buf[..2] != MAGIC {
        return Err(FrameError::BadMagic {
            found: [buf[0], buf[1]],
        });
    }
    if buf[2] != FRAME_VERSION {
        return Err(FrameError::BadVersion { found: buf[2] });
    }
    let kind =
        FrameKind::from_code(buf[3]).ok_or(FrameError::BadKind { found: buf[3] })?;
    let stream_id = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let payload_len =
        u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    if payload_len > MAX_PAYLOAD_LEN {
        return Err(FrameError::Oversized { payload_len });
    }
    if kind == FrameKind::Data && payload_len % 8 != 0 {
        return Err(FrameError::PayloadAlign { payload_len });
    }
    Ok(FrameHeader {
        version: buf[2],
        kind,
        stream_id,
        payload_len,
    })
}

/// Decode one complete frame from the front of `buf`, borrowing the
/// payload. Errors `Truncated` when `buf` holds less than the header
/// promises — a streaming reader treats that as "wait for more bytes"
/// while it can still read, and as a hard error at EOF.
pub fn decode(buf: &[u8]) -> Result<Frame<'_>, FrameError> {
    let header = decode_header(buf)?;
    let total = HEADER_LEN + header.payload_len;
    if buf.len() < total {
        return Err(FrameError::Truncated {
            needed: total,
            have: buf.len(),
        });
    }
    Ok(Frame {
        header,
        payload: &buf[HEADER_LEN..total],
    })
}

/// Iterate a `data` payload's points without materializing a `Vec`
/// (the zero-copy half of the ingest path — bytes go socket buffer →
/// monitor deques with exactly one decode).
pub fn payload_points(payload: &[u8]) -> impl Iterator<Item = f64> + '_ {
    payload
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
}

/// Decode a `shed` frame's payload: `(dropped points, reason)`. `None`
/// for a malformed payload (wrong length or unknown reason code).
pub fn decode_shed_payload(payload: &[u8]) -> Option<(u32, ShedReason)> {
    if payload.len() != 8 {
        return None;
    }
    let dropped = u32::from_le_bytes(payload[..4].try_into().unwrap());
    let reason = ShedReason::from_code(payload[4])?;
    Some((dropped, reason))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_frame_roundtrips_bit_identically() {
        let points = [0.0, -1.5, f64::MIN_POSITIVE, 1.0e300, -0.0, 42.125];
        let wire = encode_data(7, &points);
        assert_eq!(wire.len(), HEADER_LEN + points.len() * 8);
        let frame = decode(&wire).unwrap();
        assert_eq!(frame.header.kind, FrameKind::Data);
        assert_eq!(frame.header.version, FRAME_VERSION);
        assert_eq!(frame.header.stream_id, 7);
        assert_eq!(frame.header.payload_len, points.len() * 8);
        let back: Vec<f64> = payload_points(frame.payload).collect();
        assert_eq!(back.len(), points.len());
        for (a, b) in points.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "f64 bits must survive");
        }
    }

    #[test]
    fn shed_frame_roundtrips() {
        let wire = encode_shed(9, 512, ShedReason::ClientQuota);
        let frame = decode(&wire).unwrap();
        assert_eq!(frame.header.kind, FrameKind::Shed);
        assert_eq!(frame.header.stream_id, 9);
        let dropped =
            u32::from_le_bytes(frame.payload[..4].try_into().unwrap());
        assert_eq!(dropped, 512);
        assert_eq!(
            ShedReason::from_code(frame.payload[4]),
            Some(ShedReason::ClientQuota)
        );
        assert_eq!(
            decode_shed_payload(frame.payload),
            Some((512, ShedReason::ClientQuota))
        );
        assert_eq!(decode_shed_payload(&frame.payload[..7]), None);
    }

    #[test]
    fn bad_magic_is_rejected_by_name() {
        let mut wire = encode_data(1, &[1.0]);
        wire[0] = b'{'; // a JSON line can never be a frame, and vice versa
        let err = decode(&wire).unwrap_err();
        assert_eq!(err, FrameError::BadMagic { found: [b'{', 0x48] });
        assert!(err.to_string().contains("`magic`"), "{err}");
    }

    #[test]
    fn wrong_version_is_rejected_by_name() {
        let mut wire = encode_data(1, &[1.0]);
        wire[2] = 9;
        let err = decode(&wire).unwrap_err();
        assert_eq!(err, FrameError::BadVersion { found: 9 });
        assert!(err.to_string().contains("`version`"), "{err}");
    }

    #[test]
    fn unknown_kind_is_rejected_by_name() {
        let mut wire = encode_data(1, &[1.0]);
        wire[3] = 0xEE;
        let err = decode(&wire).unwrap_err();
        assert_eq!(err, FrameError::BadKind { found: 0xEE });
        assert!(err.to_string().contains("`kind`"), "{err}");
    }

    #[test]
    fn truncated_payload_is_rejected_with_counts() {
        let wire = encode_data(1, &[1.0, 2.0, 3.0]);
        let err = decode(&wire[..wire.len() - 5]).unwrap_err();
        assert_eq!(
            err,
            FrameError::Truncated {
                needed: HEADER_LEN + 24,
                have: HEADER_LEN + 19,
            }
        );
        // a cut inside the header is truncation too, not garbage
        assert!(matches!(
            decode(&wire[..HEADER_LEN - 1]),
            Err(FrameError::Truncated { .. })
        ));
    }

    #[test]
    fn oversized_length_field_is_rejected_before_any_allocation() {
        // hand-craft a header whose payload_len is hostile: the decoder
        // must reject from the 12 header bytes alone — it never waits
        // for, or allocates, 4 GiB
        let mut h = encode_header(FrameKind::Data, 1, 8).to_vec();
        h[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_header(&h).unwrap_err();
        assert_eq!(
            err,
            FrameError::Oversized {
                payload_len: u32::MAX as usize
            }
        );
        assert!(err.to_string().contains("`payload_len`"), "{err}");
    }

    #[test]
    fn misaligned_data_payload_is_rejected() {
        let mut h = encode_header(FrameKind::Data, 1, 8).to_vec();
        h[8..12].copy_from_slice(&12u32.to_le_bytes());
        let err = decode_header(&h).unwrap_err();
        assert_eq!(err, FrameError::PayloadAlign { payload_len: 12 });
        assert!(err.to_string().contains("multiple of 8"), "{err}");
    }

    #[test]
    fn kind_codes_roundtrip_and_magic_is_not_ascii() {
        for k in FrameKind::ALL {
            assert_eq!(FrameKind::from_code(k.code()), Some(k));
        }
        assert_eq!(FrameKind::from_code(0), None);
        // the JSON/frame demultiplexer depends on this byte never
        // starting a JSON line
        assert!(MAGIC[0] >= 0x80);
    }
}
