//! The job coordinator: bounded queue, worker pool, job registry, and the
//! prepared-context LRU.
//!
//! The context cache is the serving-layer payoff of the
//! [`SearchContext`](crate::context::SearchContext) session API: jobs on
//! the same `(dataset, scale_div, SaxParams)` share one context, so the
//! series generation, rolling stats, SAX index, and any warm nnd profile
//! are paid once and every later job starts searching immediately. Each
//! job report carries `ctx_cache: "hit" | "miss"` plus the engine's
//! `prep_calls` so the reuse is observable end to end.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::algo;
use crate::config::{SaxParams, SearchParams};
use crate::context::SearchContext;
use crate::ts::{datasets, TimeSeries};
use crate::util::json::Json;

/// Contexts kept warm by the coordinator (per-process; each context holds
/// its series plus prepared state, so the cap bounds memory).
const CONTEXT_CACHE_CAPACITY: usize = 8;

/// A search job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Registry dataset name (or "synthetic:noise=E,n=N" forms).
    pub dataset: String,
    /// Length divisor applied to the registry's paper length.
    pub scale_div: usize,
    /// Algorithm name (see [`crate::algo::by_name`]).
    pub algo: String,
    /// Search parameters forwarded to the engine.
    pub params: SearchParams,
}

impl JobSpec {
    /// Parse a `submit` request (protocol documented in [`crate::service`]).
    pub fn from_json(v: &Json) -> Result<JobSpec, String> {
        let dataset = v
            .get("dataset")
            .and_then(|d| d.as_str())
            .ok_or("field `dataset` required")?
            .to_string();
        let algo = v
            .get("algo")
            .and_then(|d| d.as_str())
            .unwrap_or("hst")
            .to_string();
        let scale_div = v
            .get("scale_div")
            .and_then(|d| d.as_u64())
            .unwrap_or(1) as usize;
        let params = match v.get("params") {
            Some(p) => SearchParams::from_json(p)?,
            None => return Err("field `params` required".into()),
        };
        Ok(JobSpec {
            dataset,
            scale_div,
            algo,
            params,
        })
    }

    /// Materialize the requested series.
    pub fn series(&self) -> Result<TimeSeries> {
        if let Some(rest) = self.dataset.strip_prefix("synthetic:") {
            // synthetic:noise=0.1,n=20000,seed=4
            let mut noise = 0.1f64;
            let mut n = 20_000usize;
            let mut seed = 0u64;
            for kv in rest.split(',') {
                match kv.split_once('=') {
                    Some(("noise", v)) => noise = v.parse()?,
                    Some(("n", v)) => n = v.parse()?,
                    Some(("seed", v)) => seed = v.parse()?,
                    _ => bail!("bad synthetic spec field {kv:?}"),
                }
            }
            return Ok(crate::ts::series::IntoSeries::into_series(
                crate::ts::generators::sine_with_noise(n, noise, seed),
                &format!("synthetic(E={noise},n={n})"),
            ));
        }
        match datasets::by_name(&self.dataset) {
            Some(d) => Ok(d.generate_scaled(self.scale_div)),
            None => bail!("unknown dataset {:?}", self.dataset),
        }
    }
}

/// Lifecycle of a job.
#[derive(Debug, Clone)]
pub enum JobState {
    /// Accepted, waiting for a worker.
    Queued,
    /// A worker is executing the search.
    Running,
    /// Finished successfully; carries the report JSON.
    Done(Json),
    /// Finished with an error; carries the message.
    Failed(String),
}

impl JobState {
    /// Protocol label of this state (`queued`/`running`/`done`/`failed`).
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
        }
    }
}

/// Key of the coordinator's context LRU.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ContextKey {
    dataset: String,
    scale_div: usize,
    sax: SaxParams,
}

struct ContextCacheInner {
    tick: u64,
    map: HashMap<ContextKey, (Arc<SearchContext>, u64)>,
}

/// LRU of prepared [`SearchContext`]s shared by the worker pool.
struct ContextCache {
    capacity: usize,
    inner: Mutex<ContextCacheInner>,
}

impl ContextCache {
    fn new(capacity: usize) -> ContextCache {
        ContextCache {
            capacity: capacity.max(1),
            inner: Mutex::new(ContextCacheInner {
                tick: 0,
                map: HashMap::new(),
            }),
        }
    }

    /// The context for `spec`, building (series + empty caches) on a
    /// miss. Returns `(context, was_hit)`.
    fn get_or_build(&self, spec: &JobSpec) -> Result<(Arc<SearchContext>, bool)> {
        let key = ContextKey {
            dataset: spec.dataset.clone(),
            scale_div: spec.scale_div,
            sax: spec.params.sax,
        };
        {
            let mut g = self.inner.lock().unwrap();
            g.tick += 1;
            let tick = g.tick;
            if let Some(entry) = g.map.get_mut(&key) {
                entry.1 = tick;
                return Ok((Arc::clone(&entry.0), true));
            }
        }
        // Build outside the lock: series generation can be slow and must
        // not block workers hitting other keys.
        let ts = spec.series()?;
        let ctx = Arc::new(SearchContext::builder_owned(ts).build());
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(entry) = g.map.get_mut(&key) {
            // a racing worker built it first: share theirs (their context
            // may already be warm)
            entry.1 = tick;
            return Ok((Arc::clone(&entry.0), true));
        }
        g.map.insert(key, (Arc::clone(&ctx), tick));
        if g.map.len() > self.capacity {
            if let Some(evict) = g
                .map
                .iter()
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| k.clone())
            {
                g.map.remove(&evict);
            }
        }
        Ok((ctx, false))
    }
}

struct Inner {
    queue: VecDeque<(u64, JobSpec)>,
    jobs: HashMap<u64, JobState>,
    next_id: u64,
    shutdown: bool,
    running: usize,
}

/// Thread-pool coordinator with a bounded queue (backpressure: `submit`
/// rejects when full, so upstream callers must retry/slow down — the same
/// contract a production ingestion tier would expose) and a shared
/// prepared-context LRU.
pub struct Coordinator {
    inner: Arc<(Mutex<Inner>, Condvar)>,
    workers: Vec<JoinHandle<()>>,
    capacity: usize,
}

impl Coordinator {
    /// Start `n_workers` workers with a queue bound of `capacity`.
    pub fn start(n_workers: usize, capacity: usize) -> Coordinator {
        let inner = Arc::new((
            Mutex::new(Inner {
                queue: VecDeque::new(),
                jobs: HashMap::new(),
                next_id: 1,
                shutdown: false,
                running: 0,
            }),
            Condvar::new(),
        ));
        let cache = Arc::new(ContextCache::new(CONTEXT_CACHE_CAPACITY));
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let inner = Arc::clone(&inner);
                let cache = Arc::clone(&cache);
                std::thread::spawn(move || worker_loop(inner, cache))
            })
            .collect();
        Coordinator {
            inner,
            workers,
            capacity,
        }
    }

    /// Submit a job; returns its id, or an error when the queue is full
    /// (backpressure) or the coordinator is shutting down.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        let (lock, cvar) = &*self.inner;
        let mut g = lock.lock().unwrap();
        if g.shutdown {
            bail!("coordinator is shut down");
        }
        if g.queue.len() >= self.capacity {
            bail!("queue full ({} jobs): backpressure, retry later", self.capacity);
        }
        let id = g.next_id;
        g.next_id += 1;
        g.jobs.insert(id, JobState::Queued);
        g.queue.push_back((id, spec));
        cvar.notify_one();
        Ok(id)
    }

    /// Current state of a job.
    pub fn status(&self, id: u64) -> Option<JobState> {
        let (lock, _) = &*self.inner;
        lock.lock().unwrap().jobs.get(&id).cloned()
    }

    /// All job ids with their state labels.
    pub fn list(&self) -> Vec<(u64, String)> {
        let (lock, _) = &*self.inner;
        let g = lock.lock().unwrap();
        let mut v: Vec<(u64, String)> = g
            .jobs
            .iter()
            .map(|(&id, st)| (id, st.label().to_string()))
            .collect();
        v.sort_by_key(|&(id, _)| id);
        v
    }

    /// Block until job `id` leaves the queue/running states.
    pub fn wait(&self, id: u64) -> Option<JobState> {
        loop {
            match self.status(id) {
                Some(JobState::Queued) | Some(JobState::Running) => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                other => return other,
            }
        }
    }

    /// Drain the queue and stop the workers.
    pub fn shutdown(mut self) {
        let (lock, cvar) = &*self.inner;
        {
            let mut g = lock.lock().unwrap();
            g.shutdown = true;
            cvar.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(inner: Arc<(Mutex<Inner>, Condvar)>, cache: Arc<ContextCache>) {
    loop {
        let (id, spec) = {
            let (lock, cvar) = &*inner;
            let mut g = lock.lock().unwrap();
            loop {
                if let Some(job) = g.queue.pop_front() {
                    g.running += 1;
                    *g.jobs.get_mut(&job.0).unwrap() = JobState::Running;
                    break job;
                }
                if g.shutdown {
                    return;
                }
                g = cvar.wait(g).unwrap();
            }
        };
        let outcome = run_job(&spec, &cache);
        let (lock, _) = &*inner;
        let mut g = lock.lock().unwrap();
        g.running -= 1;
        *g.jobs.get_mut(&id).unwrap() = match outcome {
            Ok(report) => JobState::Done(report),
            Err(e) => JobState::Failed(format!("{e:#}")),
        };
    }
}

fn run_job(spec: &JobSpec, cache: &ContextCache) -> Result<Json> {
    let Some(engine) = algo::by_name(&spec.algo) else {
        bail!("unknown algorithm {:?}", spec.algo);
    };
    let (ctx, cache_hit) = cache.get_or_build(spec)?;
    let report = engine.run_ctx(&ctx, &spec.params)?;
    Ok(report
        .to_json()
        .set("dataset", spec.dataset.as_str())
        .set("n_points", ctx.series().n_total())
        .set("ctx_cache", if cache_hit { "hit" } else { "miss" }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_spec(algo: &str) -> JobSpec {
        JobSpec {
            dataset: "synthetic:noise=0.5,n=1500,seed=1".into(),
            scale_div: 1,
            algo: algo.into(),
            params: SearchParams::new(64, 4, 4),
        }
    }

    #[test]
    fn submits_runs_and_completes() {
        let c = Coordinator::start(2, 16);
        let id = c.submit(quick_spec("hst")).unwrap();
        match c.wait(id) {
            Some(JobState::Done(j)) => {
                assert_eq!(j.get("algo").unwrap().as_str(), Some("hst"));
                assert!(j.get("distance_calls").unwrap().as_u64().unwrap() > 0);
            }
            other => panic!("unexpected state {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn bad_algo_fails_cleanly() {
        let c = Coordinator::start(1, 4);
        let id = c.submit(quick_spec("not-an-algo")).unwrap();
        match c.wait(id) {
            Some(JobState::Failed(msg)) => assert!(msg.contains("unknown algorithm")),
            other => panic!("unexpected state {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let c = Coordinator::start(1, 1);
        // saturate: one running + one queued, then the next submit fails
        let _a = c.submit(quick_spec("hst")).unwrap();
        let mut rejected = false;
        for _ in 0..50 {
            if c.submit(quick_spec("hst")).is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "bounded queue must eventually reject");
        c.shutdown();
    }

    #[test]
    fn parallel_jobs_all_finish() {
        let c = Coordinator::start(4, 64);
        let ids: Vec<u64> = (0..8)
            .map(|i| {
                let mut s = quick_spec(if i % 2 == 0 { "hst" } else { "hotsax" });
                s.params = s.params.with_seed(i as u64);
                c.submit(s).unwrap()
            })
            .collect();
        for id in ids {
            match c.wait(id) {
                Some(JobState::Done(_)) => {}
                other => panic!("job {id}: {other:?}"),
            }
        }
        c.shutdown();
    }

    #[test]
    fn repeated_job_hits_the_context_cache() {
        let c = Coordinator::start(1, 8);
        let first = c.submit(quick_spec("hst")).unwrap();
        let first = match c.wait(first) {
            Some(JobState::Done(j)) => j,
            other => panic!("unexpected {other:?}"),
        };
        let second = c.submit(quick_spec("hst")).unwrap();
        let second = match c.wait(second) {
            Some(JobState::Done(j)) => j,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(first.get("ctx_cache").unwrap().as_str(), Some("miss"));
        assert_eq!(second.get("ctx_cache").unwrap().as_str(), Some("hit"));
        // the warm context serves the preparation: no prep calls at all
        let cold_prep = first.get("prep_calls").unwrap().as_u64().unwrap();
        let warm_prep = second.get("prep_calls").unwrap().as_u64().unwrap();
        assert!(cold_prep > 0, "cold job must pay preparation");
        assert_eq!(warm_prep, 0, "warm job must not re-prepare");
        // a different dataset key misses
        let mut other = quick_spec("hst");
        other.dataset = "synthetic:noise=0.5,n=1500,seed=2".into();
        let third = c.submit(other).unwrap();
        match c.wait(third) {
            Some(JobState::Done(j)) => {
                assert_eq!(j.get("ctx_cache").unwrap().as_str(), Some("miss"))
            }
            other => panic!("unexpected {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn unknown_dataset_fails() {
        let c = Coordinator::start(1, 4);
        let mut s = quick_spec("hst");
        s.dataset = "does-not-exist".into();
        let id = c.submit(s).unwrap();
        match c.wait(id) {
            Some(JobState::Failed(msg)) => assert!(msg.contains("unknown dataset")),
            other => panic!("unexpected {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn registry_dataset_scaled_runs() {
        let c = Coordinator::start(1, 4);
        let spec = JobSpec {
            dataset: "Shuttle TEK 14".into(),
            scale_div: 4,
            algo: "hst".into(),
            params: SearchParams::new(128, 4, 4),
        };
        let id = c.submit(spec).unwrap();
        match c.wait(id) {
            Some(JobState::Done(j)) => {
                assert!(j.get("n_sequences").unwrap().as_u64().unwrap() > 0)
            }
            other => panic!("unexpected {other:?}"),
        }
        c.shutdown();
    }
}
