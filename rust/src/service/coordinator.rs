//! The job coordinator: bounded queue, worker pool, job registry, and the
//! prepared-context LRU.
//!
//! The context cache is the serving-layer payoff of the
//! [`SearchContext`](crate::context::SearchContext) session API: jobs on
//! the same `(dataset, scale_div, SaxParams)` share one context, so the
//! series generation, rolling stats, SAX index, and any warm nnd profile
//! are paid once and every later job starts searching immediately. Each
//! job report carries `ctx_cache: "hit" | "miss"` plus the engine's
//! `prep_calls` so the reuse is observable end to end.

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Context as _, Result};

use crate::algo;
use crate::config::{SaxParams, SearchParams};
use crate::context::SearchContext;
use crate::mdim::{self, MdimAlgorithm as _, MdimContext, MdimParams};
use crate::obs::{Counter, Registry, LATENCY_BUCKETS_MS, SIZE_BUCKETS};
use crate::snapshot::{self, store, ContextSnapshot, ProfileEntry};
use crate::stream::StreamingMonitor;
use crate::ts::{datasets, MultiSeries, TimeSeries};
use crate::util::json::Json;

use super::streams::{StreamRegistry, STREAM_REGISTRY_CAPACITY};

/// Default contexts kept warm by the coordinator (per-process; each
/// context holds its series plus prepared state, so the cap bounds
/// memory). `hst serve --ctx-cache` raises it per process.
pub const CONTEXT_CACHE_CAPACITY: usize = 8;

/// Upper bound on the total points (`n × channels`) a network-supplied
/// `synthetic-md:` spec may ask the service to materialize (~80 MB of
/// f64s before prepared state) — the same one-request-can't-abort-the-
/// server invariant `MAX_STREAM_WINDOW` enforces for `stream_open`.
pub const MAX_MDIM_SYNTHETIC_POINTS: usize = 10_000_000;

/// A search job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Registry dataset name (or "synthetic:noise=E,n=N" forms).
    pub dataset: String,
    /// Length divisor applied to the registry's paper length.
    pub scale_div: usize,
    /// Algorithm name (see [`crate::algo::by_name`]).
    pub algo: String,
    /// Search parameters forwarded to the engine.
    pub params: SearchParams,
}

impl JobSpec {
    /// Top-level request fields [`from_json`](Self::from_json) accepts.
    pub const JSON_FIELDS: [&'static str; 6] =
        ["cmd", "dataset", "scale_div", "algo", "params", "threads"];

    /// Parse a `submit` request (protocol documented in [`crate::service`]).
    ///
    /// Unknown fields — at the job level and inside `params` — are
    /// rejected with the offending name: a typo'd field must fail the
    /// request, not silently search a different series.
    pub fn from_json(v: &Json) -> Result<JobSpec, String> {
        if let Json::Obj(map) = v {
            if let Some(bad) =
                map.keys().find(|k| !Self::JSON_FIELDS.contains(&k.as_str()))
            {
                return Err(format!(
                    "unknown field `{bad}` in job (known: {})",
                    Self::JSON_FIELDS.join(", ")
                ));
            }
        } else {
            return Err("job must be a JSON object".into());
        }
        let dataset = v
            .get("dataset")
            .and_then(|d| d.as_str())
            .ok_or("field `dataset` required")?
            .to_string();
        let algo = v
            .get("algo")
            .and_then(|d| d.as_str())
            .unwrap_or("hst")
            .to_string();
        let scale_div = match v.get("scale_div") {
            None => 1,
            Some(d) => d
                .as_u64()
                .ok_or("field `scale_div` must be an integer")?
                as usize,
        };
        let mut params = match v.get("params") {
            Some(p) => SearchParams::from_json(p)?,
            None => return Err("field `params` required".into()),
        };
        // per-job thread override: a top-level `threads` applies when the
        // params object did not set one itself
        if let Some(t) = v.get("threads") {
            let t = t.as_u64().ok_or("field `threads` must be an integer")?;
            if params.threads == 0 {
                params.threads = t as usize;
            }
        }
        Ok(JobSpec {
            dataset,
            scale_div,
            algo,
            params,
        })
    }

    /// Materialize the requested series.
    ///
    /// Synthetic specs (`synthetic:noise=0.1,n=20000,seed=4`) are parsed
    /// strictly: an unknown key, a pair without `=`, or an unparsable
    /// value fails with the field named, so a malformed spec can never
    /// fall back to defaults and search the wrong series.
    pub fn series(&self) -> Result<TimeSeries> {
        if let Some(rest) = self.dataset.strip_prefix("synthetic:") {
            let mut noise = 0.1f64;
            let mut n = 20_000usize;
            let mut seed = 0u64;
            for kv in rest.split(',') {
                let Some((key, val)) = kv.split_once('=') else {
                    bail!(
                        "malformed `key=value` pair {kv:?} in synthetic \
                         spec {:?}",
                        self.dataset
                    );
                };
                match key {
                    "noise" => {
                        noise = val.parse().map_err(|e| {
                            anyhow::anyhow!(
                                "synthetic field `noise`={val:?}: {e}"
                            )
                        })?
                    }
                    "n" => {
                        n = val.parse().map_err(|e| {
                            anyhow::anyhow!("synthetic field `n`={val:?}: {e}")
                        })?
                    }
                    "seed" => {
                        seed = val.parse().map_err(|e| {
                            anyhow::anyhow!(
                                "synthetic field `seed`={val:?}: {e}"
                            )
                        })?
                    }
                    other => bail!(
                        "unknown synthetic field `{other}` (known: noise, \
                         n, seed)"
                    ),
                }
            }
            return Ok(crate::ts::series::IntoSeries::into_series(
                crate::ts::generators::sine_with_noise(n, noise, seed),
                &format!("synthetic(E={noise},n={n})"),
            ));
        }
        match datasets::by_name(&self.dataset) {
            Some(d) => Ok(d.generate_scaled(self.scale_div)),
            None => bail!("unknown dataset {:?}", self.dataset),
        }
    }
}

/// A variable-length scan job (the `vl` protocol command): the `hst-vl`
/// engine over a dataset, reporting per-length rows plus the
/// length-normalized cross-length ranking instead of a flat report.
#[derive(Debug, Clone)]
pub struct VlJobSpec {
    /// Registry dataset name (or `synthetic:` forms — same grammar as
    /// [`JobSpec`]).
    pub dataset: String,
    /// Length divisor applied to the registry's paper length.
    pub scale_div: usize,
    /// Search parameters; the scanned range rides in as
    /// `s_min`/`s_max`/`s_step` (absent → derived around `s`).
    pub params: SearchParams,
}

impl VlJobSpec {
    /// Top-level request fields [`from_json`](Self::from_json) accepts.
    /// No `algo`: the `vl` command *is* the `hst-vl` engine (merlin's
    /// registry face stays reachable through plain `submit`).
    pub const JSON_FIELDS: [&'static str; 5] =
        ["cmd", "dataset", "scale_div", "params", "threads"];

    /// Parse a `vl` request; unknown fields — top level or inside
    /// `params` — are rejected by name, as everywhere.
    pub fn from_json(v: &Json) -> Result<VlJobSpec, String> {
        if let Json::Obj(map) = v {
            if let Some(bad) =
                map.keys().find(|k| !Self::JSON_FIELDS.contains(&k.as_str()))
            {
                return Err(format!(
                    "unknown field `{bad}` in vl job (known: {})",
                    Self::JSON_FIELDS.join(", ")
                ));
            }
        } else {
            return Err("vl job must be a JSON object".into());
        }
        let dataset = v
            .get("dataset")
            .and_then(|d| d.as_str())
            .ok_or("field `dataset` required")?
            .to_string();
        let scale_div = match v.get("scale_div") {
            None => 1,
            Some(d) => d
                .as_u64()
                .ok_or("field `scale_div` must be an integer")?
                as usize,
        };
        let mut params = match v.get("params") {
            Some(p) => SearchParams::from_json(p)?,
            None => return Err("field `params` required".into()),
        };
        if let Some(t) = v.get("threads") {
            let t = t.as_u64().ok_or("field `threads` must be an integer")?;
            if params.threads == 0 {
                params.threads = t as usize;
            }
        }
        Ok(VlJobSpec {
            dataset,
            scale_div,
            params,
        })
    }
}

/// A multivariate search job (the `mdim` protocol command).
#[derive(Debug, Clone)]
pub struct MdimJobSpec {
    /// Multivariate dataset spec: `synthetic-md:…` or `file:<path>`
    /// (see [`series`](Self::series)).
    pub dataset: String,
    /// Multivariate algorithm name (see [`crate::mdim::by_name`]).
    pub algo: String,
    /// Search parameters (channel selection included) forwarded to the
    /// engine.
    pub params: MdimParams,
}

impl MdimJobSpec {
    /// Top-level request fields [`from_json`](Self::from_json) accepts.
    pub const JSON_FIELDS: [&'static str; 5] =
        ["cmd", "dataset", "algo", "params", "threads"];

    /// Parse an `mdim` request. The `params` object is the shared one
    /// plus an optional `channels` array of names; unknown fields — top
    /// level or inside `params` — are rejected by name, as everywhere.
    pub fn from_json(v: &Json) -> Result<MdimJobSpec, String> {
        if let Json::Obj(map) = v {
            if let Some(bad) =
                map.keys().find(|k| !Self::JSON_FIELDS.contains(&k.as_str()))
            {
                return Err(format!(
                    "unknown field `{bad}` in mdim job (known: {})",
                    Self::JSON_FIELDS.join(", ")
                ));
            }
        } else {
            return Err("mdim job must be a JSON object".into());
        }
        let dataset = v
            .get("dataset")
            .and_then(|d| d.as_str())
            .ok_or("field `dataset` required")?
            .to_string();
        let algo = v
            .get("algo")
            .and_then(|d| d.as_str())
            .unwrap_or("hst-md")
            .to_string();
        let mut params = match v.get("params") {
            Some(p) => MdimParams::from_json(p)?,
            None => return Err("field `params` required".into()),
        };
        // same job-level `threads` shorthand as univariate submits
        if let Some(t) = v.get("threads") {
            let t = t.as_u64().ok_or("field `threads` must be an integer")?;
            if params.base.threads == 0 {
                params.base.threads = t as usize;
            }
        }
        Ok(MdimJobSpec {
            dataset,
            algo,
            params,
        })
    }

    /// Materialize the requested multivariate series. Two dataset forms,
    /// both parsed strictly (named-field errors, like
    /// [`JobSpec::series`]):
    ///
    /// * `synthetic-md:channels=3,n=8000,len=128,seed=4` — the
    ///   [`correlated_channels`](crate::ts::generators::correlated_channels)
    ///   generator (`len` is the anomaly length; every key optional);
    /// * `file:<path>` — a delimited multi-column file via
    ///   [`ts::io::load_multi_csv`](crate::ts::io::load_multi_csv). The
    ///   path is read server-side and **must resolve inside the service
    ///   process's working directory**: even behind a trusted ingestion
    ///   tier, a network-supplied path must not be able to read (and,
    ///   through loader error messages, echo) arbitrary server files.
    pub fn series(&self) -> Result<MultiSeries> {
        if let Some(rest) = self.dataset.strip_prefix("synthetic-md:") {
            let mut channels = 3usize;
            let mut n = 8_000usize;
            let mut len = 128usize;
            let mut seed = 0u64;
            for kv in rest.split(',').filter(|kv| !kv.is_empty()) {
                let Some((key, val)) = kv.split_once('=') else {
                    bail!(
                        "malformed `key=value` pair {kv:?} in synthetic-md \
                         spec {:?}",
                        self.dataset
                    );
                };
                let parse_usize = |field: &str, val: &str| -> Result<usize> {
                    val.parse().map_err(|e| {
                        anyhow::anyhow!(
                            "synthetic-md field `{field}`={val:?}: {e}"
                        )
                    })
                };
                match key {
                    "channels" => channels = parse_usize("channels", val)?,
                    "n" => n = parse_usize("n", val)?,
                    "len" => len = parse_usize("len", val)?,
                    "seed" => {
                        seed = val.parse().map_err(|e| {
                            anyhow::anyhow!(
                                "synthetic-md field `seed`={val:?}: {e}"
                            )
                        })?
                    }
                    other => bail!(
                        "unknown synthetic-md field `{other}` (known: \
                         channels, n, len, seed)"
                    ),
                }
            }
            let total = n.checked_mul(channels.max(1));
            match total {
                Some(t) if t <= MAX_MDIM_SYNTHETIC_POINTS => {}
                _ => bail!(
                    "synthetic-md spec asks for n={n} × channels={channels} \
                     points, above the per-request cap of \
                     {MAX_MDIM_SYNTHETIC_POINTS} — a network request must \
                     not drive an unbounded allocation"
                ),
            }
            return Ok(crate::ts::generators::correlated_channels(
                n, channels, len, seed,
            ));
        }
        if let Some(path) = self.dataset.strip_prefix("file:") {
            let resolved = std::path::Path::new(path)
                .canonicalize()
                .map_err(|e| anyhow::anyhow!("file dataset {path:?}: {e}"))?;
            let root = std::env::current_dir()?.canonicalize()?;
            anyhow::ensure!(
                resolved.starts_with(&root),
                "file dataset {path:?} resolves outside the service \
                 working directory {}",
                root.display()
            );
            return crate::ts::io::load_multi_csv(&resolved);
        }
        bail!(
            "unknown multivariate dataset {:?} (expected `synthetic-md:…` \
             or `file:<path>`)",
            self.dataset
        )
    }
}

/// A queued unit of work: a univariate search, a multivariate one, or a
/// variable-length scan.
#[derive(Debug, Clone)]
enum Job {
    Search(JobSpec),
    Mdim(MdimJobSpec),
    Vl(VlJobSpec),
}

/// Lifecycle of a job.
#[derive(Debug, Clone)]
pub enum JobState {
    /// Accepted, waiting for a worker.
    Queued,
    /// A worker is executing the search.
    Running,
    /// Finished successfully; carries the report JSON.
    Done(Json),
    /// Finished with an error; carries the message.
    Failed(String),
}

impl JobState {
    /// Protocol label of this state (`queued`/`running`/`done`/`failed`).
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
        }
    }
}

/// Key of the coordinator's context LRU.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ContextKey {
    dataset: String,
    scale_div: usize,
    sax: SaxParams,
}

struct ContextCacheInner {
    tick: u64,
    map: HashMap<ContextKey, (Arc<SearchContext>, u64)>,
}

/// LRU of prepared [`SearchContext`]s shared by the worker pool.
struct ContextCache {
    capacity: usize,
    inner: Mutex<ContextCacheInner>,
}

impl ContextCache {
    fn new(capacity: usize) -> ContextCache {
        ContextCache {
            capacity: capacity.max(1),
            inner: Mutex::new(ContextCacheInner {
                tick: 0,
                map: HashMap::new(),
            }),
        }
    }

    /// Number of contexts currently cached (observability).
    fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// The context for `spec`, building (series + empty caches) on a
    /// miss. Returns `(context, was_hit)`.
    fn get_or_build(&self, spec: &JobSpec) -> Result<(Arc<SearchContext>, bool)> {
        let key = ContextKey {
            dataset: spec.dataset.clone(),
            scale_div: spec.scale_div,
            sax: spec.params.sax,
        };
        {
            let mut g = self.inner.lock().unwrap();
            g.tick += 1;
            let tick = g.tick;
            if let Some(entry) = g.map.get_mut(&key) {
                entry.1 = tick;
                return Ok((Arc::clone(&entry.0), true));
            }
        }
        // Build outside the lock: series generation can be slow and must
        // not block workers hitting other keys.
        let ts = spec.series()?;
        let ctx = Arc::new(SearchContext::builder_owned(ts).build());
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(entry) = g.map.get_mut(&key) {
            // a racing worker built it first: share theirs (their context
            // may already be warm)
            entry.1 = tick;
            return Ok((Arc::clone(&entry.0), true));
        }
        g.map.insert(key, (Arc::clone(&ctx), tick));
        if g.map.len() > self.capacity {
            if let Some(evict) = g
                .map
                .iter()
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| k.clone())
            {
                g.map.remove(&evict);
            }
        }
        Ok((ctx, false))
    }

    /// Every cached context with its key, sorted by key so snapshot
    /// save order (and the files it writes) is deterministic.
    fn entries(&self) -> Vec<(ContextKey, Arc<SearchContext>)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<(ContextKey, Arc<SearchContext>)> = g
            .map
            .iter()
            .map(|(k, (ctx, _))| (k.clone(), Arc::clone(ctx)))
            .collect();
        v.sort_by(|(a, _), (b, _)| {
            (a.dataset.as_str(), a.scale_div, a.sax.s, a.sax.p, a.sax.alphabet)
                .cmp(&(
                    b.dataset.as_str(),
                    b.scale_div,
                    b.sax.s,
                    b.sax.p,
                    b.sax.alphabet,
                ))
        });
        v
    }

    /// Seed a restored context, under the same LRU discipline as a
    /// miss in [`get_or_build`](Self::get_or_build). A context already
    /// cached under this key is left in place — the live one may be
    /// warmer than the snapshot.
    fn seed(&self, key: ContextKey, ctx: Arc<SearchContext>) -> bool {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if g.map.contains_key(&key) {
            return false;
        }
        g.map.insert(key, (ctx, tick));
        if g.map.len() > self.capacity {
            if let Some(evict) = g
                .map
                .iter()
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| k.clone())
            {
                g.map.remove(&evict);
            }
        }
        true
    }
}

struct Inner {
    queue: VecDeque<(u64, Job)>,
    jobs: HashMap<u64, JobState>,
    next_id: u64,
    shutdown: bool,
    running: usize,
}

/// A point-in-time snapshot of the coordinator's shape (the `stats`
/// protocol command).
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorStats {
    /// Jobs waiting for a worker.
    pub queued: usize,
    /// Jobs currently executing.
    pub running: usize,
    /// Worker threads in the pool.
    pub workers: usize,
    /// Total jobs ever accepted (any state).
    pub jobs_total: usize,
    /// Queue bound (backpressure threshold).
    pub queue_capacity: usize,
    /// Prepared contexts currently held by the LRU.
    pub ctx_cache_entries: usize,
    /// Streaming monitors currently open (the `stream_open` command).
    pub streams: usize,
    /// Completed `snapshot_save` operations (boot-shutdown saves
    /// included).
    pub snapshot_saves: u64,
    /// Completed `snapshot_restore` operations.
    pub snapshot_restores: u64,
    /// Contexts seeded into the LRU by restores.
    pub snapshot_contexts_restored: u64,
    /// Stream monitors re-installed by restores.
    pub snapshot_streams_restored: u64,
    /// Warm nnd profiles seeded into restored contexts.
    pub snapshot_profiles_seeded: u64,
}

/// Every metric name the service records into (or syncs at exposition
/// into) the coordinator's [`Registry`] — the `metrics` protocol
/// command exposes exactly these. `docs/OBSERVABILITY.md`'s metric
/// table is pinned to this list by `tests/docs_consistency.rs`.
pub const SERVICE_METRIC_NAMES: [&str; 16] = [
    // counters (worker pool)
    "hst_jobs_completed_total",
    "hst_jobs_failed_total",
    // per-engine histograms, recorded on every finished job
    "hst_job_latency_ms",
    "hst_job_cps",
    // counters (snapshot subsystem; the `stats` fields are views of these)
    "hst_snapshot_saves_total",
    "hst_snapshot_restores_total",
    "hst_snapshot_contexts_restored_total",
    "hst_snapshot_streams_restored_total",
    "hst_snapshot_profiles_seeded_total",
    // counters absorbed from the stream registry's ingest atomics
    "hst_stream_frames_rx_total",
    "hst_stream_points_rx_total",
    "hst_stream_frames_shed_total",
    // gauges synced from live state at exposition time
    "hst_jobs_queued",
    "hst_jobs_running",
    "hst_ctx_cache_entries",
    "hst_streams_open",
];

/// Monotonic counters behind the `stats` snapshot fields — registry
/// [`Counter`] handles, so `stats` and `metrics` report the same
/// values from the same cells.
struct SnapshotCounters {
    saves: Arc<Counter>,
    restores: Arc<Counter>,
    contexts_restored: Arc<Counter>,
    streams_restored: Arc<Counter>,
    profiles_seeded: Arc<Counter>,
}

impl SnapshotCounters {
    fn new(obs: &Registry) -> SnapshotCounters {
        SnapshotCounters {
            saves: obs.counter("hst_snapshot_saves_total"),
            restores: obs.counter("hst_snapshot_restores_total"),
            contexts_restored: obs.counter("hst_snapshot_contexts_restored_total"),
            streams_restored: obs.counter("hst_snapshot_streams_restored_total"),
            profiles_seeded: obs.counter("hst_snapshot_profiles_seeded_total"),
        }
    }
}

/// What one [`Coordinator::snapshot_save`] wrote.
#[derive(Debug, Clone)]
pub struct SnapshotSaveReport {
    /// The directory written into.
    pub dir: PathBuf,
    /// Context snapshots written.
    pub contexts: usize,
    /// Monitor snapshots written.
    pub monitors: usize,
    /// Cached contexts skipped because they held no warm profile yet
    /// (nothing a restore could reuse).
    pub skipped: usize,
    /// File names written, in write order.
    pub files: Vec<String>,
}

impl SnapshotSaveReport {
    /// Serialize for the service protocol (`docs/PROTOCOL.md`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("dir", self.dir.display().to_string())
            .set("contexts", self.contexts as u64)
            .set("monitors", self.monitors as u64)
            .set("skipped", self.skipped as u64)
            .set(
                "files",
                self.files
                    .iter()
                    .map(|f| Json::Str(f.clone()))
                    .collect::<Vec<_>>(),
            )
    }
}

/// What one [`Coordinator::snapshot_restore`] brought back.
#[derive(Debug, Clone)]
pub struct SnapshotRestoreReport {
    /// The directory read from.
    pub dir: PathBuf,
    /// Contexts seeded into the LRU.
    pub contexts: usize,
    /// Stream monitors re-installed.
    pub monitors: usize,
    /// Warm nnd profiles seeded across those contexts.
    pub profiles: usize,
    /// Snapshots skipped because live state already owned their key
    /// (context cached / stream open) — the live state may be warmer.
    pub skipped: usize,
    /// File names restored, in read order.
    pub files: Vec<String>,
}

impl SnapshotRestoreReport {
    /// Serialize for the service protocol (`docs/PROTOCOL.md`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("dir", self.dir.display().to_string())
            .set("contexts", self.contexts as u64)
            .set("monitors", self.monitors as u64)
            .set("profiles", self.profiles as u64)
            .set("skipped", self.skipped as u64)
            .set(
                "files",
                self.files
                    .iter()
                    .map(|f| Json::Str(f.clone()))
                    .collect::<Vec<_>>(),
            )
    }
}

/// Sizing knobs for [`Coordinator::start_config`]. Defaults reproduce
/// the historical `start(n_workers, capacity)` shape; `hst serve` maps
/// its `--max-streams` / `--ctx-cache` / `--stream-workers` flags here.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Search worker threads (0 = auto via
    /// [`ExecPolicy`](crate::exec::ExecPolicy)).
    pub workers: usize,
    /// Job queue bound (backpressure threshold).
    pub capacity: usize,
    /// Stream registry cap (must be ≥ 1; see
    /// [`STREAM_REGISTRY_CAPACITY`]).
    pub max_streams: usize,
    /// Prepared-context LRU size (must be ≥ 1; see
    /// [`CONTEXT_CACHE_CAPACITY`]).
    pub ctx_cache: usize,
    /// Stream drain workers servicing binary-frame queues and offloaded
    /// JSON appends (0 = inline mode, no binary draining).
    pub stream_workers: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            workers: 0,
            capacity: 64,
            max_streams: STREAM_REGISTRY_CAPACITY,
            ctx_cache: CONTEXT_CACHE_CAPACITY,
            stream_workers: super::streams::DEFAULT_STREAM_WORKERS,
        }
    }
}

/// Thread-pool coordinator with a bounded queue (backpressure: `submit`
/// rejects when full, so upstream callers must retry/slow down — the same
/// contract a production ingestion tier would expose) and a shared
/// prepared-context LRU.
pub struct Coordinator {
    inner: Arc<(Mutex<Inner>, Condvar)>,
    workers: Vec<JoinHandle<()>>,
    cache: Arc<ContextCache>,
    capacity: usize,
    streams: StreamRegistry,
    snaps: SnapshotCounters,
    obs: Arc<Registry>,
}

impl Coordinator {
    /// Start `n_workers` workers with a queue bound of `capacity` and
    /// every other knob at its default. `n_workers == 0` sizes the pool
    /// through the shared [`ExecPolicy`](crate::exec::ExecPolicy)
    /// resolution (`HST_THREADS`, then available parallelism) —
    /// zero-means-auto is normalized in `ExecPolicy` itself, not
    /// re-implemented here.
    pub fn start(n_workers: usize, capacity: usize) -> Coordinator {
        Coordinator::start_config(CoordinatorConfig {
            workers: n_workers,
            capacity,
            stream_workers: 0,
            ..CoordinatorConfig::default()
        })
    }

    /// Start with explicit sizing (see [`CoordinatorConfig`]).
    /// `max_streams` / `ctx_cache` of 0 are clamped to 1 here; the CLI
    /// rejects 0 with a named error before this runs.
    pub fn start_config(cfg: CoordinatorConfig) -> Coordinator {
        let n_workers = crate::exec::ExecPolicy::new(cfg.workers).resolve();
        let inner = Arc::new((
            Mutex::new(Inner {
                queue: VecDeque::new(),
                jobs: HashMap::new(),
                next_id: 1,
                shutdown: false,
                running: 0,
            }),
            Condvar::new(),
        ));
        let cache = Arc::new(ContextCache::new(cfg.ctx_cache.max(1)));
        let obs = Arc::new(Registry::new());
        let workers = (0..n_workers)
            .map(|_| {
                let inner = Arc::clone(&inner);
                let cache = Arc::clone(&cache);
                let obs = Arc::clone(&obs);
                std::thread::spawn(move || worker_loop(inner, cache, obs))
            })
            .collect();
        let streams = StreamRegistry::new(cfg.max_streams);
        if cfg.stream_workers > 0 {
            streams.start_workers(cfg.stream_workers);
        }
        Coordinator {
            inner,
            workers,
            cache,
            capacity: cfg.capacity,
            streams,
            snaps: SnapshotCounters::new(&obs),
            obs,
        }
    }

    /// The coordinator's metrics registry (the `metrics` protocol
    /// command). Workers record per-engine job latency and cps
    /// histograms here; the snapshot counters live here too.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.obs
    }

    /// Sync the point-in-time gauges (queue depth, running jobs,
    /// context cache, open streams) and absorb the stream registry's
    /// ingest counters into the registry, then return it for
    /// snapshotting — the one call behind every `metrics` reply.
    pub fn sync_registry(&self) -> &Arc<Registry> {
        let st = self.stats();
        self.obs.gauge("hst_jobs_queued").set(st.queued as u64);
        self.obs.gauge("hst_jobs_running").set(st.running as u64);
        self.obs
            .gauge("hst_ctx_cache_entries")
            .set(st.ctx_cache_entries as u64);
        self.obs.gauge("hst_streams_open").set(st.streams as u64);
        let ingest = self.streams.ingest_stats();
        // record_absolute: the stream registry keeps its own monotonic
        // atomics; absorbing by max never moves a counter backwards.
        self.obs
            .counter("hst_stream_frames_rx_total")
            .record_absolute(ingest.frames_rx);
        self.obs
            .counter("hst_stream_points_rx_total")
            .record_absolute(ingest.points_rx);
        self.obs
            .counter("hst_stream_frames_shed_total")
            .record_absolute(ingest.frames_shed);
        &self.obs
    }

    /// The per-stream monitor registry (the `stream_open` / `append` /
    /// `subscribe` / `stream_close` protocol commands; see
    /// `docs/PROTOCOL.md`). Lives alongside the context LRU so streaming
    /// state shares the coordinator's lifetime and observability.
    pub fn streams(&self) -> &StreamRegistry {
        &self.streams
    }

    /// Submit a job; returns its id, or an error when the queue is full
    /// (backpressure) or the coordinator is shutting down.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        Ok(self.submit_batch(vec![spec])?[0])
    }

    /// Submit a multivariate search job (the `mdim` protocol command).
    /// Shares the queue, worker pool, backpressure bound, and job
    /// registry with univariate jobs — `status`/`wait`/`list` work
    /// unchanged on the returned id.
    pub fn submit_mdim(&self, spec: MdimJobSpec) -> Result<u64> {
        Ok(self.enqueue(vec![Job::Mdim(spec)])?[0])
    }

    /// Submit a variable-length scan job (the `vl` protocol command).
    /// Same shared queue/pool/registry; the context LRU is keyed on
    /// `(dataset, scale_div, sax)` exactly like `submit`, so a `vl` scan
    /// warms the cache for later single-length jobs and vice versa.
    pub fn submit_vl(&self, spec: VlJobSpec) -> Result<u64> {
        Ok(self.enqueue(vec![Job::Vl(spec)])?[0])
    }

    /// Submit a batch atomically: either the queue has room for *all*
    /// jobs (ids returned, in order) or none are enqueued. Batched jobs
    /// share the prepared-context LRU with everything else, so a batch
    /// over one dataset pays its preparation once.
    pub fn submit_batch(&self, specs: Vec<JobSpec>) -> Result<Vec<u64>> {
        self.enqueue(specs.into_iter().map(Job::Search).collect())
    }

    /// The one enqueue path every submit flavor funnels through.
    fn enqueue(&self, specs: Vec<Job>) -> Result<Vec<u64>> {
        if specs.is_empty() {
            bail!("empty batch");
        }
        let (lock, cvar) = &*self.inner;
        let mut g = lock.lock().unwrap();
        if g.shutdown {
            bail!("coordinator is shut down");
        }
        if g.queue.len() + specs.len() > self.capacity {
            bail!(
                "queue cannot hold {} more jobs ({}/{} used): backpressure, \
                 retry later",
                specs.len(),
                g.queue.len(),
                self.capacity
            );
        }
        let mut ids = Vec::with_capacity(specs.len());
        for spec in specs {
            let id = g.next_id;
            g.next_id += 1;
            g.jobs.insert(id, JobState::Queued);
            g.queue.push_back((id, spec));
            ids.push(id);
        }
        cvar.notify_all();
        Ok(ids)
    }

    /// Snapshot of the coordinator's current shape.
    pub fn stats(&self) -> CoordinatorStats {
        let (lock, _) = &*self.inner;
        let g = lock.lock().unwrap();
        CoordinatorStats {
            queued: g.queue.len(),
            running: g.running,
            workers: self.workers.len(),
            jobs_total: g.jobs.len(),
            queue_capacity: self.capacity,
            ctx_cache_entries: self.cache.len(),
            streams: self.streams.len(),
            // views over the obs registry: the `stats` reply and the
            // `metrics` exposition read the same counter cells
            snapshot_saves: self.snaps.saves.get(),
            snapshot_restores: self.snaps.restores.get(),
            snapshot_contexts_restored: self.snaps.contexts_restored.get(),
            snapshot_streams_restored: self.snaps.streams_restored.get(),
            snapshot_profiles_seeded: self.snaps.profiles_seeded.get(),
        }
    }

    /// Persist every warm context profile and every open stream monitor
    /// into `dir` (created if missing), one `.hsts` file each (see
    /// [`crate::snapshot`]). Deterministic: keys are sorted, encodings
    /// are canonical, so the same warm state writes the same bytes.
    /// Contexts with no warm profile are skipped — a restore could reuse
    /// nothing from them.
    pub fn snapshot_save(&self, dir: &Path) -> Result<SnapshotSaveReport> {
        std::fs::create_dir_all(dir).with_context(|| {
            format!("creating snapshot directory {}", dir.display())
        })?;
        let mut report = SnapshotSaveReport {
            dir: dir.to_path_buf(),
            contexts: 0,
            monitors: 0,
            skipped: 0,
            files: Vec::new(),
        };
        for (key, ctx) in self.cache.entries() {
            let profiles: Vec<ProfileEntry> = ctx
                .warm_profiles()
                .into_iter()
                .map(|(s, kind, allow_self_match, profile)| ProfileEntry {
                    s,
                    kind,
                    allow_self_match,
                    profile,
                })
                .collect();
            if profiles.is_empty() {
                report.skipped += 1;
                continue;
            }
            let snap = ContextSnapshot {
                dataset: key.dataset.clone(),
                scale_div: key.scale_div as u64,
                sax: key.sax,
                fingerprint: snapshot::SeriesFingerprint::of(
                    &ctx.series().points,
                ),
                profiles,
            };
            let name = store::context_file_name(
                &key.dataset,
                key.scale_div as u64,
                key.sax.s,
                key.sax.p,
                key.sax.alphabet,
            );
            let path = dir.join(&name);
            std::fs::write(&path, snapshot::encode_context(&snap))
                .with_context(|| format!("writing {}", path.display()))?;
            report.contexts += 1;
            report.files.push(name);
        }
        for snap in self.streams.export_monitors() {
            let name = store::monitor_file_name(&snap.name);
            let path = dir.join(&name);
            std::fs::write(&path, snapshot::encode_monitor(&snap))
                .with_context(|| format!("writing {}", path.display()))?;
            report.monitors += 1;
            report.files.push(name);
        }
        self.snaps.saves.inc();
        Ok(report)
    }

    /// Restore every `.hsts` file in `dir` — contexts into the LRU
    /// (series regenerated from the key, fingerprint-checked, warm
    /// profiles seeded via
    /// [`store_warm_profile`](SearchContext::store_warm_profile)),
    /// monitors into the stream registry under `stream_open`'s bounds.
    /// Strict: a file that fails to decode, a fingerprint that does not
    /// match the regenerated series, or a monitor the registry refuses
    /// fails the whole restore with the file named — corruption must
    /// never silently warm a context with wrong state. Snapshots whose
    /// key is already live (context cached, stream open) are skipped and
    /// counted: the live state may be warmer than the file.
    pub fn snapshot_restore(&self, dir: &Path) -> Result<SnapshotRestoreReport> {
        let mut report = SnapshotRestoreReport {
            dir: dir.to_path_buf(),
            contexts: 0,
            monitors: 0,
            profiles: 0,
            skipped: 0,
            files: Vec::new(),
        };
        for path in store::list_dir(dir).with_context(|| {
            format!("listing snapshot directory {}", dir.display())
        })? {
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let snap = store::decode(&bytes).map_err(|e| {
                anyhow::anyhow!("snapshot {}: {e}", path.display())
            })?;
            let file = path
                .file_name()
                .and_then(|n| n.to_str())
                .unwrap_or("?")
                .to_string();
            match snap {
                store::Snapshot::Context(c) => {
                    let spec = JobSpec {
                        dataset: c.dataset.clone(),
                        scale_div: c.scale_div as usize,
                        algo: String::new(),
                        params: SearchParams::new(
                            c.sax.s,
                            c.sax.p,
                            c.sax.alphabet,
                        ),
                    };
                    let ts = spec.series().with_context(|| {
                        format!("snapshot {}: regenerating series", file)
                    })?;
                    c.check_series(&ts.points).map_err(|e| {
                        anyhow::anyhow!("snapshot {file}: {e}")
                    })?;
                    let ctx = Arc::new(SearchContext::builder_owned(ts).build());
                    for e in &c.profiles {
                        ctx.store_warm_profile(
                            e.s,
                            e.kind,
                            e.allow_self_match,
                            e.profile.clone(),
                        );
                    }
                    let seeded = self.cache.seed(
                        ContextKey {
                            dataset: c.dataset,
                            scale_div: c.scale_div as usize,
                            sax: c.sax,
                        },
                        ctx,
                    );
                    if seeded {
                        report.contexts += 1;
                        report.profiles += c.profiles.len();
                        report.files.push(file);
                        self.snaps.contexts_restored.inc();
                        self.snaps
                            .profiles_seeded
                            .add(c.profiles.len() as u64);
                    } else {
                        report.skipped += 1;
                    }
                }
                store::Snapshot::Monitor(m) => {
                    if self.streams.stream_id(&m.name).is_some() {
                        report.skipped += 1;
                        continue;
                    }
                    let mon =
                        StreamingMonitor::from_snapshot(m).map_err(|e| {
                            anyhow::anyhow!("snapshot {file}: {e}")
                        })?;
                    self.streams.install(mon).with_context(|| {
                        format!("snapshot {file}: reopening stream")
                    })?;
                    report.monitors += 1;
                    report.files.push(file);
                    self.snaps.streams_restored.inc();
                }
            }
        }
        self.snaps.restores.inc();
        Ok(report)
    }

    /// Current state of a job.
    pub fn status(&self, id: u64) -> Option<JobState> {
        let (lock, _) = &*self.inner;
        lock.lock().unwrap().jobs.get(&id).cloned()
    }

    /// All job ids with their state labels.
    pub fn list(&self) -> Vec<(u64, String)> {
        let (lock, _) = &*self.inner;
        let g = lock.lock().unwrap();
        let mut v: Vec<(u64, String)> = g
            .jobs
            .iter()
            .map(|(&id, st)| (id, st.label().to_string()))
            .collect();
        v.sort_by_key(|&(id, _)| id);
        v
    }

    /// Block until job `id` leaves the queue/running states.
    pub fn wait(&self, id: u64) -> Option<JobState> {
        self.wait_timeout(id, None)
    }

    /// Block until job `id` reaches a terminal state or `timeout`
    /// elapses. On expiry the job's *current* (non-terminal) state is
    /// returned, so a protocol handler can answer `state: "running"`
    /// instead of pinning its thread forever. `None` timeout = wait
    /// indefinitely.
    pub fn wait_timeout(
        &self,
        id: u64,
        timeout: Option<std::time::Duration>,
    ) -> Option<JobState> {
        let deadline = timeout.map(|t| std::time::Instant::now() + t);
        loop {
            match self.status(id) {
                st @ Some(JobState::Queued | JobState::Running) => {
                    if let Some(d) = deadline {
                        if std::time::Instant::now() >= d {
                            return st;
                        }
                    }
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                other => return other,
            }
        }
    }

    /// Drain the queue and stop the workers (stream drain workers
    /// first, so no refresh runs against a coordinator mid-teardown).
    pub fn shutdown(mut self) {
        self.streams.stop_workers();
        let (lock, cvar) = &*self.inner;
        {
            let mut g = lock.lock().unwrap();
            g.shutdown = true;
            cvar.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    inner: Arc<(Mutex<Inner>, Condvar)>,
    cache: Arc<ContextCache>,
    obs: Arc<Registry>,
) {
    loop {
        let (id, spec) = {
            let (lock, cvar) = &*inner;
            let mut g = lock.lock().unwrap();
            loop {
                if let Some(job) = g.queue.pop_front() {
                    g.running += 1;
                    *g.jobs.get_mut(&job.0).unwrap() = JobState::Running;
                    break job;
                }
                if g.shutdown {
                    return;
                }
                g = cvar.wait(g).unwrap();
            }
        };
        let started = std::time::Instant::now();
        let outcome = match &spec {
            Job::Search(spec) => run_job(spec, &cache),
            Job::Mdim(spec) => run_mdim_job(spec),
            Job::Vl(spec) => run_vl_job(spec, &cache),
        };
        record_job_metrics(&obs, &outcome, started.elapsed());
        let (lock, _) = &*inner;
        let mut g = lock.lock().unwrap();
        g.running -= 1;
        *g.jobs.get_mut(&id).unwrap() = match outcome {
            Ok(report) => JobState::Done(report),
            Err(e) => JobState::Failed(format!("{e:#}")),
        };
    }
}

/// Per-job observability, recorded after the engine returns — never on
/// the search hot path. The engine label comes from the *report* (the
/// resolved registry id), not the request string, so label cardinality
/// is bounded by the engine registry; failed jobs (which may carry an
/// arbitrary requested name) count unlabeled.
fn record_job_metrics(
    obs: &Registry,
    outcome: &Result<Json>,
    elapsed: std::time::Duration,
) {
    match outcome {
        Ok(report) => {
            let engine = report
                .get("algo")
                .and_then(|a| a.as_str())
                .unwrap_or("unknown")
                .to_string();
            obs.labeled_counter("hst_jobs_completed_total", "engine", &engine)
                .inc();
            obs.labeled_histogram(
                "hst_job_latency_ms",
                "engine",
                &engine,
                &LATENCY_BUCKETS_MS,
            )
            .observe(elapsed.as_secs_f64() * 1e3);
            if let Some(cps) = report.get("cps").and_then(|c| c.as_f64()) {
                obs.labeled_histogram(
                    "hst_job_cps",
                    "engine",
                    &engine,
                    &SIZE_BUCKETS,
                )
                .observe(cps);
            }
        }
        Err(_) => obs.counter("hst_jobs_failed_total").inc(),
    }
}

fn run_job(spec: &JobSpec, cache: &ContextCache) -> Result<Json> {
    let Some(engine) = algo::by_name(&spec.algo) else {
        bail!("unknown algorithm {:?}", spec.algo);
    };
    let (ctx, cache_hit) = cache.get_or_build(spec)?;
    let report = engine.run_ctx(&ctx, &spec.params)?;
    Ok(report
        .to_json()
        .set("dataset", spec.dataset.as_str())
        .set("n_points", ctx.series().n_total())
        .set("ctx_cache", if cache_hit { "hit" } else { "miss" }))
}

fn run_vl_job(spec: &VlJobSpec, cache: &ContextCache) -> Result<Json> {
    // vl jobs share the context LRU through the same key a plain submit
    // would use, so the series + stats at the anchor length are reused
    let search_spec = JobSpec {
        dataset: spec.dataset.clone(),
        scale_div: spec.scale_div,
        algo: crate::vl::ENGINE_ID.to_string(),
        params: spec.params.clone(),
    };
    let (ctx, cache_hit) = cache.get_or_build(&search_spec)?;
    let report = crate::vl::HstVl::default().scan(&ctx, &spec.params)?;
    Ok(report
        .to_json()
        .set("dataset", spec.dataset.as_str())
        .set("n_points", ctx.series().n_total())
        .set("ctx_cache", if cache_hit { "hit" } else { "miss" }))
}

fn run_mdim_job(spec: &MdimJobSpec) -> Result<Json> {
    let Some(engine) = mdim::by_name(&spec.algo) else {
        bail!("unknown multivariate algorithm {:?}", spec.algo);
    };
    // mdim jobs build their context per job (no LRU yet: multivariate
    // preparation costs no distance calls, so only series generation is
    // repeated across jobs on the same dataset)
    let ms = spec.series()?;
    let ctx = MdimContext::builder_owned(ms).build();
    let report = engine.run_md(&ctx, &spec.params)?;
    Ok(report
        .to_json()
        .set("dataset", spec.dataset.as_str())
        .set("n_points", ctx.series().n_total())
        .set("dims", ctx.series().dims()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_spec(algo: &str) -> JobSpec {
        JobSpec {
            dataset: "synthetic:noise=0.5,n=1500,seed=1".into(),
            scale_div: 1,
            algo: algo.into(),
            params: SearchParams::new(64, 4, 4),
        }
    }

    #[test]
    fn submits_runs_and_completes() {
        let c = Coordinator::start(2, 16);
        let id = c.submit(quick_spec("hst")).unwrap();
        match c.wait(id) {
            Some(JobState::Done(j)) => {
                assert_eq!(j.get("algo").unwrap().as_str(), Some("hst"));
                assert!(j.get("distance_calls").unwrap().as_u64().unwrap() > 0);
            }
            other => panic!("unexpected state {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn bad_algo_fails_cleanly() {
        let c = Coordinator::start(1, 4);
        let id = c.submit(quick_spec("not-an-algo")).unwrap();
        match c.wait(id) {
            Some(JobState::Failed(msg)) => assert!(msg.contains("unknown algorithm")),
            other => panic!("unexpected state {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let c = Coordinator::start(1, 1);
        // saturate: one running + one queued, then the next submit fails
        let _a = c.submit(quick_spec("hst")).unwrap();
        let mut rejected = false;
        for _ in 0..50 {
            if c.submit(quick_spec("hst")).is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "bounded queue must eventually reject");
        c.shutdown();
    }

    #[test]
    fn parallel_jobs_all_finish() {
        let c = Coordinator::start(4, 64);
        let ids: Vec<u64> = (0..8)
            .map(|i| {
                let mut s = quick_spec(if i % 2 == 0 { "hst" } else { "hotsax" });
                s.params = s.params.with_seed(i as u64);
                c.submit(s).unwrap()
            })
            .collect();
        for id in ids {
            match c.wait(id) {
                Some(JobState::Done(_)) => {}
                other => panic!("job {id}: {other:?}"),
            }
        }
        c.shutdown();
    }

    #[test]
    fn repeated_job_hits_the_context_cache() {
        let c = Coordinator::start(1, 8);
        let first = c.submit(quick_spec("hst")).unwrap();
        let first = match c.wait(first) {
            Some(JobState::Done(j)) => j,
            other => panic!("unexpected {other:?}"),
        };
        let second = c.submit(quick_spec("hst")).unwrap();
        let second = match c.wait(second) {
            Some(JobState::Done(j)) => j,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(first.get("ctx_cache").unwrap().as_str(), Some("miss"));
        assert_eq!(second.get("ctx_cache").unwrap().as_str(), Some("hit"));
        // the warm context serves the preparation: no prep calls at all
        let cold_prep = first.get("prep_calls").unwrap().as_u64().unwrap();
        let warm_prep = second.get("prep_calls").unwrap().as_u64().unwrap();
        assert!(cold_prep > 0, "cold job must pay preparation");
        assert_eq!(warm_prep, 0, "warm job must not re-prepare");
        // a different dataset key misses
        let mut other = quick_spec("hst");
        other.dataset = "synthetic:noise=0.5,n=1500,seed=2".into();
        let third = c.submit(other).unwrap();
        match c.wait(third) {
            Some(JobState::Done(j)) => {
                assert_eq!(j.get("ctx_cache").unwrap().as_str(), Some("miss"))
            }
            other => panic!("unexpected {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn unknown_dataset_fails() {
        let c = Coordinator::start(1, 4);
        let mut s = quick_spec("hst");
        s.dataset = "does-not-exist".into();
        let id = c.submit(s).unwrap();
        match c.wait(id) {
            Some(JobState::Failed(msg)) => assert!(msg.contains("unknown dataset")),
            other => panic!("unexpected {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn from_json_rejects_unknown_fields_by_name() {
        // regression: `scale_dib` (typo) used to be silently dropped,
        // searching the full-length series instead of the scaled one
        let j = Json::parse(
            r#"{"cmd":"submit","dataset":"ECG 15","scale_dib":8,
                "params":{"s":64}}"#,
        )
        .unwrap();
        let err = JobSpec::from_json(&j).unwrap_err();
        assert!(err.contains("`scale_dib`"), "{err}");
        // nested params typos are caught too
        let j = Json::parse(
            r#"{"cmd":"submit","dataset":"ECG 15","params":{"s":64,"kk":2}}"#,
        )
        .unwrap();
        assert!(JobSpec::from_json(&j).unwrap_err().contains("`kk`"));
    }

    #[test]
    fn job_level_threads_flows_into_params() {
        let j = Json::parse(
            r#"{"cmd":"submit","dataset":"ECG 15","threads":3,
                "params":{"s":64}}"#,
        )
        .unwrap();
        assert_eq!(JobSpec::from_json(&j).unwrap().params.threads, 3);
        // an explicit params.threads wins over the job-level field
        let j = Json::parse(
            r#"{"cmd":"submit","dataset":"ECG 15","threads":3,
                "params":{"s":64,"threads":2}}"#,
        )
        .unwrap();
        assert_eq!(JobSpec::from_json(&j).unwrap().params.threads, 2);
    }

    #[test]
    fn synthetic_spec_errors_name_the_field() {
        let mut s = quick_spec("hst");
        s.dataset = "synthetic:noize=0.1".into();
        let err = format!("{:#}", s.series().unwrap_err());
        assert!(err.contains("`noize`"), "{err}");

        s.dataset = "synthetic:noise=abc".into();
        let err = format!("{:#}", s.series().unwrap_err());
        assert!(err.contains("`noise`"), "{err}");

        s.dataset = "synthetic:n".into();
        let err = format!("{:#}", s.series().unwrap_err());
        assert!(err.contains("key=value"), "{err}");
    }

    #[test]
    fn batch_is_atomic_and_shares_the_context_cache() {
        let c = Coordinator::start(2, 16);
        let ids = c
            .submit_batch(vec![quick_spec("hst"), quick_spec("hotsax")])
            .unwrap();
        assert_eq!(ids.len(), 2);
        assert!(ids[1] > ids[0]);
        for id in &ids {
            match c.wait(*id) {
                Some(JobState::Done(_)) => {}
                other => panic!("job {id}: {other:?}"),
            }
        }
        // an oversize batch is rejected whole: no partial enqueue
        let big: Vec<JobSpec> =
            (0..20).map(|_| quick_spec("hst")).collect();
        assert!(c.submit_batch(big).is_err());
        assert!(c.submit_batch(Vec::new()).is_err(), "empty batch");
        let before = c.stats().jobs_total;
        assert_eq!(before, 2, "rejected batches must not register jobs");
        c.shutdown();
    }

    #[test]
    fn wait_timeout_returns_the_live_state() {
        let c = Coordinator::start(1, 8);
        // a slow job plus a queued one behind it
        let mut slow = quick_spec("brute");
        slow.dataset = "synthetic:noise=0.5,n=2500,seed=7".into();
        slow.params = SearchParams::new(32, 4, 4);
        let a = c.submit(slow.clone()).unwrap();
        let b = c.submit(slow).unwrap();
        let st = c
            .wait_timeout(b, Some(std::time::Duration::from_millis(10)))
            .unwrap();
        assert!(
            matches!(st, JobState::Queued | JobState::Running),
            "timeout must surface a non-terminal state, got {st:?}"
        );
        for id in [a, b] {
            match c.wait(id) {
                Some(JobState::Done(_)) => {}
                other => panic!("job {id}: {other:?}"),
            }
        }
        c.shutdown();
    }

    #[test]
    fn stats_reflect_pool_shape() {
        let c = Coordinator::start(3, 9);
        let st = c.stats();
        assert_eq!(st.workers, 3);
        assert_eq!(st.queue_capacity, 9);
        assert_eq!(st.jobs_total, 0);
        assert_eq!(st.ctx_cache_entries, 0);
        assert_eq!(st.streams, 0);
        let id = c.submit(quick_spec("hst")).unwrap();
        let _ = c.wait(id);
        let st = c.stats();
        assert_eq!(st.jobs_total, 1);
        assert_eq!(st.ctx_cache_entries, 1, "job context stays cached");
        assert_eq!(st.queued, 0);
        c.shutdown();
    }

    #[test]
    fn zero_workers_resolves_through_exec_policy() {
        let c = Coordinator::start(0, 4);
        assert!(c.stats().workers >= 1);
        let id = c.submit(quick_spec("hst")).unwrap();
        assert!(matches!(c.wait(id), Some(JobState::Done(_))));
        c.shutdown();
    }

    #[test]
    fn stream_registry_lives_alongside_the_context_cache() {
        let c = Coordinator::start(1, 4);
        let id = c
            .streams()
            .open("s1", SearchParams::new(32, 4, 4), 300, 0)
            .unwrap();
        assert_eq!(c.streams().stream_id("s1"), Some(id));
        assert_eq!(c.stats().streams, 1);
        let pts = crate::ts::generators::sine_with_noise(400, 0.3, 31);
        let updates = c.streams().append("s1", &pts).unwrap();
        assert_eq!(updates.len(), 1);
        // batch jobs and streams coexist on one coordinator
        let id = c.submit(quick_spec("hst")).unwrap();
        assert!(matches!(c.wait(id), Some(JobState::Done(_))));
        c.streams().close("s1").unwrap();
        assert_eq!(c.stats().streams, 0);
        c.shutdown();
    }

    #[test]
    fn config_sizes_the_registry_and_stream_workers() {
        let c = Coordinator::start_config(CoordinatorConfig {
            workers: 1,
            capacity: 4,
            max_streams: 3,
            ctx_cache: 2,
            stream_workers: 1,
        });
        assert_eq!(c.streams().capacity(), 3);
        assert!(c.streams().has_workers());
        for i in 0..3 {
            c.streams()
                .open(&format!("s{i}"), SearchParams::new(32, 4, 4), 300, 0)
                .unwrap();
        }
        assert!(c
            .streams()
            .open("s3", SearchParams::new(32, 4, 4), 300, 0)
            .is_err());
        c.shutdown();
    }

    fn quick_mdim_spec(algo: &str) -> MdimJobSpec {
        MdimJobSpec {
            dataset: "synthetic-md:channels=2,n=900,len=64,seed=3".into(),
            algo: algo.into(),
            params: MdimParams::new(SearchParams::new(64, 4, 4)),
        }
    }

    #[test]
    fn mdim_jobs_run_through_the_shared_pool() {
        let c = Coordinator::start(2, 16);
        let id = c.submit_mdim(quick_mdim_spec("hst-md")).unwrap();
        // univariate and multivariate jobs interleave on one queue
        let other = c.submit(quick_spec("hst")).unwrap();
        match c.wait(id) {
            Some(JobState::Done(j)) => {
                assert_eq!(j.get("algo").unwrap().as_str(), Some("hst-md"));
                assert_eq!(j.get("dims").unwrap().as_u64(), Some(2));
                assert!(j.get("distance_calls").unwrap().as_u64().unwrap() > 0);
                assert!(j.get("cps_per_channel").unwrap().as_f64().unwrap() > 0.0);
                let chans = j.get("channels").unwrap().as_arr().unwrap();
                assert_eq!(chans.len(), 2);
            }
            other => panic!("unexpected state {other:?}"),
        }
        assert!(matches!(c.wait(other), Some(JobState::Done(_))));
        c.shutdown();
    }

    #[test]
    fn mdim_channel_selection_flows_through() {
        let c = Coordinator::start(1, 4);
        let mut spec = quick_mdim_spec("brute-md");
        spec.params = spec.params.with_channels(["c1"]);
        let id = c.submit_mdim(spec).unwrap();
        match c.wait(id) {
            Some(JobState::Done(j)) => {
                let chans = j.get("channels").unwrap().as_arr().unwrap();
                assert_eq!(chans.len(), 1);
                assert_eq!(chans[0].as_str(), Some("c1"));
            }
            other => panic!("unexpected state {other:?}"),
        }
        // a bad channel fails the job with the name in the error
        let mut spec = quick_mdim_spec("hst-md");
        spec.params = spec.params.with_channels(["nope"]);
        let id = c.submit_mdim(spec).unwrap();
        match c.wait(id) {
            Some(JobState::Failed(msg)) => {
                assert!(msg.contains("unknown channel `nope`"), "{msg}")
            }
            other => panic!("unexpected state {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn mdim_from_json_rejects_unknown_fields_by_name() {
        let j = Json::parse(
            r#"{"cmd":"mdim","dataset":"synthetic-md:","chanels":["a"],
                "params":{"s":64}}"#,
        )
        .unwrap();
        let err = MdimJobSpec::from_json(&j).unwrap_err();
        assert!(err.contains("`chanels`"), "{err}");
        // nested params typos are caught too
        let j = Json::parse(
            r#"{"cmd":"mdim","dataset":"synthetic-md:","params":{"s":64,"kk":1}}"#,
        )
        .unwrap();
        assert!(MdimJobSpec::from_json(&j).unwrap_err().contains("`kk`"));
        // channels ride inside params and must be strings
        let j = Json::parse(
            r#"{"cmd":"mdim","dataset":"synthetic-md:","params":{"s":64,"channels":[0]}}"#,
        )
        .unwrap();
        let err = MdimJobSpec::from_json(&j).unwrap_err();
        assert!(err.contains("channels[0]"), "{err}");
        // job-level threads shorthand
        let j = Json::parse(
            r#"{"cmd":"mdim","dataset":"synthetic-md:","threads":2,
                "params":{"s":64}}"#,
        )
        .unwrap();
        assert_eq!(MdimJobSpec::from_json(&j).unwrap().params.base.threads, 2);
    }

    #[test]
    fn synthetic_md_spec_errors_name_the_field() {
        let mut s = quick_mdim_spec("hst-md");
        s.dataset = "synthetic-md:chanels=2".into();
        let err = format!("{:#}", s.series().unwrap_err());
        assert!(err.contains("`chanels`"), "{err}");

        s.dataset = "synthetic-md:n=abc".into();
        let err = format!("{:#}", s.series().unwrap_err());
        assert!(err.contains("`n`"), "{err}");

        s.dataset = "synthetic-md:n".into();
        let err = format!("{:#}", s.series().unwrap_err());
        assert!(err.contains("key=value"), "{err}");

        s.dataset = "not-a-multi-dataset".into();
        let err = format!("{:#}", s.series().unwrap_err());
        assert!(err.contains("synthetic-md"), "{err}");

        // defaults apply when the spec names no field
        s.dataset = "synthetic-md:".into();
        let ms = s.series().unwrap();
        assert_eq!(ms.dims(), 3);
        assert_eq!(ms.n_total(), 8_000);

        // a network request must not drive an unbounded allocation
        // (the stream_open MAX_STREAM_WINDOW invariant, applied here)
        s.dataset = "synthetic-md:channels=100000000,n=100000000".into();
        let err = format!("{:#}", s.series().unwrap_err());
        assert!(err.contains("cap"), "{err}");
        // the overflow-safe path: n × channels wraps usize
        s.dataset = format!("synthetic-md:channels=8,n={}", usize::MAX / 4);
        assert!(s.series().is_err());
    }

    #[test]
    fn mdim_file_dataset_loads_multi_csv_inside_the_working_dir_only() {
        // in-tree file (cargo test runs from the package root): loads
        let dir = std::env::current_dir().unwrap().join("target");
        std::fs::create_dir_all(&dir).unwrap();
        let path =
            dir.join(format!("hstime_mdim_job_{}.csv", std::process::id()));
        std::fs::write(&path, "a,b\n1,2\n3,4\n5,6\n").unwrap();
        let mut s = quick_mdim_spec("hst-md");
        s.dataset = format!("file:{}", path.display());
        let ms = s.series().unwrap();
        assert_eq!(ms.dims(), 2);
        assert_eq!(ms.channel_names(), vec!["a", "b"]);
        std::fs::remove_file(&path).ok();

        // a path resolving outside the working directory is refused
        // before any read — a network-supplied path must not be able to
        // read (or echo) arbitrary server files
        let mut outside = std::env::temp_dir();
        outside.push(format!("hstime_mdim_out_{}.csv", std::process::id()));
        std::fs::write(&outside, "a,b\n1,2\n").unwrap();
        s.dataset = format!("file:{}", outside.display());
        let err = format!("{:#}", s.series().unwrap_err());
        assert!(
            err.contains("outside the service working directory"),
            "{err}"
        );
        std::fs::remove_file(&outside).ok();

        // a missing file errors cleanly too
        s.dataset = "file:does/not/exist.csv".into();
        assert!(s.series().is_err());
    }

    #[test]
    fn vl_jobs_run_through_the_shared_pool() {
        let c = Coordinator::start(2, 16);
        let spec = VlJobSpec {
            dataset: "synthetic:noise=0.5,n=1500,seed=5".into(),
            scale_div: 1,
            params: SearchParams::new(64, 4, 4).with_length_range(
                crate::config::LengthRange::new(48, 64, 8),
            ),
        };
        let id = c.submit_vl(spec.clone()).unwrap();
        // univariate and vl jobs interleave on one queue
        let other = c.submit(quick_spec("hst")).unwrap();
        match c.wait(id) {
            Some(JobState::Done(j)) => {
                assert_eq!(j.get("algo").unwrap().as_str(), Some("hst-vl"));
                let lengths = j.get("lengths").unwrap().as_arr().unwrap();
                assert_eq!(lengths.len(), 3); // 48, 56, 64
                assert!(!j.get("ranked").unwrap().as_arr().unwrap().is_empty());
                assert!(j.get("total_calls").unwrap().as_u64().unwrap() > 0);
            }
            other => panic!("unexpected state {other:?}"),
        }
        assert!(matches!(c.wait(other), Some(JobState::Done(_))));
        // a second identical scan reuses the prepared context
        let id = c.submit_vl(spec).unwrap();
        match c.wait(id) {
            Some(JobState::Done(j)) => {
                assert_eq!(j.get("ctx_cache").unwrap().as_str(), Some("hit"))
            }
            other => panic!("unexpected state {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn vl_from_json_rejects_unknown_fields_by_name() {
        // no `algo` field on the vl command: the job kind *is* the engine
        let j = Json::parse(
            r#"{"cmd":"vl","dataset":"ECG 15","algo":"hst-vl",
                "params":{"s":64}}"#,
        )
        .unwrap();
        let err = VlJobSpec::from_json(&j).unwrap_err();
        assert!(err.contains("`algo`"), "{err}");
        // nested params typos are caught too
        let j = Json::parse(
            r#"{"cmd":"vl","dataset":"ECG 15",
                "params":{"s":64,"s_mim":32}}"#,
        )
        .unwrap();
        assert!(VlJobSpec::from_json(&j).unwrap_err().contains("`s_mim`"));
        // the range rides in as s_min/s_max/s_step and is validated
        let j = Json::parse(
            r#"{"cmd":"vl","dataset":"ECG 15","scale_div":8,"threads":2,
                "params":{"s":64,"s_min":32,"s_max":64,"s_step":8}}"#,
        )
        .unwrap();
        let spec = VlJobSpec::from_json(&j).unwrap();
        assert_eq!(spec.scale_div, 8);
        assert_eq!(spec.params.threads, 2);
        let r = spec.params.s_range.unwrap();
        assert_eq!((r.min, r.max, r.step), (32, 64, 8));
        let j = Json::parse(
            r#"{"cmd":"vl","dataset":"ECG 15",
                "params":{"s":64,"s_min":2,"s_max":64}}"#,
        )
        .unwrap();
        let err = VlJobSpec::from_json(&j).unwrap_err();
        assert!(err.contains("min=2"), "{err}");
    }

    #[test]
    fn snapshot_save_restore_round_trips_warm_state() {
        let dir = std::env::temp_dir().join(format!(
            "hstime_coord_snap_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();

        // warm a context and a stream, then save
        let c = Coordinator::start(1, 8);
        let id = c.submit(quick_spec("hst")).unwrap();
        assert!(matches!(c.wait(id), Some(JobState::Done(_))));
        c.streams()
            .open("snap-a", SearchParams::new(32, 4, 4), 300, 0)
            .unwrap();
        let pts = crate::ts::generators::sine_with_noise(400, 0.3, 41);
        c.streams().append("snap-a", &pts).unwrap();
        let saved = c.snapshot_save(&dir).unwrap();
        assert_eq!(saved.contexts, 1);
        assert_eq!(saved.monitors, 1);
        assert_eq!(c.stats().snapshot_saves, 1);
        c.shutdown();

        // a fresh coordinator restores it all
        let c2 = Coordinator::start(1, 8);
        let restored = c2.snapshot_restore(&dir).unwrap();
        assert_eq!(restored.contexts, 1);
        assert_eq!(restored.monitors, 1);
        assert!(restored.profiles >= 1);
        let st = c2.stats();
        assert_eq!(st.snapshot_restores, 1);
        assert_eq!(st.snapshot_contexts_restored, 1);
        assert_eq!(st.snapshot_streams_restored, 1);
        assert!(st.snapshot_profiles_seeded >= 1);

        // the restored context is a cache hit and needs no re-preparation
        let id = c2.submit(quick_spec("hst")).unwrap();
        match c2.wait(id) {
            Some(JobState::Done(j)) => {
                assert_eq!(j.get("ctx_cache").unwrap().as_str(), Some("hit"));
                assert_eq!(j.get("prep_calls").unwrap().as_u64(), Some(0));
            }
            other => panic!("unexpected {other:?}"),
        }
        // the restored stream continues warm
        let more = crate::ts::generators::sine_with_noise(50, 0.3, 42);
        let ups = c2.streams().append("snap-a", &more).unwrap();
        assert_eq!(ups[0].get("warm").unwrap().as_bool(), Some(true));
        assert_eq!(ups[0].get("prep_calls").unwrap().as_u64(), Some(0));

        // restoring again skips keys that are already live
        let again = c2.snapshot_restore(&dir).unwrap();
        assert_eq!(again.contexts + again.monitors, 0);
        assert_eq!(again.skipped, 2);
        c2.shutdown();

        // a corrupted file fails the restore with the file named
        let c3 = Coordinator::start(1, 4);
        let victim = store::list_dir(&dir).unwrap().remove(0);
        let mut bytes = std::fs::read(&victim).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        let err = format!("{:#}", c3.snapshot_restore(&dir).unwrap_err());
        assert!(err.contains("snapshot"), "{err}");
        assert!(
            err.contains(victim.file_name().unwrap().to_str().unwrap())
                || err.contains(&victim.display().to_string()),
            "{err}"
        );
        c3.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn registry_dataset_scaled_runs() {
        let c = Coordinator::start(1, 4);
        let spec = JobSpec {
            dataset: "Shuttle TEK 14".into(),
            scale_div: 4,
            algo: "hst".into(),
            params: SearchParams::new(128, 4, 4),
        };
        let id = c.submit(spec).unwrap();
        match c.wait(id) {
            Some(JobState::Done(j)) => {
                assert!(j.get("n_sequences").unwrap().as_u64().unwrap() > 0)
            }
            other => panic!("unexpected {other:?}"),
        }
        c.shutdown();
    }
}
