//! The job coordinator: bounded queue, worker pool, job registry.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::algo;
use crate::config::SearchParams;
use crate::ts::{datasets, TimeSeries};
use crate::util::json::Json;

/// A search job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Registry dataset name (or "synthetic:noise=E,n=N" forms).
    pub dataset: String,
    /// Length divisor applied to the registry's paper length.
    pub scale_div: usize,
    /// Algorithm name (see [`crate::algo::by_name`]).
    pub algo: String,
    /// Search parameters forwarded to the engine.
    pub params: SearchParams,
}

impl JobSpec {
    /// Parse a `submit` request (protocol documented in [`crate::service`]).
    pub fn from_json(v: &Json) -> Result<JobSpec, String> {
        let dataset = v
            .get("dataset")
            .and_then(|d| d.as_str())
            .ok_or("field `dataset` required")?
            .to_string();
        let algo = v
            .get("algo")
            .and_then(|d| d.as_str())
            .unwrap_or("hst")
            .to_string();
        let scale_div = v
            .get("scale_div")
            .and_then(|d| d.as_u64())
            .unwrap_or(1) as usize;
        let params = match v.get("params") {
            Some(p) => SearchParams::from_json(p)?,
            None => return Err("field `params` required".into()),
        };
        Ok(JobSpec {
            dataset,
            scale_div,
            algo,
            params,
        })
    }

    /// Materialize the requested series.
    pub fn series(&self) -> Result<TimeSeries> {
        if let Some(rest) = self.dataset.strip_prefix("synthetic:") {
            // synthetic:noise=0.1,n=20000,seed=4
            let mut noise = 0.1f64;
            let mut n = 20_000usize;
            let mut seed = 0u64;
            for kv in rest.split(',') {
                match kv.split_once('=') {
                    Some(("noise", v)) => noise = v.parse()?,
                    Some(("n", v)) => n = v.parse()?,
                    Some(("seed", v)) => seed = v.parse()?,
                    _ => bail!("bad synthetic spec field {kv:?}"),
                }
            }
            return Ok(crate::ts::series::IntoSeries::into_series(
                crate::ts::generators::sine_with_noise(n, noise, seed),
                &format!("synthetic(E={noise},n={n})"),
            ));
        }
        match datasets::by_name(&self.dataset) {
            Some(d) => Ok(d.generate_scaled(self.scale_div)),
            None => bail!("unknown dataset {:?}", self.dataset),
        }
    }
}

/// Lifecycle of a job.
#[derive(Debug, Clone)]
pub enum JobState {
    /// Accepted, waiting for a worker.
    Queued,
    /// A worker is executing the search.
    Running,
    /// Finished successfully; carries the report JSON.
    Done(Json),
    /// Finished with an error; carries the message.
    Failed(String),
}

impl JobState {
    /// Protocol label of this state (`queued`/`running`/`done`/`failed`).
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
        }
    }
}

struct Inner {
    queue: VecDeque<(u64, JobSpec)>,
    jobs: HashMap<u64, JobState>,
    next_id: u64,
    shutdown: bool,
    running: usize,
}

/// Thread-pool coordinator with a bounded queue (backpressure: `submit`
/// rejects when full, so upstream callers must retry/slow down — the same
/// contract a production ingestion tier would expose).
pub struct Coordinator {
    inner: Arc<(Mutex<Inner>, Condvar)>,
    workers: Vec<JoinHandle<()>>,
    capacity: usize,
}

impl Coordinator {
    /// Start `n_workers` workers with a queue bound of `capacity`.
    pub fn start(n_workers: usize, capacity: usize) -> Coordinator {
        let inner = Arc::new((
            Mutex::new(Inner {
                queue: VecDeque::new(),
                jobs: HashMap::new(),
                next_id: 1,
                shutdown: false,
                running: 0,
            }),
            Condvar::new(),
        ));
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || worker_loop(inner))
            })
            .collect();
        Coordinator {
            inner,
            workers,
            capacity,
        }
    }

    /// Submit a job; returns its id, or an error when the queue is full
    /// (backpressure) or the coordinator is shutting down.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        let (lock, cvar) = &*self.inner;
        let mut g = lock.lock().unwrap();
        if g.shutdown {
            bail!("coordinator is shut down");
        }
        if g.queue.len() >= self.capacity {
            bail!("queue full ({} jobs): backpressure, retry later", self.capacity);
        }
        let id = g.next_id;
        g.next_id += 1;
        g.jobs.insert(id, JobState::Queued);
        g.queue.push_back((id, spec));
        cvar.notify_one();
        Ok(id)
    }

    /// Current state of a job.
    pub fn status(&self, id: u64) -> Option<JobState> {
        let (lock, _) = &*self.inner;
        lock.lock().unwrap().jobs.get(&id).cloned()
    }

    /// All job ids with their state labels.
    pub fn list(&self) -> Vec<(u64, String)> {
        let (lock, _) = &*self.inner;
        let g = lock.lock().unwrap();
        let mut v: Vec<(u64, String)> = g
            .jobs
            .iter()
            .map(|(&id, st)| (id, st.label().to_string()))
            .collect();
        v.sort_by_key(|&(id, _)| id);
        v
    }

    /// Block until job `id` leaves the queue/running states.
    pub fn wait(&self, id: u64) -> Option<JobState> {
        loop {
            match self.status(id) {
                Some(JobState::Queued) | Some(JobState::Running) => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                other => return other,
            }
        }
    }

    /// Drain the queue and stop the workers.
    pub fn shutdown(mut self) {
        let (lock, cvar) = &*self.inner;
        {
            let mut g = lock.lock().unwrap();
            g.shutdown = true;
            cvar.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(inner: Arc<(Mutex<Inner>, Condvar)>) {
    loop {
        let (id, spec) = {
            let (lock, cvar) = &*inner;
            let mut g = lock.lock().unwrap();
            loop {
                if let Some(job) = g.queue.pop_front() {
                    g.running += 1;
                    *g.jobs.get_mut(&job.0).unwrap() = JobState::Running;
                    break job;
                }
                if g.shutdown {
                    return;
                }
                g = cvar.wait(g).unwrap();
            }
        };
        let outcome = run_job(&spec);
        let (lock, _) = &*inner;
        let mut g = lock.lock().unwrap();
        g.running -= 1;
        *g.jobs.get_mut(&id).unwrap() = match outcome {
            Ok(report) => JobState::Done(report),
            Err(e) => JobState::Failed(format!("{e:#}")),
        };
    }
}

fn run_job(spec: &JobSpec) -> Result<Json> {
    let Some(engine) = algo::by_name(&spec.algo) else {
        bail!("unknown algorithm {:?}", spec.algo);
    };
    let ts = spec.series()?;
    let report = engine.run(&ts, &spec.params)?;
    Ok(report
        .to_json()
        .set("dataset", spec.dataset.as_str())
        .set("n_points", ts.n_total()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_spec(algo: &str) -> JobSpec {
        JobSpec {
            dataset: "synthetic:noise=0.5,n=1500,seed=1".into(),
            scale_div: 1,
            algo: algo.into(),
            params: SearchParams::new(64, 4, 4),
        }
    }

    #[test]
    fn submits_runs_and_completes() {
        let c = Coordinator::start(2, 16);
        let id = c.submit(quick_spec("hst")).unwrap();
        match c.wait(id) {
            Some(JobState::Done(j)) => {
                assert_eq!(j.get("algo").unwrap().as_str(), Some("hst"));
                assert!(j.get("distance_calls").unwrap().as_u64().unwrap() > 0);
            }
            other => panic!("unexpected state {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn bad_algo_fails_cleanly() {
        let c = Coordinator::start(1, 4);
        let id = c.submit(quick_spec("not-an-algo")).unwrap();
        match c.wait(id) {
            Some(JobState::Failed(msg)) => assert!(msg.contains("unknown algorithm")),
            other => panic!("unexpected state {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let c = Coordinator::start(1, 1);
        // saturate: one running + one queued, then the next submit fails
        let _a = c.submit(quick_spec("hst")).unwrap();
        let mut rejected = false;
        for _ in 0..50 {
            if c.submit(quick_spec("hst")).is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "bounded queue must eventually reject");
        c.shutdown();
    }

    #[test]
    fn parallel_jobs_all_finish() {
        let c = Coordinator::start(4, 64);
        let ids: Vec<u64> = (0..8)
            .map(|i| {
                let mut s = quick_spec(if i % 2 == 0 { "hst" } else { "hotsax" });
                s.params = s.params.with_seed(i as u64);
                c.submit(s).unwrap()
            })
            .collect();
        for id in ids {
            match c.wait(id) {
                Some(JobState::Done(_)) => {}
                other => panic!("job {id}: {other:?}"),
            }
        }
        c.shutdown();
    }

    #[test]
    fn unknown_dataset_fails() {
        let c = Coordinator::start(1, 4);
        let mut s = quick_spec("hst");
        s.dataset = "does-not-exist".into();
        let id = c.submit(s).unwrap();
        match c.wait(id) {
            Some(JobState::Failed(msg)) => assert!(msg.contains("unknown dataset")),
            other => panic!("unexpected {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn registry_dataset_scaled_runs() {
        let c = Coordinator::start(1, 4);
        let spec = JobSpec {
            dataset: "Shuttle TEK 14".into(),
            scale_div: 4,
            algo: "hst".into(),
            params: SearchParams::new(128, 4, 4),
        };
        let id = c.submit(spec).unwrap();
        match c.wait(id) {
            Some(JobState::Done(j)) => {
                assert!(j.get("n_sequences").unwrap().as_u64().unwrap() > 0)
            }
            other => panic!("unexpected {other:?}"),
        }
        c.shutdown();
    }
}
