//! TCP JSON-lines front end over the [`Coordinator`] plus a blocking
//! [`Client`] for the CLI, examples, and integration tests.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::SearchParams;
use crate::util::json::Json;

use super::coordinator::{Coordinator, JobSpec, JobState};

/// Serve until a `shutdown` command arrives. Returns the bound local
/// address through `on_bound` (use port 0 to pick a free port).
pub fn serve<A: ToSocketAddrs>(
    addr: A,
    n_workers: usize,
    capacity: usize,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).context("binding service socket")?;
    on_bound(listener.local_addr()?);
    let coord = Arc::new(Coordinator::start(n_workers, capacity));
    let stop = Arc::new(AtomicBool::new(false));
    // accept loop: one handler thread per connection (few clients, long
    // jobs — thread-per-conn is the right tradeoff here). Handlers are
    // detached: joining them would deadlock shutdown while another client
    // keeps its connection open; they exit when their peer disconnects.
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = stream?;
        let coord = Arc::clone(&coord);
        let stop2 = Arc::clone(&stop);
        std::thread::spawn(move || {
            let _ = handle_conn(stream, &coord, &stop2);
        });
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    match Arc::try_unwrap(coord) {
        Ok(c) => c.shutdown(),
        Err(_) => {} // a handler still holds it; workers die with process
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    stop: &AtomicBool,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = dispatch(&line, coord, stop);
        writeln!(writer, "{reply}")?;
        if stop.load(Ordering::SeqCst) {
            // unblock the accept loop with a dummy connection
            let _ = TcpStream::connect(writer.local_addr()?);
            break;
        }
    }
    let _ = peer;
    Ok(())
}

/// Every `cmd` the dispatcher accepts, in `docs/PROTOCOL.md` order.
/// `tests/docs_consistency.rs` asserts the protocol document covers each
/// of these, so the list and the doc cannot drift apart.
pub const COMMANDS: [&str; 13] = [
    "submit",
    "batch",
    "mdim",
    "vl",
    "status",
    "wait",
    "stats",
    "list",
    "stream_open",
    "append",
    "subscribe",
    "stream_close",
    "shutdown",
];

fn err_reply(msg: &str) -> Json {
    Json::obj().set("ok", false).set("error", msg)
}

/// Reject requests carrying fields outside `known` — applied to every
/// command (same strictness as the job parser: a typo must fail loudly,
/// not silently change the request; `{"cmd":"wait","timout_ms":250}`
/// must not block forever).
fn check_fields(req: &Json, known: &[&str]) -> Result<(), Json> {
    if let Json::Obj(map) = req {
        if let Some(bad) = map.keys().find(|k| !known.contains(&k.as_str())) {
            return Err(err_reply(&format!(
                "unknown field `{bad}` (known: {})",
                known.join(", ")
            )));
        }
    }
    Ok(())
}

/// The `stream` field every streaming command addresses a monitor by.
fn stream_name(req: &Json) -> Result<&str, Json> {
    req.get("stream")
        .and_then(|s| s.as_str())
        .ok_or_else(|| err_reply("field `stream` (string) required"))
}

fn dispatch(line: &str, coord: &Coordinator, stop: &AtomicBool) -> Json {
    let req = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return err_reply(&format!("bad json: {e}")),
    };
    match req.get("cmd").and_then(|c| c.as_str()) {
        Some("submit") => match JobSpec::from_json(&req) {
            Ok(spec) => match coord.submit(spec) {
                Ok(id) => Json::obj().set("ok", true).set("job", id),
                Err(e) => err_reply(&format!("{e:#}")),
            },
            Err(e) => err_reply(&e),
        },
        Some("mdim") => match super::coordinator::MdimJobSpec::from_json(&req) {
            Ok(spec) => match coord.submit_mdim(spec) {
                Ok(id) => Json::obj().set("ok", true).set("job", id),
                Err(e) => err_reply(&format!("{e:#}")),
            },
            Err(e) => err_reply(&e),
        },
        Some("vl") => match super::coordinator::VlJobSpec::from_json(&req) {
            Ok(spec) => match coord.submit_vl(spec) {
                Ok(id) => Json::obj().set("ok", true).set("job", id),
                Err(e) => err_reply(&format!("{e:#}")),
            },
            Err(e) => err_reply(&e),
        },
        Some("status") => {
            if let Err(e) = check_fields(&req, &["cmd", "job"]) {
                return e;
            }
            let Some(id) = req.get("job").and_then(|j| j.as_u64()) else {
                return err_reply("field `job` required");
            };
            match coord.status(id) {
                None => err_reply("no such job"),
                Some(st) => {
                    let mut out = Json::obj()
                        .set("ok", true)
                        .set("job", id)
                        .set("state", st.label());
                    match st {
                        JobState::Done(report) => out = out.set("report", report),
                        JobState::Failed(msg) => out = out.set("error", msg),
                        _ => {}
                    }
                    out
                }
            }
        }
        Some("batch") => {
            if let Err(e) = check_fields(&req, &["cmd", "jobs"]) {
                return e;
            }
            let Some(jobs) = req.get("jobs").and_then(|j| j.as_arr()) else {
                return err_reply("field `jobs` (array) required");
            };
            let mut specs = Vec::with_capacity(jobs.len());
            for (i, job) in jobs.iter().enumerate() {
                match JobSpec::from_json(job) {
                    Ok(spec) => specs.push(spec),
                    Err(e) => return err_reply(&format!("jobs[{i}]: {e}")),
                }
            }
            match coord.submit_batch(specs) {
                Ok(ids) => Json::obj().set("ok", true).set(
                    "jobs",
                    ids.into_iter().map(Json::from).collect::<Vec<_>>(),
                ),
                Err(e) => err_reply(&format!("{e:#}")),
            }
        }
        Some("wait") => {
            if let Err(e) = check_fields(&req, &["cmd", "job", "timeout_ms"]) {
                return e;
            }
            let Some(id) = req.get("job").and_then(|j| j.as_u64()) else {
                return err_reply("field `job` required");
            };
            let timeout = match req.get("timeout_ms") {
                None => None,
                Some(t) => match t.as_u64() {
                    Some(ms) => Some(std::time::Duration::from_millis(ms)),
                    None => {
                        return err_reply(
                            "field `timeout_ms` must be an integer",
                        )
                    }
                },
            };
            match coord.wait_timeout(id, timeout) {
                None => err_reply("no such job"),
                Some(JobState::Done(report)) => Json::obj()
                    .set("ok", true)
                    .set("job", id)
                    .set("state", "done")
                    .set("report", report),
                Some(JobState::Failed(msg)) => Json::obj()
                    .set("ok", false)
                    .set("job", id)
                    .set("state", "failed")
                    .set("error", msg),
                // the timeout expired: report the live state instead of
                // pinning this handler thread until the job finishes
                Some(st) => Json::obj()
                    .set("ok", true)
                    .set("job", id)
                    .set("state", st.label())
                    .set("timed_out", true),
            }
        }
        Some("stats") => {
            if let Err(e) = check_fields(&req, &["cmd"]) {
                return e;
            }
            let st = coord.stats();
            Json::obj()
                .set("ok", true)
                .set("queued", st.queued)
                .set("running", st.running)
                .set("workers", st.workers)
                .set("jobs_total", st.jobs_total)
                .set("queue_capacity", st.queue_capacity)
                .set("ctx_cache_entries", st.ctx_cache_entries)
                .set("streams", st.streams)
        }
        Some("list") => {
            if let Err(e) = check_fields(&req, &["cmd"]) {
                return e;
            }
            let jobs: Vec<Json> = coord
                .list()
                .into_iter()
                .map(|(id, st)| Json::obj().set("job", id).set("state", st))
                .collect();
            Json::obj().set("ok", true).set("jobs", jobs)
        }
        Some("stream_open") => {
            if let Err(e) = check_fields(
                &req,
                &["cmd", "stream", "params", "window", "refresh_every"],
            ) {
                return e;
            }
            let name = match stream_name(&req) {
                Ok(n) => n,
                Err(e) => return e,
            };
            let params = match req.get("params") {
                Some(p) => match SearchParams::from_json(p) {
                    Ok(p) => p,
                    Err(e) => return err_reply(&e),
                },
                None => return err_reply("field `params` required"),
            };
            let Some(window) = req.get("window").and_then(|w| w.as_u64()) else {
                return err_reply("field `window` (points, integer) required");
            };
            let refresh_every = match req.get("refresh_every") {
                None => 0,
                Some(r) => match r.as_u64() {
                    Some(r) => r as usize,
                    None => {
                        return err_reply(
                            "field `refresh_every` must be an integer",
                        )
                    }
                },
            };
            match coord.streams().open(name, params, window as usize, refresh_every)
            {
                Ok(()) => Json::obj().set("ok", true).set("stream", name),
                Err(e) => err_reply(&format!("{e:#}")),
            }
        }
        Some("append") => {
            if let Err(e) = check_fields(&req, &["cmd", "stream", "points"]) {
                return e;
            }
            let name = match stream_name(&req) {
                Ok(n) => n,
                Err(e) => return e,
            };
            let Some(raw) = req.get("points").and_then(|p| p.as_arr()) else {
                return err_reply("field `points` (array of numbers) required");
            };
            let mut points = Vec::with_capacity(raw.len());
            for (i, v) in raw.iter().enumerate() {
                match v.as_f64() {
                    Some(x) => points.push(x),
                    None => {
                        return err_reply(&format!(
                            "points[{i}] is not a number"
                        ))
                    }
                }
            }
            match coord.streams().append(name, &points) {
                Ok(updates) => Json::obj()
                    .set("ok", true)
                    .set("stream", name)
                    .set("appended", points.len())
                    .set("updates", updates),
                Err(e) => err_reply(&format!("{e:#}")),
            }
        }
        Some("subscribe") => {
            if let Err(e) =
                check_fields(&req, &["cmd", "stream", "after", "timeout_ms"])
            {
                return e;
            }
            let name = match stream_name(&req) {
                Ok(n) => n,
                Err(e) => return e,
            };
            let after = match req.get("after") {
                None => 0,
                Some(a) => match a.as_u64() {
                    Some(a) => a,
                    None => {
                        return err_reply("field `after` must be an integer")
                    }
                },
            };
            let timeout = match req.get("timeout_ms") {
                None => None,
                Some(t) => match t.as_u64() {
                    Some(ms) => Some(std::time::Duration::from_millis(ms)),
                    None => {
                        return err_reply(
                            "field `timeout_ms` must be an integer",
                        )
                    }
                },
            };
            match coord.streams().subscribe(name, after, timeout) {
                Ok(Some((seq, update))) => Json::obj()
                    .set("ok", true)
                    .set("stream", name)
                    .set("seq", seq)
                    .set("update", update),
                // the timeout expired before the next refresh
                Ok(None) => Json::obj()
                    .set("ok", true)
                    .set("stream", name)
                    .set("timed_out", true),
                Err(e) => err_reply(&format!("{e:#}")),
            }
        }
        Some("stream_close") => {
            if let Err(e) = check_fields(&req, &["cmd", "stream"]) {
                return e;
            }
            let name = match stream_name(&req) {
                Ok(n) => n,
                Err(e) => return e,
            };
            match coord.streams().close(name) {
                Ok(()) => Json::obj()
                    .set("ok", true)
                    .set("stream", name)
                    .set("closed", true),
                Err(e) => err_reply(&format!("{e:#}")),
            }
        }
        Some("shutdown") => {
            if let Err(e) = check_fields(&req, &["cmd"]) {
                return e;
            }
            stop.store(true, Ordering::SeqCst);
            Json::obj().set("ok", true).set("bye", true)
        }
        _ => err_reply(&format!(
            "unknown cmd (expected one of: {})",
            COMMANDS.join("|")
        )),
    }
}

/// Blocking client for the JSON-lines protocol.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a running service.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to service")?;
        let writer = stream.try_clone()?;
        Ok(Client {
            writer,
            reader: BufReader::new(stream),
        })
    }

    /// Send one request, read one reply.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{req}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    /// Submit a prepared request object; returns the job id.
    pub fn submit(&mut self, spec_json: Json) -> Result<u64> {
        let reply = self.call(&spec_json)?;
        if reply.get("ok").and_then(|b| b.as_bool()) != Some(true) {
            anyhow::bail!(
                "submit rejected: {}",
                reply.get("error").and_then(|e| e.as_str()).unwrap_or("?")
            );
        }
        reply
            .get("job")
            .and_then(|j| j.as_u64())
            .context("reply missing job id")
    }

    /// Block until `job` reaches a terminal state; returns the reply.
    pub fn wait(&mut self, job: u64) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "wait").set("job", job))
    }

    /// Wait at most `timeout_ms` for `job`; on expiry the reply carries
    /// the job's live state (`"queued"`/`"running"`) and
    /// `timed_out: true`.
    pub fn wait_timeout(&mut self, job: u64, timeout_ms: u64) -> Result<Json> {
        self.call(
            &Json::obj()
                .set("cmd", "wait")
                .set("job", job)
                .set("timeout_ms", timeout_ms),
        )
    }

    /// Submit a job array in one atomic request; returns the job ids.
    pub fn submit_batch(&mut self, jobs: Vec<Json>) -> Result<Vec<u64>> {
        let reply = self.call(&Json::obj().set("cmd", "batch").set("jobs", jobs))?;
        if reply.get("ok").and_then(|b| b.as_bool()) != Some(true) {
            anyhow::bail!(
                "batch rejected: {}",
                reply.get("error").and_then(|e| e.as_str()).unwrap_or("?")
            );
        }
        reply
            .get("jobs")
            .and_then(|j| j.as_arr())
            .map(|ids| ids.iter().filter_map(|j| j.as_u64()).collect())
            .context("reply missing job ids")
    }

    /// Fetch the service's observability snapshot (`cmd: "stats"`).
    pub fn stats(&mut self) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "stats"))
    }

    /// Ask the service to stop accepting connections and drain.
    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.call(&Json::obj().set("cmd", "shutdown"))?;
        Ok(())
    }
}
