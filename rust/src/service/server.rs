//! TCP front end over the [`Coordinator`]: a readiness-driven reactor
//! multiplexing every connection on one thread, speaking JSON lines and
//! binary frames over the same port, plus a blocking [`Client`] for the
//! CLI, examples, benches, and integration tests.
//!
//! ## Reactor, not thread-per-connection
//!
//! Earlier versions parked one handler thread per connection, which
//! meant one OS thread pinned per blocked `subscribe` — a dead end at
//! 1k+ streams. The reactor keeps every socket nonblocking and loops:
//! accept whatever is pending, read whatever is readable, parse, answer
//! what can be answered now, and park what cannot (`wait`, `subscribe`,
//! offloaded `append`) as a *pending reply slot* polled on later ticks.
//! Replies flush strictly in request order per connection, so pipelined
//! clients see exactly the ordering a blocking server gave them. A
//! client that disconnects mid-`subscribe` is dropped — with its pending
//! slots — on the very next tick instead of leaking a parked thread
//! until some timeout.
//!
//! ## One port, two encodings
//!
//! The first byte of [`frame::MAGIC`] is `0xB5` (≥ 0x80), which can
//! never start a JSON line, so the reactor demultiplexes per message:
//! magic byte → length-prefixed binary frame, anything else → JSON line.
//! Binary `data` frames are ingest-only and fire-and-forget: accepted
//! payloads go to the stream's bounded queue for the drain workers and
//! get no reply; dropped ones come back as a binary `shed` frame naming
//! the reason. Frames must be negotiated first with the versioned
//! `hello` command — a frame on a connection that never said hello is a
//! protocol error.
//!
//! ## Backpressure
//!
//! Three bounds keep a flood from growing server memory: the per-stream
//! ingest queue (capacity = the stream's window), the per-connection
//! in-flight point quota ([`CLIENT_INFLIGHT_QUOTA`]), and the
//! per-connection outbound buffer (a consumer too slow to read its own
//! replies is disconnected). The first two shed frames with a named
//! reason; all of it is observable through `stats`.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::{Component, Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::SearchParams;
use crate::util::json::Json;

use super::coordinator::{Coordinator, CoordinatorConfig, JobSpec, JobState};
use super::frame::{self, FrameHeader, FrameKind, ShedReason};
use super::streams::Enqueue;

/// Points one connection may have in flight (accepted into stream
/// queues, not yet drained) before its further `data` frames shed with
/// reason `client_quota`. 256k points ≈ 2 MB of payload per client.
pub const CLIENT_INFLIGHT_QUOTA: u64 = 262_144;

/// Longest JSON line a client may send (a `batch` of jobs fits in far
/// less; past this is a protocol error, not an allocation).
const MAX_LINE_LEN: usize = 16 << 20;

/// Outbound bytes buffered per connection before the reactor drops it
/// as a slow consumer (its memory, not ours, is the resource at risk).
const MAX_OUT_BUF: usize = 8 << 20;

/// Reactor sleep when a tick made no progress (no readable socket, no
/// resolvable pending, nothing to flush).
const IDLE_SLEEP: Duration = Duration::from_millis(1);

/// Sizing for [`serve_config`]. Defaults match the historical server:
/// auto workers, queue of 64, 8 streams, 8 cached contexts, 2 stream
/// drain workers.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Search worker threads (0 = auto via `ExecPolicy`).
    pub workers: usize,
    /// Job queue bound.
    pub capacity: usize,
    /// Stream registry cap (`--max-streams`).
    pub max_streams: usize,
    /// Prepared-context LRU size (`--ctx-cache`).
    pub ctx_cache: usize,
    /// Stream drain workers (`--stream-workers`; 0 = inline JSON
    /// appends and no binary-frame draining).
    pub stream_workers: usize,
    /// Warm-state directory (`--snapshot-dir`): restored on boot,
    /// saved on shutdown, and the default `dir` of the
    /// `snapshot_save`/`snapshot_restore` commands. `None` = no
    /// durability (the historical behavior).
    pub snapshot_dir: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        let c = CoordinatorConfig::default();
        ServeConfig {
            workers: 0,
            capacity: 64,
            max_streams: c.max_streams,
            ctx_cache: c.ctx_cache,
            stream_workers: c.stream_workers,
            snapshot_dir: None,
        }
    }
}

/// Serve until a `shutdown` command arrives, with default sizing.
/// Returns the bound local address through `on_bound` (use port 0 to
/// pick a free port).
pub fn serve<A: ToSocketAddrs>(
    addr: A,
    n_workers: usize,
    capacity: usize,
    on_bound: impl FnOnce(SocketAddr),
) -> Result<()> {
    serve_config(
        addr,
        ServeConfig {
            workers: n_workers,
            capacity,
            ..ServeConfig::default()
        },
        on_bound,
    )
}

/// Serve with explicit sizing (see [`ServeConfig`]). The calling thread
/// becomes the reactor; it returns after a `shutdown` command has been
/// answered and flushed.
pub fn serve_config<A: ToSocketAddrs>(
    addr: A,
    cfg: ServeConfig,
    on_bound: impl FnOnce(SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).context("binding service socket")?;
    listener
        .set_nonblocking(true)
        .context("making service socket nonblocking")?;
    on_bound(listener.local_addr()?);
    let coord = Coordinator::start_config(CoordinatorConfig {
        workers: cfg.workers,
        capacity: cfg.capacity,
        max_streams: cfg.max_streams,
        ctx_cache: cfg.ctx_cache,
        stream_workers: cfg.stream_workers,
    });
    if let Some(dir) = &cfg.snapshot_dir {
        // boot restore is best-effort: a missing directory is an empty
        // restore, but a corrupt file must not block serving — report
        // it and start cold (the file stays on disk for inspection)
        match coord.snapshot_restore(dir) {
            Ok(r) if r.contexts + r.monitors > 0 => eprintln!(
                "restored {} context(s), {} stream(s) from {}",
                r.contexts,
                r.monitors,
                dir.display()
            ),
            Ok(_) => {}
            Err(e) => eprintln!(
                "warning: snapshot restore from {} failed ({e:#}); \
                 starting cold",
                dir.display()
            ),
        }
    }
    reactor(listener, coord, cfg.snapshot_dir)
}

/// One reply owed to a connection, in request order.
enum ReplySlot {
    /// Computed; flushes as soon as every earlier slot has.
    Ready(Json),
    /// Parked; polled each tick until it resolves.
    Pending(Pending),
}

/// The three commands the reactor parks instead of blocking on.
enum Pending {
    /// `wait`: resolves when the job reaches a terminal state (or the
    /// deadline passes → live state + `timed_out`).
    Wait {
        job: u64,
        deadline: Option<Instant>,
    },
    /// `subscribe`: resolves when the stream's refresh counter passes
    /// `after` (or the deadline passes → `timed_out`).
    Subscribe {
        stream: String,
        after: u64,
        deadline: Option<Instant>,
    },
    /// `append` offloaded to a stream drain worker; the worker answers
    /// on the channel.
    Append {
        stream: String,
        appended: usize,
        rx: mpsc::Receiver<Result<Vec<Json>, String>>,
    },
}

/// Per-connection reactor state.
struct Conn {
    sock: TcpStream,
    /// Unparsed inbound bytes (at most one incomplete message after a
    /// parse pass — both message kinds are length-capped).
    buf: Vec<u8>,
    /// Outbound bytes not yet accepted by the socket.
    out: Vec<u8>,
    out_pos: usize,
    replies: VecDeque<ReplySlot>,
    /// `hello` negotiated — binary frames accepted.
    frames_on: bool,
    /// Points accepted into stream queues on behalf of this connection
    /// and not yet drained (the `client_quota` bound).
    in_flight: Arc<AtomicU64>,
    /// No more reads; drop once every owed reply has flushed.
    closing: bool,
    /// Drop now (EOF, io error, slow consumer).
    dead: bool,
}

impl Conn {
    fn new(sock: TcpStream) -> Conn {
        Conn {
            sock,
            buf: Vec::new(),
            out: Vec::new(),
            out_pos: 0,
            replies: VecDeque::new(),
            frames_on: false,
            in_flight: Arc::new(AtomicU64::new(0)),
            closing: false,
            dead: false,
        }
    }

    fn push_ready(&mut self, reply: Json) {
        self.replies.push_back(ReplySlot::Ready(reply));
    }

    /// Queue an error reply and stop reading: protocol errors (bad
    /// frame, oversized line, frame before hello) end the connection,
    /// but only after the client has been told why.
    fn protocol_error(&mut self, msg: &str) {
        self.push_ready(err_reply(msg));
        self.closing = true;
    }

    fn pending_count(&self) -> usize {
        self.replies
            .iter()
            .filter(|s| matches!(s, ReplySlot::Pending(_)))
            .count()
    }
}

/// Reactor-level gauges the `stats` command reports (snapshotted at the
/// top of the tick that dispatches it).
#[derive(Clone, Copy)]
struct ReactorSnapshot {
    conns: usize,
    pending: usize,
}

/// The reactor loop: accept, read/parse/dispatch, resolve pendings,
/// flush, reap dead connections — then sleep only if nothing moved.
fn reactor(
    listener: TcpListener,
    coord: Coordinator,
    snapshot_dir: Option<PathBuf>,
) -> Result<()> {
    let stop = AtomicBool::new(false);
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        let mut progressed = false;
        loop {
            match listener.accept() {
                Ok((sock, _peer)) => {
                    if sock.set_nonblocking(true).is_ok() {
                        conns.push(Conn::new(sock));
                        progressed = true;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => break, // transient accept failure: retry next tick
            }
        }
        let snap = ReactorSnapshot {
            conns: conns.len(),
            pending: conns.iter().map(Conn::pending_count).sum(),
        };
        for conn in conns.iter_mut() {
            progressed |=
                service_reads(conn, &coord, &stop, snap, snapshot_dir.as_deref());
        }
        for conn in conns.iter_mut() {
            progressed |= resolve_pendings(conn, &coord);
        }
        for conn in conns.iter_mut() {
            progressed |= flush(conn);
        }
        conns.retain(|c| !c.dead);
        if stop.load(Ordering::SeqCst) {
            // best-effort: give every connection a moment to take its
            // final replies (the `bye`), then tear down
            let deadline = Instant::now() + Duration::from_secs(2);
            while Instant::now() < deadline {
                for conn in conns.iter_mut() {
                    resolve_pendings(conn, &coord);
                    flush(conn);
                }
                conns.retain(|c| !c.dead);
                if conns.iter().all(|c| c.out.is_empty()) {
                    break;
                }
                std::thread::sleep(IDLE_SLEEP);
            }
            break;
        }
        if !progressed {
            std::thread::sleep(IDLE_SLEEP);
        }
    }
    drop(conns);
    drop(listener);
    if let Some(dir) = &snapshot_dir {
        // save-on-shutdown: warm state survives the restart; a failed
        // save loses warmth, never correctness, so report and proceed
        match coord.snapshot_save(dir) {
            Ok(r) => eprintln!(
                "saved {} context(s), {} stream(s) to {}",
                r.contexts,
                r.monitors,
                dir.display()
            ),
            Err(e) => eprintln!(
                "warning: snapshot save to {} failed ({e:#})",
                dir.display()
            ),
        }
    }
    coord.shutdown();
    Ok(())
}

/// Read everything the socket has, then parse message-by-message:
/// magic byte → binary frame, otherwise → JSON line.
fn service_reads(
    conn: &mut Conn,
    coord: &Coordinator,
    stop: &AtomicBool,
    snap: ReactorSnapshot,
    snap_dir: Option<&Path>,
) -> bool {
    if conn.dead || conn.closing {
        return false;
    }
    let mut progressed = false;
    let mut tmp = [0u8; 64 * 1024];
    loop {
        match conn.sock.read(&mut tmp) {
            Ok(0) => {
                // peer closed: drop the connection and, with it, every
                // pending slot (a mid-`subscribe` disconnect frees its
                // reply slot this tick, not at some timeout)
                conn.dead = true;
                break;
            }
            Ok(n) => {
                conn.buf.extend_from_slice(&tmp[..n]);
                progressed = true;
                if n < tmp.len() {
                    break;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    while !conn.dead && !conn.closing && !conn.buf.is_empty() {
        if conn.buf[0] == frame::MAGIC[0] {
            match frame::decode(&conn.buf) {
                Ok(f) => {
                    let header = f.header;
                    let payload = f.payload.to_vec();
                    conn.buf.drain(..frame::HEADER_LEN + header.payload_len);
                    handle_frame(conn, header, payload, coord);
                    progressed = true;
                }
                // an incomplete frame is not an error yet — the header
                // already validated the length cap, so waiting for the
                // rest can never over-allocate
                Err(frame::FrameError::Truncated { .. }) => break,
                Err(e) => {
                    conn.protocol_error(&format!("bad frame: {e}"));
                    progressed = true;
                }
            }
        } else {
            let Some(nl) = conn.buf.iter().position(|&b| b == b'\n') else {
                if conn.buf.len() > MAX_LINE_LEN {
                    conn.protocol_error(&format!(
                        "request line exceeds {MAX_LINE_LEN} bytes"
                    ));
                    progressed = true;
                }
                break;
            };
            let line: Vec<u8> = conn.buf.drain(..=nl).collect();
            progressed = true;
            match std::str::from_utf8(&line[..line.len() - 1]) {
                Err(_) => conn.push_ready(err_reply(
                    "request line is not valid UTF-8",
                )),
                Ok(s) if s.trim().is_empty() => {}
                Ok(s) => match dispatch(s.trim(), coord, stop, snap, snap_dir) {
                    Disposition::Reply(j) => conn.push_ready(j),
                    Disposition::Hello(j) => {
                        conn.frames_on = true;
                        conn.push_ready(j);
                    }
                    Disposition::Pend(p) => {
                        conn.replies.push_back(ReplySlot::Pending(p))
                    }
                },
            }
        }
    }
    progressed
}

/// One complete inbound binary frame. `data` is fire-and-forget ingest:
/// accepted → silence, shed → a binary `shed` frame back out-of-band.
fn handle_frame(
    conn: &mut Conn,
    header: FrameHeader,
    payload: Vec<u8>,
    coord: &Coordinator,
) {
    if !conn.frames_on {
        conn.protocol_error(
            "binary frame before `hello` — negotiate with \
             {\"cmd\":\"hello\",\"version\":1} first",
        );
        return;
    }
    match header.kind {
        FrameKind::Shed => {
            conn.protocol_error("frame kind `shed` is server-to-client only")
        }
        FrameKind::Data => {
            let outcome = coord.streams().enqueue_data(
                header.stream_id,
                payload,
                Some((&conn.in_flight, CLIENT_INFLIGHT_QUOTA)),
            );
            if let Enqueue::Shed { reason, dropped } = outcome {
                conn.out.extend_from_slice(&frame::encode_shed(
                    header.stream_id,
                    dropped.min(u32::MAX as usize) as u32,
                    reason,
                ));
            }
        }
    }
}

/// Try to resolve every parked reply slot; each tick costs one cheap
/// status/poll per pending, never a blocking wait.
fn resolve_pendings(conn: &mut Conn, coord: &Coordinator) -> bool {
    let mut progressed = false;
    for slot in conn.replies.iter_mut() {
        if let ReplySlot::Pending(p) = slot {
            if let Some(reply) = poll_pending(p, coord) {
                *slot = ReplySlot::Ready(reply);
                progressed = true;
            }
        }
    }
    progressed
}

fn poll_pending(p: &mut Pending, coord: &Coordinator) -> Option<Json> {
    match p {
        Pending::Wait { job, deadline } => {
            let id = *job;
            match coord.status(id) {
                None => Some(err_reply("no such job")),
                Some(JobState::Done(report)) => Some(
                    Json::obj()
                        .set("ok", true)
                        .set("job", id)
                        .set("state", "done")
                        .set("report", report),
                ),
                Some(JobState::Failed(msg)) => Some(
                    Json::obj()
                        .set("ok", false)
                        .set("job", id)
                        .set("state", "failed")
                        .set("error", msg),
                ),
                // still queued/running: report the live state once the
                // deadline passes instead of pinning the slot forever
                Some(st) => {
                    if deadline.is_some_and(|d| Instant::now() >= d) {
                        Some(
                            Json::obj()
                                .set("ok", true)
                                .set("job", id)
                                .set("state", st.label())
                                .set("timed_out", true),
                        )
                    } else {
                        None
                    }
                }
            }
        }
        Pending::Subscribe {
            stream,
            after,
            deadline,
        } => match coord.streams().poll(stream, *after) {
            Err(e) => Some(err_reply(&format!("{e:#}"))),
            Ok(Some((seq, update))) => Some(
                Json::obj()
                    .set("ok", true)
                    .set("stream", stream.as_str())
                    .set("seq", seq)
                    .set("update", update),
            ),
            Ok(None) => {
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    Some(
                        Json::obj()
                            .set("ok", true)
                            .set("stream", stream.as_str())
                            .set("timed_out", true),
                    )
                } else {
                    None
                }
            }
        },
        Pending::Append {
            stream,
            appended,
            rx,
        } => match rx.try_recv() {
            Ok(Ok(updates)) => Some(
                Json::obj()
                    .set("ok", true)
                    .set("stream", stream.as_str())
                    .set("appended", *appended)
                    .set("updates", updates),
            ),
            Ok(Err(msg)) => Some(err_reply(&msg)),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(err_reply("stream worker dropped the append"))
            }
        },
    }
}

/// Move ready replies (in order, stopping at the first still-pending
/// slot) into the outbound buffer, then write what the socket takes.
fn flush(conn: &mut Conn) -> bool {
    if conn.dead {
        return false;
    }
    while matches!(conn.replies.front(), Some(ReplySlot::Ready(_))) {
        if let Some(ReplySlot::Ready(j)) = conn.replies.pop_front() {
            conn.out.extend_from_slice(j.to_string().as_bytes());
            conn.out.push(b'\n');
        }
    }
    if conn.out.len() - conn.out_pos > MAX_OUT_BUF {
        // slow consumer: shed the connection, not server memory
        conn.dead = true;
        return true;
    }
    let mut progressed = false;
    while conn.out_pos < conn.out.len() {
        match conn.sock.write(&conn.out[conn.out_pos..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => {
                conn.out_pos += n;
                progressed = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    if conn.out_pos == conn.out.len() {
        conn.out.clear();
        conn.out_pos = 0;
        if conn.closing && conn.replies.is_empty() {
            conn.dead = true;
        }
    }
    progressed
}

/// Every `cmd` the dispatcher accepts, in `docs/PROTOCOL.md` order.
/// `tests/docs_consistency.rs` asserts the protocol document covers each
/// of these, so the list and the doc cannot drift apart.
pub const COMMANDS: [&str; 17] = [
    "hello",
    "submit",
    "batch",
    "mdim",
    "vl",
    "status",
    "wait",
    "stats",
    "metrics",
    "list",
    "stream_open",
    "append",
    "subscribe",
    "stream_close",
    "snapshot_save",
    "snapshot_restore",
    "shutdown",
];

fn err_reply(msg: &str) -> Json {
    Json::obj().set("ok", false).set("error", msg)
}

/// Reject requests carrying fields outside `known` — applied to every
/// command (same strictness as the job parser: a typo must fail loudly,
/// not silently change the request; `{"cmd":"wait","timout_ms":250}`
/// must not block forever).
fn check_fields(req: &Json, known: &[&str]) -> Result<(), Json> {
    if let Json::Obj(map) = req {
        if let Some(bad) = map.keys().find(|k| !known.contains(&k.as_str())) {
            return Err(err_reply(&format!(
                "unknown field `{bad}` (known: {})",
                known.join(", ")
            )));
        }
    }
    Ok(())
}

/// Resolve the directory a `snapshot_save`/`snapshot_restore` request
/// targets: an explicit `dir` field (which, being network-supplied,
/// must stay **inside the service working directory** — relative, no
/// `..` — the same containment `file:` datasets get), else the
/// operator's `--snapshot-dir`.
fn resolve_snapshot_dir(
    req: &Json,
    configured: Option<&Path>,
) -> Result<PathBuf, Json> {
    match req.get("dir") {
        Some(d) => {
            let Some(s) = d.as_str() else {
                return Err(err_reply("field `dir` must be a string"));
            };
            let p = Path::new(s);
            if p.as_os_str().is_empty()
                || p.is_absolute()
                || p.components()
                    .any(|c| !matches!(c, Component::Normal(_) | Component::CurDir))
            {
                return Err(err_reply(
                    "field `dir` must be a relative path inside the \
                     service working directory (no absolute paths, no `..`)",
                ));
            }
            Ok(p.to_path_buf())
        }
        None => match configured {
            Some(d) => Ok(d.to_path_buf()),
            None => Err(err_reply(
                "no snapshot directory: pass `dir` or start the server \
                 with `--snapshot-dir`",
            )),
        },
    }
}

/// The `stream` field every streaming command addresses a monitor by.
fn stream_name(req: &Json) -> Result<&str, Json> {
    req.get("stream")
        .and_then(|s| s.as_str())
        .ok_or_else(|| err_reply("field `stream` (string) required"))
}

/// What one dispatched request does to its connection.
enum Disposition {
    /// Answer now.
    Reply(Json),
    /// Answer now *and* enable binary frames on this connection.
    Hello(Json),
    /// Park a pending reply slot; the reactor resolves it later.
    Pend(Pending),
}

fn reply(j: Json) -> Disposition {
    Disposition::Reply(j)
}

fn dispatch(
    line: &str,
    coord: &Coordinator,
    stop: &AtomicBool,
    snap: ReactorSnapshot,
    snap_dir: Option<&Path>,
) -> Disposition {
    let req = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return reply(err_reply(&format!("bad json: {e}"))),
    };
    match req.get("cmd").and_then(|c| c.as_str()) {
        Some("hello") => {
            if let Err(e) = check_fields(&req, &["cmd", "version"]) {
                return reply(e);
            }
            let version = match req.get("version") {
                None => frame::FRAME_VERSION as u64,
                Some(v) => match v.as_u64() {
                    Some(v) => v,
                    None => {
                        return reply(err_reply(
                            "field `version` must be an integer",
                        ))
                    }
                },
            };
            if version != frame::FRAME_VERSION as u64 {
                return reply(err_reply(&format!(
                    "unsupported frame `version` {version} (this server \
                     speaks {})",
                    frame::FRAME_VERSION
                )));
            }
            Disposition::Hello(
                Json::obj().set("ok", true).set(
                    "frames",
                    Json::obj()
                        .set("version", frame::FRAME_VERSION as u64)
                        .set(
                            "magic",
                            vec![
                                Json::from(frame::MAGIC[0] as u64),
                                Json::from(frame::MAGIC[1] as u64),
                            ],
                        )
                        .set("header_len", frame::HEADER_LEN)
                        .set("max_points", frame::MAX_FRAME_POINTS),
                ),
            )
        }
        Some("submit") => match JobSpec::from_json(&req) {
            Ok(spec) => match coord.submit(spec) {
                Ok(id) => reply(Json::obj().set("ok", true).set("job", id)),
                Err(e) => reply(err_reply(&format!("{e:#}"))),
            },
            Err(e) => reply(err_reply(&e)),
        },
        Some("mdim") => match super::coordinator::MdimJobSpec::from_json(&req) {
            Ok(spec) => match coord.submit_mdim(spec) {
                Ok(id) => reply(Json::obj().set("ok", true).set("job", id)),
                Err(e) => reply(err_reply(&format!("{e:#}"))),
            },
            Err(e) => reply(err_reply(&e)),
        },
        Some("vl") => match super::coordinator::VlJobSpec::from_json(&req) {
            Ok(spec) => match coord.submit_vl(spec) {
                Ok(id) => reply(Json::obj().set("ok", true).set("job", id)),
                Err(e) => reply(err_reply(&format!("{e:#}"))),
            },
            Err(e) => reply(err_reply(&e)),
        },
        Some("status") => {
            if let Err(e) = check_fields(&req, &["cmd", "job"]) {
                return reply(e);
            }
            let Some(id) = req.get("job").and_then(|j| j.as_u64()) else {
                return reply(err_reply("field `job` required"));
            };
            match coord.status(id) {
                None => reply(err_reply("no such job")),
                Some(st) => {
                    let mut out = Json::obj()
                        .set("ok", true)
                        .set("job", id)
                        .set("state", st.label());
                    match st {
                        JobState::Done(report) => out = out.set("report", report),
                        JobState::Failed(msg) => out = out.set("error", msg),
                        _ => {}
                    }
                    reply(out)
                }
            }
        }
        Some("batch") => {
            if let Err(e) = check_fields(&req, &["cmd", "jobs"]) {
                return reply(e);
            }
            let Some(jobs) = req.get("jobs").and_then(|j| j.as_arr()) else {
                return reply(err_reply("field `jobs` (array) required"));
            };
            let mut specs = Vec::with_capacity(jobs.len());
            for (i, job) in jobs.iter().enumerate() {
                match JobSpec::from_json(job) {
                    Ok(spec) => specs.push(spec),
                    Err(e) => return reply(err_reply(&format!("jobs[{i}]: {e}"))),
                }
            }
            match coord.submit_batch(specs) {
                Ok(ids) => reply(Json::obj().set("ok", true).set(
                    "jobs",
                    ids.into_iter().map(Json::from).collect::<Vec<_>>(),
                )),
                Err(e) => reply(err_reply(&format!("{e:#}"))),
            }
        }
        Some("wait") => {
            if let Err(e) = check_fields(&req, &["cmd", "job", "timeout_ms"]) {
                return reply(e);
            }
            let Some(id) = req.get("job").and_then(|j| j.as_u64()) else {
                return reply(err_reply("field `job` required"));
            };
            let deadline = match req.get("timeout_ms") {
                None => None,
                Some(t) => match t.as_u64() {
                    Some(ms) => {
                        Some(Instant::now() + Duration::from_millis(ms))
                    }
                    None => {
                        return reply(err_reply(
                            "field `timeout_ms` must be an integer",
                        ))
                    }
                },
            };
            // parked, not blocked: the reactor polls the job each tick
            Disposition::Pend(Pending::Wait { job: id, deadline })
        }
        Some("stats") => {
            if let Err(e) = check_fields(&req, &["cmd"]) {
                return reply(e);
            }
            let st = coord.stats();
            let ing = coord.streams().ingest_stats();
            reply(
                Json::obj()
                    .set("ok", true)
                    .set("queued", st.queued)
                    .set("running", st.running)
                    .set("workers", st.workers)
                    .set("jobs_total", st.jobs_total)
                    .set("queue_capacity", st.queue_capacity)
                    .set("ctx_cache_entries", st.ctx_cache_entries)
                    .set("streams", st.streams)
                    .set("conns", snap.conns)
                    .set("pending", snap.pending)
                    .set("frames_rx", ing.frames_rx)
                    .set("points_rx", ing.points_rx)
                    .set("frames_shed", ing.frames_shed)
                    .set("stream_queue_points", ing.queued_points)
                    .set("snapshot_saves", st.snapshot_saves)
                    .set("snapshot_restores", st.snapshot_restores)
                    .set(
                        "snapshot_contexts_restored",
                        st.snapshot_contexts_restored,
                    )
                    .set(
                        "snapshot_streams_restored",
                        st.snapshot_streams_restored,
                    )
                    .set(
                        "snapshot_profiles_seeded",
                        st.snapshot_profiles_seeded,
                    ),
            )
        }
        Some("metrics") => {
            if let Err(e) = check_fields(&req, &["cmd", "format"]) {
                return reply(e);
            }
            let format = match req.get("format").map(|f| f.as_str()) {
                None | Some(Some("json")) => "json",
                Some(Some("prometheus")) => "prometheus",
                _ => {
                    return reply(err_reply(
                        "field `format` must be \"json\" or \"prometheus\"",
                    ))
                }
            };
            // sync_registry refreshes the gauges and absorbs the stream
            // ingest counters, so both formats expose one coherent view
            let snapshot = coord.sync_registry().snapshot();
            reply(match format {
                "prometheus" => Json::obj()
                    .set("ok", true)
                    .set("format", "prometheus")
                    .set("body", snapshot.to_prometheus()),
                _ => Json::obj()
                    .set("ok", true)
                    .set("format", "json")
                    .set("metrics", snapshot.to_json()),
            })
        }
        Some("list") => {
            if let Err(e) = check_fields(&req, &["cmd"]) {
                return reply(e);
            }
            let jobs: Vec<Json> = coord
                .list()
                .into_iter()
                .map(|(id, st)| Json::obj().set("job", id).set("state", st))
                .collect();
            reply(Json::obj().set("ok", true).set("jobs", jobs))
        }
        Some("stream_open") => {
            if let Err(e) = check_fields(
                &req,
                &["cmd", "stream", "params", "window", "refresh_every"],
            ) {
                return reply(e);
            }
            let name = match stream_name(&req) {
                Ok(n) => n,
                Err(e) => return reply(e),
            };
            let params = match req.get("params") {
                Some(p) => match SearchParams::from_json(p) {
                    Ok(p) => p,
                    Err(e) => return reply(err_reply(&e)),
                },
                None => return reply(err_reply("field `params` required")),
            };
            let Some(window) = req.get("window").and_then(|w| w.as_u64()) else {
                return reply(err_reply(
                    "field `window` (points, integer) required",
                ));
            };
            let refresh_every = match req.get("refresh_every") {
                None => 0,
                Some(r) => match r.as_u64() {
                    Some(r) => r as usize,
                    None => {
                        return reply(err_reply(
                            "field `refresh_every` must be an integer",
                        ))
                    }
                },
            };
            match coord
                .streams()
                .open(name, params, window as usize, refresh_every)
            {
                Ok(id) => reply(
                    Json::obj()
                        .set("ok", true)
                        .set("stream", name)
                        .set("stream_id", id as u64),
                ),
                Err(e) => reply(err_reply(&format!("{e:#}"))),
            }
        }
        Some("append") => {
            if let Err(e) = check_fields(&req, &["cmd", "stream", "points"]) {
                return reply(e);
            }
            let name = match stream_name(&req) {
                Ok(n) => n,
                Err(e) => return reply(e),
            };
            let Some(raw) = req.get("points").and_then(|p| p.as_arr()) else {
                return reply(err_reply(
                    "field `points` (array of numbers) required",
                ));
            };
            let mut points = Vec::with_capacity(raw.len());
            for (i, v) in raw.iter().enumerate() {
                match v.as_f64() {
                    Some(x) => points.push(x),
                    None => {
                        return reply(err_reply(&format!(
                            "points[{i}] is not a number"
                        )))
                    }
                }
            }
            // offload to a drain worker when one exists so a long
            // refresh never stalls the reactor; inline otherwise — both
            // run the exact same monitor code, so replies are identical
            if coord.streams().has_workers() {
                let appended = points.len();
                match coord.streams().submit_json_append(name, points) {
                    Ok(rx) => Disposition::Pend(Pending::Append {
                        stream: name.to_string(),
                        appended,
                        rx,
                    }),
                    Err(e) => reply(err_reply(&format!("{e:#}"))),
                }
            } else {
                match coord.streams().append(name, &points) {
                    Ok(updates) => reply(
                        Json::obj()
                            .set("ok", true)
                            .set("stream", name)
                            .set("appended", points.len())
                            .set("updates", updates),
                    ),
                    Err(e) => reply(err_reply(&format!("{e:#}"))),
                }
            }
        }
        Some("subscribe") => {
            if let Err(e) =
                check_fields(&req, &["cmd", "stream", "after", "timeout_ms"])
            {
                return reply(e);
            }
            let name = match stream_name(&req) {
                Ok(n) => n,
                Err(e) => return reply(e),
            };
            let after = match req.get("after") {
                None => 0,
                Some(a) => match a.as_u64() {
                    Some(a) => a,
                    None => {
                        return reply(err_reply(
                            "field `after` must be an integer",
                        ))
                    }
                },
            };
            let deadline = match req.get("timeout_ms") {
                None => None,
                Some(t) => match t.as_u64() {
                    Some(ms) => {
                        Some(Instant::now() + Duration::from_millis(ms))
                    }
                    None => {
                        return reply(err_reply(
                            "field `timeout_ms` must be an integer",
                        ))
                    }
                },
            };
            // parked, not blocked: no thread pins per idle subscriber
            Disposition::Pend(Pending::Subscribe {
                stream: name.to_string(),
                after,
                deadline,
            })
        }
        Some("stream_close") => {
            if let Err(e) = check_fields(&req, &["cmd", "stream"]) {
                return reply(e);
            }
            let name = match stream_name(&req) {
                Ok(n) => n,
                Err(e) => return reply(e),
            };
            match coord.streams().close(name) {
                Ok(()) => reply(
                    Json::obj()
                        .set("ok", true)
                        .set("stream", name)
                        .set("closed", true),
                ),
                Err(e) => reply(err_reply(&format!("{e:#}"))),
            }
        }
        Some("snapshot_save") => {
            if let Err(e) = check_fields(&req, &["cmd", "dir"]) {
                return reply(e);
            }
            let dir = match resolve_snapshot_dir(&req, snap_dir) {
                Ok(d) => d,
                Err(e) => return reply(e),
            };
            match coord.snapshot_save(&dir) {
                Ok(r) => reply(r.to_json().set("ok", true)),
                Err(e) => reply(err_reply(&format!("{e:#}"))),
            }
        }
        Some("snapshot_restore") => {
            if let Err(e) = check_fields(&req, &["cmd", "dir"]) {
                return reply(e);
            }
            let dir = match resolve_snapshot_dir(&req, snap_dir) {
                Ok(d) => d,
                Err(e) => return reply(e),
            };
            match coord.snapshot_restore(&dir) {
                Ok(r) => reply(r.to_json().set("ok", true)),
                Err(e) => reply(err_reply(&format!("{e:#}"))),
            }
        }
        Some("shutdown") => {
            if let Err(e) = check_fields(&req, &["cmd"]) {
                return reply(e);
            }
            stop.store(true, Ordering::SeqCst);
            reply(Json::obj().set("ok", true).set("bye", true))
        }
        _ => reply(err_reply(&format!(
            "unknown cmd (expected one of: {})",
            COMMANDS.join("|")
        ))),
    }
}

/// A `shed` frame the server sent this client (one of its `data`
/// frames was dropped).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShedNotice {
    /// Stream the dropped frame addressed.
    pub stream_id: u32,
    /// Points it carried.
    pub dropped: u32,
    /// Why it was dropped.
    pub reason: ShedReason,
}

/// Blocking client for the protocol: JSON lines for commands, binary
/// frames for stream ingest after [`hello`](Self::hello). Inbound
/// `shed` frames are collected into a side buffer
/// ([`take_sheds`](Self::take_sheds)) so they never corrupt a
/// command/reply exchange.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    sheds: Vec<ShedNotice>,
}

impl Client {
    /// Connect to a running service.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to service")?;
        let writer = stream.try_clone()?;
        Ok(Client {
            writer,
            reader: BufReader::new(stream),
            sheds: Vec::new(),
        })
    }

    /// Read the next JSON reply, absorbing any binary `shed` frames
    /// that arrive in between.
    fn read_reply(&mut self) -> Result<Json> {
        loop {
            let first = {
                let buf = self.reader.fill_buf()?;
                if buf.is_empty() {
                    anyhow::bail!("server closed the connection");
                }
                buf[0]
            };
            if first == frame::MAGIC[0] {
                let mut header = [0u8; frame::HEADER_LEN];
                self.reader.read_exact(&mut header)?;
                let h = frame::decode_header(&header)
                    .map_err(|e| anyhow::anyhow!("bad frame from server: {e}"))?;
                let mut payload = vec![0u8; h.payload_len];
                self.reader.read_exact(&mut payload)?;
                if h.kind == FrameKind::Shed {
                    if let Some((dropped, reason)) =
                        frame::decode_shed_payload(&payload)
                    {
                        self.sheds.push(ShedNotice {
                            stream_id: h.stream_id,
                            dropped,
                            reason,
                        });
                    }
                }
                continue;
            }
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            return Json::parse(&line)
                .map_err(|e| anyhow::anyhow!("bad reply: {e}"));
        }
    }

    /// Send one request, read one reply.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{req}")?;
        self.read_reply()
    }

    /// Negotiate binary framing (the versioned `hello`); returns the
    /// server's frame parameters. Must precede any
    /// [`send_points`](Self::send_points).
    pub fn hello(&mut self) -> Result<Json> {
        let reply = self.call(
            &Json::obj()
                .set("cmd", "hello")
                .set("version", frame::FRAME_VERSION as u64),
        )?;
        if reply.get("ok").and_then(|b| b.as_bool()) != Some(true) {
            anyhow::bail!(
                "hello rejected: {}",
                reply.get("error").and_then(|e| e.as_str()).unwrap_or("?")
            );
        }
        Ok(reply)
    }

    /// Open a stream; returns the numeric id `data` frames address it by.
    pub fn open_stream(
        &mut self,
        name: &str,
        params: Json,
        window: usize,
        refresh_every: usize,
    ) -> Result<u32> {
        let reply = self.call(
            &Json::obj()
                .set("cmd", "stream_open")
                .set("stream", name)
                .set("params", params)
                .set("window", window)
                .set("refresh_every", refresh_every),
        )?;
        if reply.get("ok").and_then(|b| b.as_bool()) != Some(true) {
            anyhow::bail!(
                "stream_open rejected: {}",
                reply.get("error").and_then(|e| e.as_str()).unwrap_or("?")
            );
        }
        reply
            .get("stream_id")
            .and_then(|i| i.as_u64())
            .map(|i| i as u32)
            .context("reply missing stream_id")
    }

    /// Send points as binary `data` frames (chunked to the frame cap),
    /// fire-and-forget: accepted points produce no reply; drops arrive
    /// later as `shed` notices (see [`take_sheds`](Self::take_sheds)).
    pub fn send_points(&mut self, stream_id: u32, points: &[f64]) -> Result<()> {
        for chunk in points.chunks(frame::MAX_FRAME_POINTS.max(1)) {
            self.writer.write_all(&frame::encode_data(stream_id, chunk))?;
        }
        Ok(())
    }

    /// `shed` notices absorbed so far (cleared by this call).
    pub fn take_sheds(&mut self) -> Vec<ShedNotice> {
        std::mem::take(&mut self.sheds)
    }

    /// JSON-path append (the text twin of [`send_points`]).
    pub fn append(&mut self, stream: &str, points: &[f64]) -> Result<Json> {
        self.call(
            &Json::obj()
                .set("cmd", "append")
                .set("stream", stream)
                .set(
                    "points",
                    points.iter().copied().map(Json::from).collect::<Vec<_>>(),
                ),
        )
    }

    /// Wait (server-side) for the refresh after `after`; `timeout_ms`
    /// bounds the wait.
    pub fn subscribe(
        &mut self,
        stream: &str,
        after: u64,
        timeout_ms: u64,
    ) -> Result<Json> {
        self.call(
            &Json::obj()
                .set("cmd", "subscribe")
                .set("stream", stream)
                .set("after", after)
                .set("timeout_ms", timeout_ms),
        )
    }

    /// Submit a prepared request object; returns the job id.
    pub fn submit(&mut self, spec_json: Json) -> Result<u64> {
        let reply = self.call(&spec_json)?;
        if reply.get("ok").and_then(|b| b.as_bool()) != Some(true) {
            anyhow::bail!(
                "submit rejected: {}",
                reply.get("error").and_then(|e| e.as_str()).unwrap_or("?")
            );
        }
        reply
            .get("job")
            .and_then(|j| j.as_u64())
            .context("reply missing job id")
    }

    /// Block until `job` reaches a terminal state; returns the reply.
    pub fn wait(&mut self, job: u64) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "wait").set("job", job))
    }

    /// Wait at most `timeout_ms` for `job`; on expiry the reply carries
    /// the job's live state (`"queued"`/`"running"`) and
    /// `timed_out: true`.
    pub fn wait_timeout(&mut self, job: u64, timeout_ms: u64) -> Result<Json> {
        self.call(
            &Json::obj()
                .set("cmd", "wait")
                .set("job", job)
                .set("timeout_ms", timeout_ms),
        )
    }

    /// Submit a job array in one atomic request; returns the job ids.
    pub fn submit_batch(&mut self, jobs: Vec<Json>) -> Result<Vec<u64>> {
        let reply =
            self.call(&Json::obj().set("cmd", "batch").set("jobs", jobs))?;
        if reply.get("ok").and_then(|b| b.as_bool()) != Some(true) {
            anyhow::bail!(
                "batch rejected: {}",
                reply.get("error").and_then(|e| e.as_str()).unwrap_or("?")
            );
        }
        reply
            .get("jobs")
            .and_then(|j| j.as_arr())
            .map(|ids| ids.iter().filter_map(|j| j.as_u64()).collect())
            .context("reply missing job ids")
    }

    /// Fetch the service's observability snapshot (`cmd: "stats"`).
    pub fn stats(&mut self) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "stats"))
    }

    /// Ask the service to stop accepting connections and drain.
    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.call(&Json::obj().set("cmd", "shutdown"))?;
        Ok(())
    }
}
