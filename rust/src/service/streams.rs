//! Named streaming monitors served over the JSON-lines protocol.
//!
//! The [`Coordinator`](super::Coordinator) keeps a [`StreamRegistry`]
//! alongside its prepared-context LRU: each open stream is one
//! [`StreamingMonitor`] behind a mutex, with a condvar so `subscribe`
//! requests can block until the next refresh publishes an update. The
//! registry is bounded (like the job queue and the context LRU) so a
//! client cannot grow server memory without bound; `stream_open` rejects
//! with a backpressure error when it is full.
//!
//! Protocol commands (`stream_open` / `append` / `subscribe` /
//! `stream_close`) are documented with worked examples in
//! `docs/PROTOCOL.md` at the repository root.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::SearchParams;
use crate::stream::StreamingMonitor;
use crate::util::json::Json;

/// Streams one coordinator will hold open at once (each holds a window of
/// points plus per-sequence state, so the cap bounds memory).
pub const STREAM_REGISTRY_CAPACITY: usize = 8;

/// Largest window (in points) a single stream may request. Per-point
/// state is ~100 bytes (window point + rolling stats + SAX word + profile
/// entry), so this caps one stream at roughly 100 MB — and, with
/// [`STREAM_REGISTRY_CAPACITY`], total streaming memory per process. A
/// network-supplied `window` must never size an allocation unbounded.
pub const MAX_STREAM_WINDOW: usize = 1_000_000;

struct StreamState {
    monitor: StreamingMonitor,
    /// Last published update (protocol JSON), if any refresh ran yet.
    last: Option<Json>,
    /// Refresh counter mirror — `subscribe` waits for `seq > after`.
    seq: u64,
    closed: bool,
}

struct StreamEntry {
    state: Mutex<StreamState>,
    cv: Condvar,
}

/// Bounded registry of named streaming monitors (see the [module
/// docs](self)).
pub struct StreamRegistry {
    capacity: usize,
    inner: Mutex<HashMap<String, Arc<StreamEntry>>>,
}

impl StreamRegistry {
    /// An empty registry holding at most `capacity` streams.
    pub fn new(capacity: usize) -> StreamRegistry {
        StreamRegistry {
            capacity: capacity.max(1),
            inner: Mutex::new(HashMap::new()),
        }
    }

    /// Streams currently open (observability; the `stats` command).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// Whether no stream is open.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn entry(&self, name: &str) -> Result<Arc<StreamEntry>> {
        match self.inner.lock().unwrap().get(name) {
            Some(e) => Ok(Arc::clone(e)),
            None => bail!("no such stream {name:?}"),
        }
    }

    /// Open a stream. `refresh_every == 0` means every `append` request
    /// triggers one refresh at its end (request-driven cadence); a
    /// positive value refreshes each time that many points arrive.
    /// `window` is capped at [`MAX_STREAM_WINDOW`].
    pub fn open(
        &self,
        name: &str,
        params: SearchParams,
        window: usize,
        refresh_every: usize,
    ) -> Result<()> {
        anyhow::ensure!(
            window <= MAX_STREAM_WINDOW,
            "window {window} exceeds the per-stream cap of \
             {MAX_STREAM_WINDOW} points"
        );
        let monitor = StreamingMonitor::new(params, window)?
            .with_name(name)
            .with_refresh_every(refresh_every);
        let mut g = self.inner.lock().unwrap();
        if g.contains_key(name) {
            bail!("stream {name:?} is already open");
        }
        if g.len() >= self.capacity {
            bail!(
                "stream registry full ({}/{}): close a stream first",
                g.len(),
                self.capacity
            );
        }
        g.insert(
            name.to_string(),
            Arc::new(StreamEntry {
                state: Mutex::new(StreamState {
                    monitor,
                    last: None,
                    seq: 0,
                    closed: false,
                }),
                cv: Condvar::new(),
            }),
        );
        Ok(())
    }

    /// Append points to a stream; returns the protocol JSON of every
    /// update the appends produced (auto-refreshes at the stream's
    /// cadence, plus one request-end refresh when the cadence is 0).
    /// Subscribers are woken when at least one update was produced.
    pub fn append(&self, name: &str, points: &[f64]) -> Result<Vec<Json>> {
        let e = self.entry(name)?;
        let mut st = e.state.lock().unwrap();
        if st.closed {
            bail!("stream {name:?} is closed");
        }
        let mut updates = st.monitor.extend(points)?;
        if st.monitor.refresh_cadence() == 0
            && !points.is_empty()
            && st.monitor.num_sequences() >= 2
        {
            updates.push(st.monitor.refresh()?);
        }
        let out: Vec<Json> = updates.iter().map(|u| u.to_json()).collect();
        if let Some(last) = out.last() {
            st.last = Some(last.clone());
            st.seq = st.monitor.refreshes();
            e.cv.notify_all();
        }
        Ok(out)
    }

    /// Block until the stream's refresh counter exceeds `after` (or the
    /// timeout expires → `Ok(None)`). Returns the latest update with its
    /// refresh counter. Errors when the stream does not exist or is
    /// closed while waiting.
    pub fn subscribe(
        &self,
        name: &str,
        after: u64,
        timeout: Option<Duration>,
    ) -> Result<Option<(u64, Json)>> {
        let e = self.entry(name)?;
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut st = e.state.lock().unwrap();
        loop {
            if st.closed {
                bail!("stream {name:?} is closed");
            }
            if st.seq > after {
                let last = st.last.clone().expect("seq > 0 implies an update");
                return Ok(Some((st.seq, last)));
            }
            match deadline {
                None => st = e.cv.wait(st).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Ok(None);
                    }
                    st = e.cv.wait_timeout(st, d - now).unwrap().0;
                }
            }
        }
    }

    /// Close and drop a stream, waking any blocked subscribers (they
    /// receive a "stream closed" error).
    pub fn close(&self, name: &str) -> Result<()> {
        let e = match self.inner.lock().unwrap().remove(name) {
            Some(e) => e,
            None => bail!("no such stream {name:?}"),
        };
        let mut st = e.state.lock().unwrap();
        st.closed = true;
        e.cv.notify_all();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;

    fn registry() -> StreamRegistry {
        StreamRegistry::new(2)
    }

    fn open(r: &StreamRegistry, name: &str) {
        r.open(name, SearchParams::new(32, 4, 4), 300, 0).unwrap();
    }

    #[test]
    fn open_append_subscribe_close_lifecycle() {
        let r = registry();
        open(&r, "a");
        assert_eq!(r.len(), 1);
        assert!(r.open("a", SearchParams::new(32, 4, 4), 300, 0).is_err());

        let pts = generators::sine_with_noise(400, 0.3, 21);
        let updates = r.append("a", &pts).unwrap();
        assert_eq!(updates.len(), 1, "cadence 0 = one refresh per request");
        let u = &updates[0];
        assert_eq!(u.get("refresh").unwrap().as_u64(), Some(1));

        // an already-published update returns immediately
        let (seq, last) = r.subscribe("a", 0, None).unwrap().unwrap();
        assert_eq!(seq, 1);
        assert_eq!(last, *u);
        // waiting past the head times out
        let got = r
            .subscribe("a", seq, Some(Duration::from_millis(20)))
            .unwrap();
        assert!(got.is_none());

        r.close("a").unwrap();
        assert!(r.append("a", &pts).is_err());
        assert!(r.close("a").is_err());
        assert!(r.is_empty());
    }

    #[test]
    fn registry_is_bounded() {
        let r = registry();
        open(&r, "a");
        open(&r, "b");
        let err = r
            .open("c", SearchParams::new(32, 4, 4), 300, 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("full"), "{err}");
        r.close("a").unwrap();
        open(&r, "c");
    }

    #[test]
    fn subscriber_is_woken_by_append() {
        let r = Arc::new(registry());
        open(&r, "a");
        let r2 = Arc::clone(&r);
        let waiter = std::thread::spawn(move || {
            r2.subscribe("a", 0, Some(Duration::from_secs(10))).unwrap()
        });
        // give the waiter a moment to block, then publish
        std::thread::sleep(Duration::from_millis(30));
        let pts = generators::sine_with_noise(400, 0.3, 22);
        r.append("a", &pts).unwrap();
        let got = waiter.join().unwrap();
        assert!(got.is_some(), "append must wake the subscriber");
    }

    #[test]
    fn close_wakes_blocked_subscribers_with_an_error() {
        let r = Arc::new(registry());
        open(&r, "a");
        let r2 = Arc::clone(&r);
        let waiter = std::thread::spawn(move || {
            r2.subscribe("a", 0, Some(Duration::from_secs(10)))
        });
        std::thread::sleep(Duration::from_millis(30));
        r.close("a").unwrap();
        let got = waiter.join().unwrap();
        assert!(got.is_err(), "close must fail blocked subscribers fast");
    }

    #[test]
    fn invalid_window_is_rejected_at_open() {
        let r = registry();
        assert!(r.open("a", SearchParams::new(64, 4, 4), 100, 0).is_err());
        // a network-supplied window must never size an unbounded allocation
        let err = r
            .open("a", SearchParams::new(64, 4, 4), MAX_STREAM_WINDOW + 1, 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("cap"), "{err}");
        assert_eq!(r.len(), 0);
    }
}
