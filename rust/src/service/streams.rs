//! Named streaming monitors served over the JSON-lines + binary-frame
//! protocol.
//!
//! The [`Coordinator`](super::Coordinator) keeps a [`StreamRegistry`]
//! alongside its prepared-context LRU: each open stream is one
//! [`StreamingMonitor`] plus a bounded ingest queue of raw binary
//! batches. Two ingest paths feed the same monitor, so their refreshes
//! are bit-identical by construction:
//!
//! * **JSON `append`** — synchronous: points in, updates in the reply
//!   (or offloaded to a drain worker by the server's reactor, same
//!   monitor code either way).
//! * **Binary `data` frames** — [`StreamRegistry::enqueue_data`] parks
//!   the frame's raw little-endian payload in the stream's bounded
//!   queue; drain workers decode it straight into the monitor deques
//!   via [`StreamingMonitor::extend_from_le_bytes`]. A full queue (or a
//!   client over its in-flight quota) sheds the frame instead of
//!   growing memory — the shed is reported, never silent.
//!
//! Locking is split three ways per stream so a long refresh can never
//! stall the server's reactor thread: `queue` (short-held, the reactor's
//! only lock), `mon` (held across extend/refresh by whoever ingests),
//! and `publish` (the seq/last-update pair `subscribe`/`poll` read,
//! with the condvar blocking library subscribers wait on).
//!
//! The registry is bounded (stream count by `capacity`, per-stream
//! queue by the stream's own window, total via both) so no client can
//! grow server memory without bound; `stream_open` rejects with a
//! backpressure error when the registry is full.
//!
//! Protocol commands (`stream_open` / `append` / `subscribe` /
//! `stream_close`) and the binary framing are documented with worked
//! examples in `docs/PROTOCOL.md` at the repository root.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::SearchParams;
use crate::stream::StreamingMonitor;
use crate::util::json::Json;

use super::frame::ShedReason;

/// Default cap on streams one coordinator holds open at once (each holds
/// a window of points plus per-sequence state, so the cap bounds
/// memory). `hst serve --max-streams` raises it per process.
pub const STREAM_REGISTRY_CAPACITY: usize = 8;

/// Largest window (in points) a single stream may request. Per-point
/// state is ~100 bytes (window point + rolling stats + SAX word + profile
/// entry), so this caps one stream at roughly 100 MB — and, with the
/// registry capacity, total streaming memory per process. A network-
/// supplied `window` must never size an allocation unbounded.
pub const MAX_STREAM_WINDOW: usize = 1_000_000;

/// Default drain-worker count for [`StreamRegistry::start_workers`]
/// (`hst serve --stream-workers`). Zero workers = inline mode: JSON
/// appends run on the caller, binary frames queue until shed.
pub const DEFAULT_STREAM_WORKERS: usize = 2;

/// Outcome of offering one binary `data` frame to the registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Enqueue {
    /// The frame's points were queued for a drain worker.
    Accepted {
        /// Points the frame carried.
        points: usize,
    },
    /// The frame was dropped; the client owes itself a retry/slow-down.
    Shed {
        /// Why (queue full / client quota / unknown stream).
        reason: ShedReason,
        /// Points dropped with it.
        dropped: usize,
    },
}

/// Monotonic ingest counters for the `stats` command.
#[derive(Debug, Clone, Copy, Default)]
pub struct IngestStats {
    /// Binary `data` frames accepted.
    pub frames_rx: u64,
    /// Points those frames carried.
    pub points_rx: u64,
    /// Frames shed (all reasons).
    pub frames_shed: u64,
    /// Points currently parked in stream queues (gauge, not counter).
    pub queued_points: usize,
}

/// Published state `subscribe`/`poll` read; its mutex is never held
/// across a refresh, so reads are always cheap.
struct PubState {
    /// Last published update (protocol JSON), if any refresh ran yet.
    last: Option<Json>,
    /// Refresh counter mirror — `subscribe` waits for `seq > after`.
    seq: u64,
    closed: bool,
}

/// The bounded per-stream ingest queue of raw binary payloads. Its
/// mutex is the only one the server's reactor thread ever takes, and it
/// is held for pushes/swaps only — never across a refresh.
struct IngestQueue {
    /// Raw LE-f64 payloads, each with the quota counter of the client
    /// connection that sent it (decremented after the drain).
    batches: VecDeque<(Vec<u8>, Option<Arc<AtomicU64>>)>,
    /// Points across `batches` (the queue bound checks this).
    queued_points: usize,
    /// Queue bound in points (= the stream's window: one window of
    /// backlog is the most a drain can ever make useful).
    capacity_points: usize,
    /// A drain work item for this stream is already enqueued.
    scheduled: bool,
    /// A worker is currently draining this stream (keeps two workers
    /// from reordering one stream's batches).
    draining: bool,
}

struct StreamEntry {
    id: u32,
    name: String,
    queue: Mutex<IngestQueue>,
    mon: Mutex<StreamingMonitor>,
    publish: Mutex<PubState>,
    cv: Condvar,
}

/// What the drain workers pull off the shared work queue.
enum Work {
    /// Drain a stream's binary ingest queue.
    Drain(Arc<StreamEntry>),
    /// A JSON `append` offloaded by the reactor (reply via the channel
    /// so the reactor thread never blocks on a refresh).
    JsonAppend {
        entry: Arc<StreamEntry>,
        points: Vec<f64>,
        tx: mpsc::Sender<Result<Vec<Json>, String>>,
    },
}

struct WorkQueue {
    ready: VecDeque<Work>,
    shutdown: bool,
}

struct RegistryInner {
    capacity: usize,
    streams: Mutex<Streams>,
    work: Mutex<WorkQueue>,
    work_cv: Condvar,
    workers: Mutex<Vec<JoinHandle<()>>>,
    worker_count: AtomicUsize,
    queued_points: AtomicUsize,
    frames_rx: AtomicU64,
    points_rx: AtomicU64,
    frames_shed: AtomicU64,
}

struct Streams {
    by_name: HashMap<String, Arc<StreamEntry>>,
    by_id: HashMap<u32, Arc<StreamEntry>>,
    next_id: u32,
}

/// Bounded registry of named streaming monitors (see the [module
/// docs](self)). Cheap to share: a handle over one `Arc`'d inner.
pub struct StreamRegistry {
    inner: Arc<RegistryInner>,
}

impl StreamRegistry {
    /// An empty registry holding at most `capacity` streams, with no
    /// drain workers yet (call [`start_workers`](Self::start_workers)
    /// to enable asynchronous ingest).
    pub fn new(capacity: usize) -> StreamRegistry {
        StreamRegistry {
            inner: Arc::new(RegistryInner {
                capacity: capacity.max(1),
                streams: Mutex::new(Streams {
                    by_name: HashMap::new(),
                    by_id: HashMap::new(),
                    next_id: 1,
                }),
                work: Mutex::new(WorkQueue {
                    ready: VecDeque::new(),
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                workers: Mutex::new(Vec::new()),
                worker_count: AtomicUsize::new(0),
                queued_points: AtomicUsize::new(0),
                frames_rx: AtomicU64::new(0),
                points_rx: AtomicU64::new(0),
                frames_shed: AtomicU64::new(0),
            }),
        }
    }

    /// Streams currently open (observability; the `stats` command).
    pub fn len(&self) -> usize {
        self.inner.streams.lock().unwrap().by_name.len()
    }

    /// Whether no stream is open.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum streams this registry admits.
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Monotonic ingest counters plus the queued-points gauge.
    pub fn ingest_stats(&self) -> IngestStats {
        IngestStats {
            frames_rx: self.inner.frames_rx.load(Ordering::Relaxed),
            points_rx: self.inner.points_rx.load(Ordering::Relaxed),
            frames_shed: self.inner.frames_shed.load(Ordering::Relaxed),
            queued_points: self.inner.queued_points.load(Ordering::Relaxed),
        }
    }

    fn entry(&self, name: &str) -> Result<Arc<StreamEntry>> {
        match self.inner.streams.lock().unwrap().by_name.get(name) {
            Some(e) => Ok(Arc::clone(e)),
            None => bail!("no such stream {name:?}"),
        }
    }

    /// Open a stream; returns the numeric id binary `data` frames
    /// address it by. `refresh_every == 0` means every `append` request
    /// (or binary frame) triggers one refresh at its end
    /// (request-driven cadence); a positive value refreshes each time
    /// that many points arrive. `window` is capped at
    /// [`MAX_STREAM_WINDOW`] and also bounds the stream's binary ingest
    /// queue.
    pub fn open(
        &self,
        name: &str,
        params: SearchParams,
        window: usize,
        refresh_every: usize,
    ) -> Result<u32> {
        anyhow::ensure!(
            window <= MAX_STREAM_WINDOW,
            "window {window} exceeds the per-stream cap of \
             {MAX_STREAM_WINDOW} points"
        );
        let monitor = StreamingMonitor::new(params, window)?
            .with_name(name)
            .with_refresh_every(refresh_every);
        let mut g = self.inner.streams.lock().unwrap();
        if g.by_name.contains_key(name) {
            bail!("stream {name:?} is already open");
        }
        if g.by_name.len() >= self.inner.capacity {
            bail!(
                "stream registry full ({}/{}): close a stream first, or \
                 raise `--max-streams`",
                g.by_name.len(),
                self.inner.capacity
            );
        }
        let id = g.next_id;
        g.next_id = g.next_id.wrapping_add(1).max(1);
        let entry = Arc::new(StreamEntry {
            id,
            name: name.to_string(),
            queue: Mutex::new(IngestQueue {
                batches: VecDeque::new(),
                queued_points: 0,
                capacity_points: window,
                scheduled: false,
                draining: false,
            }),
            mon: Mutex::new(monitor),
            publish: Mutex::new(PubState {
                last: None,
                seq: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        });
        g.by_name.insert(name.to_string(), Arc::clone(&entry));
        g.by_id.insert(id, entry);
        Ok(id)
    }

    /// Snapshot every open stream's monitor, sorted by stream name so
    /// save order (and thus snapshot-directory content) is
    /// deterministic. Each monitor's lock is held only for the copy —
    /// never across I/O — so a long refresh on one stream delays that
    /// stream's snapshot, not the whole export.
    pub fn export_monitors(&self) -> Vec<crate::snapshot::MonitorSnapshot> {
        let entries: Vec<Arc<StreamEntry>> = {
            let g = self.inner.streams.lock().unwrap();
            let mut v: Vec<_> = g.by_name.values().map(Arc::clone).collect();
            v.sort_by(|a, b| a.name.cmp(&b.name));
            v
        };
        entries
            .iter()
            .map(|e| e.mon.lock().unwrap().snapshot())
            .collect()
    }

    /// Install a restored monitor as an open stream, under exactly the
    /// bounds [`open`](Self::open) enforces: the window cap (a snapshot
    /// file must never size an allocation past what `stream_open`
    /// admits), the duplicate-name check, and the registry capacity.
    /// Returns the fresh numeric id (ids are not persisted — binary
    /// senders re-learn them from `stream_open`-style replies).
    pub fn install(&self, monitor: StreamingMonitor) -> Result<u32> {
        let window = monitor.window_capacity();
        anyhow::ensure!(
            window <= MAX_STREAM_WINDOW,
            "window {window} exceeds the per-stream cap of \
             {MAX_STREAM_WINDOW} points"
        );
        let name = monitor.name().to_string();
        let mut g = self.inner.streams.lock().unwrap();
        if g.by_name.contains_key(&name) {
            bail!("stream {name:?} is already open");
        }
        if g.by_name.len() >= self.inner.capacity {
            bail!(
                "stream registry full ({}/{}): close a stream first, or \
                 raise `--max-streams`",
                g.by_name.len(),
                self.inner.capacity
            );
        }
        let id = g.next_id;
        g.next_id = g.next_id.wrapping_add(1).max(1);
        let entry = Arc::new(StreamEntry {
            id,
            name: name.clone(),
            queue: Mutex::new(IngestQueue {
                batches: VecDeque::new(),
                queued_points: 0,
                capacity_points: window,
                scheduled: false,
                draining: false,
            }),
            mon: Mutex::new(monitor),
            publish: Mutex::new(PubState {
                last: None,
                seq: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        });
        g.by_name.insert(name, Arc::clone(&entry));
        g.by_id.insert(id, entry);
        Ok(id)
    }

    /// The numeric id of an open stream (what `stream_open` replied).
    pub fn stream_id(&self, name: &str) -> Option<u32> {
        self.inner
            .streams
            .lock()
            .unwrap()
            .by_name
            .get(name)
            .map(|e| e.id)
    }

    /// Append points to a stream synchronously; returns the protocol
    /// JSON of every update the appends produced (auto-refreshes at the
    /// stream's cadence, plus one request-end refresh when the cadence
    /// is 0). Subscribers are woken when at least one update was
    /// produced.
    pub fn append(&self, name: &str, points: &[f64]) -> Result<Vec<Json>> {
        let e = self.entry(name)?;
        append_now(&e, points).map_err(|msg| anyhow::anyhow!(msg))
    }

    /// Offload a JSON `append` to the drain workers; the reply arrives
    /// on the returned receiver. Callers must check
    /// [`has_workers`](Self::has_workers) first — with no workers the
    /// item would never run (use [`append`](Self::append) inline
    /// instead).
    pub fn submit_json_append(
        &self,
        name: &str,
        points: Vec<f64>,
    ) -> Result<mpsc::Receiver<Result<Vec<Json>, String>>> {
        let entry = self.entry(name)?;
        let (tx, rx) = mpsc::channel();
        let mut w = self.inner.work.lock().unwrap();
        if w.shutdown {
            bail!("stream workers are shut down");
        }
        w.ready.push_back(Work::JsonAppend { entry, points, tx });
        self.inner.work_cv.notify_one();
        Ok(rx)
    }

    /// Offer one binary `data` frame's raw payload (packed LE f64).
    /// Never blocks and never refreshes — the fast path the reactor
    /// thread calls. `quota` is the sending connection's in-flight point
    /// counter with its limit; a frame that would exceed either the
    /// stream queue or the quota is shed, not queued.
    pub fn enqueue_data(
        &self,
        id: u32,
        payload: Vec<u8>,
        quota: Option<(&Arc<AtomicU64>, u64)>,
    ) -> Enqueue {
        let points = payload.len() / 8;
        let entry = match self.inner.streams.lock().unwrap().by_id.get(&id) {
            Some(e) => Arc::clone(e),
            None => {
                self.inner.frames_shed.fetch_add(1, Ordering::Relaxed);
                return Enqueue::Shed {
                    reason: ShedReason::NoSuchStream,
                    dropped: points,
                };
            }
        };
        if let Some((counter, limit)) = quota {
            if counter.load(Ordering::Relaxed) + points as u64 > limit {
                self.inner.frames_shed.fetch_add(1, Ordering::Relaxed);
                return Enqueue::Shed {
                    reason: ShedReason::ClientQuota,
                    dropped: points,
                };
            }
        }
        let mut q = entry.queue.lock().unwrap();
        if q.queued_points + points > q.capacity_points {
            drop(q);
            self.inner.frames_shed.fetch_add(1, Ordering::Relaxed);
            return Enqueue::Shed {
                reason: ShedReason::QueueFull,
                dropped: points,
            };
        }
        q.queued_points += points;
        let counter = quota.map(|(c, _)| {
            c.fetch_add(points as u64, Ordering::Relaxed);
            Arc::clone(c)
        });
        q.batches.push_back((payload, counter));
        let schedule = !q.scheduled && !q.draining;
        if schedule {
            q.scheduled = true;
        }
        drop(q);
        self.inner.queued_points.fetch_add(points, Ordering::Relaxed);
        self.inner.frames_rx.fetch_add(1, Ordering::Relaxed);
        self.inner.points_rx.fetch_add(points as u64, Ordering::Relaxed);
        if schedule {
            let mut w = self.inner.work.lock().unwrap();
            w.ready.push_back(Work::Drain(entry));
            self.inner.work_cv.notify_one();
        }
        Enqueue::Accepted { points }
    }

    /// Non-blocking subscribe: the latest update if the stream's
    /// refresh counter exceeds `after`, `None` otherwise. Errors when
    /// the stream does not exist or is closed. This is what the
    /// server's reactor polls so no thread ever parks per subscriber.
    pub fn poll(&self, name: &str, after: u64) -> Result<Option<(u64, Json)>> {
        let e = self.entry(name)?;
        let st = e.publish.lock().unwrap();
        if st.closed {
            bail!("stream {name:?} is closed");
        }
        if st.seq > after {
            let last = st.last.clone().expect("seq > 0 implies an update");
            return Ok(Some((st.seq, last)));
        }
        Ok(None)
    }

    /// Block until the stream's refresh counter exceeds `after` (or the
    /// timeout expires → `Ok(None)`). Returns the latest update with its
    /// refresh counter. Errors when the stream does not exist or is
    /// closed while waiting. (Library-embedding API; the TCP server
    /// polls via [`poll`](Self::poll) instead of parking a thread.)
    pub fn subscribe(
        &self,
        name: &str,
        after: u64,
        timeout: Option<Duration>,
    ) -> Result<Option<(u64, Json)>> {
        let e = self.entry(name)?;
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut st = e.publish.lock().unwrap();
        loop {
            if st.closed {
                bail!("stream {name:?} is closed");
            }
            if st.seq > after {
                let last = st.last.clone().expect("seq > 0 implies an update");
                return Ok(Some((st.seq, last)));
            }
            match deadline {
                None => st = e.cv.wait(st).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Ok(None);
                    }
                    st = e.cv.wait_timeout(st, d - now).unwrap().0;
                }
            }
        }
    }

    /// Close and drop a stream, waking any blocked subscribers (they
    /// receive a "stream closed" error) and releasing its queued
    /// batches (their senders' quota is returned).
    pub fn close(&self, name: &str) -> Result<()> {
        let e = {
            let mut g = self.inner.streams.lock().unwrap();
            match g.by_name.remove(name) {
                Some(e) => {
                    g.by_id.remove(&e.id);
                    e
                }
                None => bail!("no such stream {name:?}"),
            }
        };
        {
            let mut st = e.publish.lock().unwrap();
            st.closed = true;
            e.cv.notify_all();
        }
        let mut q = e.queue.lock().unwrap();
        let dropped = q.queued_points;
        q.queued_points = 0;
        for (payload, counter) in q.batches.drain(..) {
            if let Some(c) = counter {
                c.fetch_sub(payload.len() as u64 / 8, Ordering::Relaxed);
            }
        }
        drop(q);
        self.inner.queued_points.fetch_sub(dropped, Ordering::Relaxed);
        Ok(())
    }

    /// Spawn `n` drain workers (idempotent additions; each pulls from
    /// the shared work queue). With zero workers the registry is in
    /// inline mode: callers run [`append`](Self::append) themselves and
    /// binary frames queue until shed.
    pub fn start_workers(&self, n: usize) {
        let mut handles = self.inner.workers.lock().unwrap();
        for _ in 0..n {
            let inner = Arc::clone(&self.inner);
            handles.push(std::thread::spawn(move || drain_loop(inner)));
        }
        self.inner.worker_count.fetch_add(n, Ordering::SeqCst);
    }

    /// Whether any drain worker is running (decides inline vs offload).
    pub fn has_workers(&self) -> bool {
        self.inner.worker_count.load(Ordering::SeqCst) > 0
    }

    /// Stop and join the drain workers (queued work is abandoned; the
    /// registry stays usable in inline mode).
    pub fn stop_workers(&self) {
        {
            let mut w = self.inner.work.lock().unwrap();
            w.shutdown = true;
            self.inner.work_cv.notify_all();
        }
        let mut handles = self.inner.workers.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
        self.inner.worker_count.store(0, Ordering::SeqCst);
        self.inner.work.lock().unwrap().shutdown = false;
    }
}

impl Drop for StreamRegistry {
    fn drop(&mut self) {
        // only the last handle (the Coordinator's) joins the workers
        if Arc::strong_count(&self.inner)
            == 1 + self.inner.worker_count.load(Ordering::SeqCst)
        {
            self.stop_workers();
        }
    }
}

/// Run one synchronous append against a stream entry: extend, apply
/// the cadence-0 request-end refresh, publish. Both ingest paths (JSON
/// and drained binary batches) funnel through the same
/// [`StreamingMonitor`] calls, which is what makes their refreshes
/// bit-identical for the same points in the same order.
fn append_now(e: &StreamEntry, points: &[f64]) -> Result<Vec<Json>, String> {
    if e.publish.lock().unwrap().closed {
        return Err(format!("stream {:?} is closed", e.name));
    }
    let mut mon = e.mon.lock().unwrap();
    let mut updates = mon.extend(points).map_err(|err| format!("{err:#}"))?;
    if mon.refresh_cadence() == 0 && !points.is_empty() && mon.num_sequences() >= 2
    {
        updates.push(mon.refresh().map_err(|err| format!("{err:#}"))?);
    }
    let out: Vec<Json> = updates.iter().map(|u| u.to_json()).collect();
    let seq = mon.refreshes();
    drop(mon);
    publish(e, &out, seq);
    Ok(out)
}

/// Publish the last of a batch of updates (if any) and wake blocked
/// subscribers.
fn publish(e: &StreamEntry, updates: &[Json], seq: u64) {
    if let Some(last) = updates.last() {
        let mut st = e.publish.lock().unwrap();
        if !st.closed {
            st.last = Some(last.clone());
            st.seq = seq;
            e.cv.notify_all();
        }
    }
}

/// Drain-worker body: pull work items, run them, re-schedule streams
/// that accumulated more batches while draining.
fn drain_loop(inner: Arc<RegistryInner>) {
    loop {
        let item = {
            let mut w = inner.work.lock().unwrap();
            loop {
                if let Some(item) = w.ready.pop_front() {
                    break item;
                }
                if w.shutdown {
                    return;
                }
                w = inner.work_cv.wait(w).unwrap();
            }
        };
        match item {
            Work::JsonAppend { entry, points, tx } => {
                // receiver may have disconnected (client gone): fine
                let _ = tx.send(append_now(&entry, &points));
            }
            Work::Drain(entry) => drain_stream(&inner, entry),
        }
    }
}

/// Drain everything currently queued on one stream: decode each raw
/// payload zero-copy into the monitor (cadence refreshes happen inside
/// `extend_from_le_bytes`, one request-end refresh per frame at cadence
/// 0 — a frame is a request), publish, release quota.
fn drain_stream(inner: &Arc<RegistryInner>, entry: Arc<StreamEntry>) {
    let batches: Vec<(Vec<u8>, Option<Arc<AtomicU64>>)> = {
        let mut q = entry.queue.lock().unwrap();
        q.scheduled = false;
        q.draining = true;
        q.batches.drain(..).collect()
    };
    let mut failed: Option<String> = None;
    let mut drained_points = 0usize;
    {
        let mut mon = entry.mon.lock().unwrap();
        let mut updates: Vec<Json> = Vec::new();
        for (payload, _) in &batches {
            drained_points += payload.len() / 8;
            if failed.is_some() {
                continue; // still release quota below
            }
            let res = mon.extend_from_le_bytes(payload).and_then(|mut ups| {
                if mon.refresh_cadence() == 0
                    && !payload.is_empty()
                    && mon.num_sequences() >= 2
                {
                    ups.push(mon.refresh()?);
                }
                Ok(ups)
            });
            match res {
                Ok(ups) => updates.extend(ups.iter().map(|u| u.to_json())),
                Err(e) => failed = Some(format!("{e:#}")),
            }
        }
        let seq = mon.refreshes();
        drop(mon);
        publish(&entry, &updates, seq);
    }
    for (payload, counter) in &batches {
        if let Some(c) = counter {
            c.fetch_sub(payload.len() as u64 / 8, Ordering::Relaxed);
        }
    }
    {
        let mut q = entry.queue.lock().unwrap();
        q.queued_points -= drained_points.min(q.queued_points);
        q.draining = false;
        if !q.batches.is_empty() && !q.scheduled {
            q.scheduled = true;
            let mut w = inner.work.lock().unwrap();
            w.ready.push_back(Work::Drain(Arc::clone(&entry)));
            inner.work_cv.notify_one();
        }
    }
    inner.queued_points.fetch_sub(drained_points, Ordering::Relaxed);
    if let Some(msg) = failed {
        // a monitor that rejects its input cannot continue exactly;
        // close the stream so subscribers see the error, not silence
        let mut st = entry.publish.lock().unwrap();
        st.closed = true;
        st.last = Some(Json::obj().set("ok", false).set("error", msg));
        entry.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::frame;
    use crate::ts::generators;

    fn registry() -> StreamRegistry {
        StreamRegistry::new(2)
    }

    fn open(r: &StreamRegistry, name: &str) -> u32 {
        r.open(name, SearchParams::new(32, 4, 4), 300, 0).unwrap()
    }

    fn le_bytes(points: &[f64]) -> Vec<u8> {
        points.iter().flat_map(|x| x.to_le_bytes()).collect()
    }

    #[test]
    fn open_append_subscribe_close_lifecycle() {
        let r = registry();
        open(&r, "a");
        assert_eq!(r.len(), 1);
        assert!(r.open("a", SearchParams::new(32, 4, 4), 300, 0).is_err());

        let pts = generators::sine_with_noise(400, 0.3, 21);
        let updates = r.append("a", &pts).unwrap();
        assert_eq!(updates.len(), 1, "cadence 0 = one refresh per request");
        let u = &updates[0];
        assert_eq!(u.get("refresh").unwrap().as_u64(), Some(1));

        // an already-published update returns immediately
        let (seq, last) = r.subscribe("a", 0, None).unwrap().unwrap();
        assert_eq!(seq, 1);
        assert_eq!(last, *u);
        // poll agrees without blocking
        let (pseq, plast) = r.poll("a", 0).unwrap().unwrap();
        assert_eq!((pseq, &plast), (seq, &last));
        assert!(r.poll("a", seq).unwrap().is_none());
        // waiting past the head times out
        let got = r
            .subscribe("a", seq, Some(Duration::from_millis(20)))
            .unwrap();
        assert!(got.is_none());

        r.close("a").unwrap();
        assert!(r.append("a", &pts).is_err());
        assert!(r.close("a").is_err());
        assert!(r.is_empty());
    }

    #[test]
    fn registry_is_bounded() {
        let r = registry();
        open(&r, "a");
        open(&r, "b");
        let err = r
            .open("c", SearchParams::new(32, 4, 4), 300, 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("full"), "{err}");
        r.close("a").unwrap();
        open(&r, "c");
    }

    #[test]
    fn stream_ids_are_unique_and_resolvable() {
        let r = registry();
        let a = open(&r, "a");
        let b = open(&r, "b");
        assert_ne!(a, b);
        assert_eq!(r.stream_id("a"), Some(a));
        assert_eq!(r.stream_id("b"), Some(b));
        r.close("a").unwrap();
        assert_eq!(r.stream_id("a"), None);
        // the id is retired with the stream: frames to it shed by name
        let out = r.enqueue_data(a, le_bytes(&[1.0]), None);
        assert_eq!(
            out,
            Enqueue::Shed {
                reason: ShedReason::NoSuchStream,
                dropped: 1
            }
        );
    }

    #[test]
    fn subscriber_is_woken_by_append() {
        let r = Arc::new(registry());
        open(&r, "a");
        let r2 = Arc::clone(&r);
        let waiter = std::thread::spawn(move || {
            r2.subscribe("a", 0, Some(Duration::from_secs(10))).unwrap()
        });
        // give the waiter a moment to block, then publish
        std::thread::sleep(Duration::from_millis(30));
        let pts = generators::sine_with_noise(400, 0.3, 22);
        r.append("a", &pts).unwrap();
        let got = waiter.join().unwrap();
        assert!(got.is_some(), "append must wake the subscriber");
    }

    #[test]
    fn close_wakes_blocked_subscribers_with_an_error() {
        let r = Arc::new(registry());
        open(&r, "a");
        let r2 = Arc::clone(&r);
        let waiter = std::thread::spawn(move || {
            r2.subscribe("a", 0, Some(Duration::from_secs(10)))
        });
        std::thread::sleep(Duration::from_millis(30));
        r.close("a").unwrap();
        let got = waiter.join().unwrap();
        assert!(got.is_err(), "close must fail blocked subscribers fast");
    }

    #[test]
    fn invalid_window_is_rejected_at_open() {
        let r = registry();
        assert!(r.open("a", SearchParams::new(64, 4, 4), 100, 0).is_err());
        // a network-supplied window must never size an unbounded allocation
        let err = r
            .open("a", SearchParams::new(64, 4, 4), MAX_STREAM_WINDOW + 1, 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("cap"), "{err}");
        assert_eq!(r.len(), 0);
    }

    #[test]
    fn full_queue_sheds_deterministically_without_workers() {
        // no drain workers started: the queue only fills. Window = 300
        // points bounds it; the frame that would cross the line sheds.
        let r = registry();
        let id = open(&r, "a");
        let chunk = le_bytes(&vec![0.5; 100]);
        for _ in 0..3 {
            assert_eq!(
                r.enqueue_data(id, chunk.clone(), None),
                Enqueue::Accepted { points: 100 }
            );
        }
        assert_eq!(
            r.enqueue_data(id, chunk.clone(), None),
            Enqueue::Shed {
                reason: ShedReason::QueueFull,
                dropped: 100
            }
        );
        let st = r.ingest_stats();
        assert_eq!(st.frames_rx, 3);
        assert_eq!(st.points_rx, 300);
        assert_eq!(st.frames_shed, 1);
        assert_eq!(st.queued_points, 300);
        // closing releases the backlog accounting
        r.close("a").unwrap();
        assert_eq!(r.ingest_stats().queued_points, 0);
    }

    #[test]
    fn client_quota_sheds_and_releases_on_close() {
        let r = registry();
        let id = open(&r, "a");
        let counter = Arc::new(AtomicU64::new(0));
        let chunk = le_bytes(&vec![0.5; 100]);
        assert_eq!(
            r.enqueue_data(id, chunk.clone(), Some((&counter, 150))),
            Enqueue::Accepted { points: 100 }
        );
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(
            r.enqueue_data(id, chunk.clone(), Some((&counter, 150))),
            Enqueue::Shed {
                reason: ShedReason::ClientQuota,
                dropped: 100
            }
        );
        r.close("a").unwrap();
        assert_eq!(
            counter.load(Ordering::Relaxed),
            0,
            "close must return the in-flight quota of queued batches"
        );
    }

    #[test]
    fn drained_binary_frames_match_direct_extend_bitwise() {
        // one registry ingests via frames + drain worker, a bare
        // monitor ingests the same points directly: refreshes must be
        // bit-identical (the tentpole's exactness requirement)
        let pts = generators::sine_with_noise(360, 0.3, 23);
        let params = SearchParams::new(32, 4, 4);

        let r = registry();
        r.open("a", params.clone(), 300, 120).unwrap();
        let id = r.stream_id("a").unwrap();
        r.start_workers(1);
        for chunk in pts.chunks(90) {
            // frames of 90 points; cadence 120 fires inside extend
            assert!(matches!(
                r.enqueue_data(id, le_bytes(chunk), None),
                Enqueue::Accepted { .. }
            ));
        }
        let (seq, last) = r
            .subscribe("a", 2, Some(Duration::from_secs(20)))
            .unwrap()
            .expect("drain workers must publish the third refresh");
        assert_eq!(seq, 3, "360 points / cadence 120 = 3 refreshes");

        let mut mon = StreamingMonitor::new(params, 300)
            .unwrap()
            .with_name("a")
            .with_refresh_every(120);
        let direct = mon.extend(&pts).unwrap();
        assert_eq!(direct.len(), 3);
        assert_eq!(
            last,
            direct.last().unwrap().to_json(),
            "binary ingest must be bit-identical to direct extend"
        );
        // backlog fully drained and quota-free
        assert_eq!(r.ingest_stats().queued_points, 0);
        r.stop_workers();
    }

    #[test]
    fn offloaded_json_append_matches_inline_append() {
        let pts = generators::sine_with_noise(400, 0.3, 24);
        let r = registry();
        open(&r, "via-worker");
        r.start_workers(1);
        let rx = r
            .submit_json_append("via-worker", pts.clone())
            .unwrap();
        let offloaded = rx
            .recv_timeout(Duration::from_secs(20))
            .expect("worker must answer")
            .expect("append must succeed");
        r.stop_workers();

        let r2 = registry();
        open(&r2, "inline");
        let inline = r2.append("inline", &pts).unwrap();
        // names differ; everything else (counts, discords, call
        // accounting) must be bit-identical
        assert_eq!(offloaded.len(), inline.len());
        assert_eq!(offloaded, inline);
    }

    #[test]
    fn export_install_roundtrip_preserves_warm_streams() {
        let r = registry();
        open(&r, "b");
        open(&r, "a");
        let pts = generators::sine_with_noise(400, 0.3, 25);
        r.append("a", &pts).unwrap();
        r.append("b", &pts).unwrap();

        let snaps = r.export_monitors();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].name, "a", "export order is by name");
        assert_eq!(snaps[1].name, "b");
        assert!(snaps[0].warm);

        let r2 = registry();
        for snap in snaps {
            let mon = StreamingMonitor::from_snapshot(snap).unwrap();
            r2.install(mon).unwrap();
        }
        assert_eq!(r2.len(), 2);
        // the restored stream continues warm: its next request-end
        // refresh carries the snapshot's profile (prep_calls == 0)
        let more = generators::sine_with_noise(50, 0.3, 26);
        let ups = r2.append("a", &more).unwrap();
        assert_eq!(ups.len(), 1);
        assert_eq!(ups[0].get("warm").unwrap().as_bool(), Some(true));
        assert_eq!(ups[0].get("prep_calls").unwrap().as_u64(), Some(0));
        assert_eq!(ups[0].get("refresh").unwrap().as_u64(), Some(2));
        // install re-checks open()'s bounds: duplicates are refused
        let dup = StreamingMonitor::new(SearchParams::new(32, 4, 4), 300)
            .unwrap()
            .with_name("a");
        assert!(r2.install(dup).is_err());
    }

    #[test]
    fn registry_only_sees_codec_validated_payloads() {
        // a misaligned length never reaches enqueue_data: the codec
        // rejects it at the header, before any payload is read
        let bad = frame::decode_header(&frame::encode_header(
            frame::FrameKind::Data,
            1,
            12,
        ));
        assert!(bad.is_err(), "codec must reject misaligned payload_len");
        // an aligned odd-count batch is a normal frame
        let r = registry();
        let id = open(&r, "a");
        assert!(matches!(
            r.enqueue_data(id, le_bytes(&[1.0, 2.0, 3.0]), None),
            Enqueue::Accepted { points: 3 }
        ));
    }
}
