//! Snapshot files on disk: naming, directory layout, and inspection.
//!
//! A snapshot directory is flat: one `.hsts` file per saved context
//! (`ctx_<slug>_<hash>.hsts`) and per saved stream monitor
//! (`stream_<slug>_<hash>.hsts`). Slugs are sanitized for readability;
//! the FNV hash of the raw key makes names collision-free even when two
//! keys sanitize identically. [`inspect`] summarizes any snapshot from
//! bytes alone — it is what `hst snapshot inspect` and the CI golden
//! check run, so a file that inspects cleanly also decodes cleanly.

use std::path::{Path, PathBuf};

use super::context::{decode_context, ContextSnapshot};
use super::monitor::{decode_monitor, MonitorSnapshot};
use super::{
    decode_header, decode_sections, tag_name, SnapshotError, SnapshotKind,
    SNAPSHOT_EXT,
};

/// FNV-1a over a label, for collision-free file names.
fn fnv64(text: &str) -> u64 {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.as_bytes() {
        hash ^= *b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
    }
    hash
}

/// Sanitize a free-form label into a filename slug: lowercase
/// alphanumerics kept, everything else folded to `-`, capped at 48 bytes.
fn slug(label: &str) -> String {
    let mut out = String::with_capacity(label.len().min(48));
    for c in label.chars() {
        if out.len() >= 48 {
            break;
        }
        if c.is_ascii_alphanumeric() {
            out.push(c.to_ascii_lowercase());
        } else {
            out.push('-');
        }
    }
    if out.is_empty() {
        out.push('-');
    }
    out
}

/// File name for a context snapshot, from its cache-key fields.
pub fn context_file_name(dataset: &str, scale_div: u64, s: usize, p: usize, alphabet: usize) -> String {
    let key = format!("{dataset}\u{1f}{scale_div}\u{1f}{s}\u{1f}{p}\u{1f}{alphabet}");
    format!(
        "ctx_{}_{:016x}.{SNAPSHOT_EXT}",
        slug(dataset),
        fnv64(&key)
    )
}

/// File name for a stream monitor snapshot, from its stream name.
pub fn monitor_file_name(stream: &str) -> String {
    format!("stream_{}_{:016x}.{SNAPSHOT_EXT}", slug(stream), fnv64(stream))
}

/// All `.hsts` files in a directory, sorted by name so restore order is
/// deterministic. A missing directory is an empty restore, not an error.
pub fn list_dir(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e),
    };
    for entry in entries {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) == Some(SNAPSHOT_EXT) {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

/// A decoded snapshot of either kind.
#[derive(Debug, Clone, PartialEq)]
pub enum Snapshot {
    /// A context warm-profile snapshot.
    Context(ContextSnapshot),
    /// A stream monitor snapshot.
    Monitor(MonitorSnapshot),
}

/// Decode any snapshot, dispatching on the header's kind byte.
pub fn decode(bytes: &[u8]) -> Result<Snapshot, SnapshotError> {
    match super::decode_kind(bytes)? {
        SnapshotKind::Context => decode_context(bytes).map(Snapshot::Context),
        SnapshotKind::Monitor => decode_monitor(bytes).map(Snapshot::Monitor),
    }
}

/// One section row of an [`SnapshotSummary`].
#[derive(Debug, Clone, PartialEq)]
pub struct SectionInfo {
    /// Wire tag.
    pub tag: u16,
    /// Stable tag name.
    pub name: &'static str,
    /// Payload length in bytes.
    pub len: usize,
    /// Byte offset of the section header in the file.
    pub offset: usize,
}

/// What `hst snapshot inspect` prints: the header fields, the section
/// table, and a one-line summary of the decoded content.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotSummary {
    /// Snapshot kind.
    pub kind: SnapshotKind,
    /// Total file size in bytes.
    pub bytes: usize,
    /// The CRC-verified section table.
    pub sections: Vec<SectionInfo>,
    /// Kind-specific description lines.
    pub detail: Vec<String>,
}

/// Fully validate a snapshot (header, section CRCs, content decode) and
/// summarize it. Any corruption surfaces as the same named
/// [`SnapshotError`] a restore would hit.
pub fn inspect(bytes: &[u8]) -> Result<SnapshotSummary, SnapshotError> {
    let (kind, _) = decode_header(bytes)?;
    let sections = decode_sections(bytes)?
        .iter()
        .map(|s| SectionInfo {
            tag: s.tag,
            name: tag_name(s.tag).unwrap_or("unknown"),
            len: s.payload.len(),
            offset: s.offset,
        })
        .collect::<Vec<_>>();
    let detail = match decode(bytes)? {
        Snapshot::Context(c) => {
            let mut lines = vec![format!(
                "dataset {:?} scale_div {} sax {}/{}/{} series len {} hash {:016x}",
                c.dataset,
                c.scale_div,
                c.sax.s,
                c.sax.p,
                c.sax.alphabet,
                c.fingerprint.len,
                c.fingerprint.hash
            )];
            for e in &c.profiles {
                let warm = e
                    .profile
                    .nnd
                    .iter()
                    .filter(|v| v.is_finite())
                    .count();
                lines.push(format!(
                    "profile s={} kind={} allow_self_match={} sequences={} warm={}",
                    e.s,
                    match e.kind {
                        crate::dist::DistanceKind::Znorm => "znorm",
                        crate::dist::DistanceKind::Raw => "raw",
                    },
                    e.allow_self_match,
                    e.profile.len(),
                    warm
                ));
            }
            lines
        }
        Snapshot::Monitor(m) => {
            vec![format!(
                "stream {:?} s={} window {}/{} start {} sequences {} warm={} \
                 refreshes {} calls {}",
                m.name,
                m.params.sax.s,
                m.buf.len(),
                m.capacity,
                m.start,
                m.nnd.len(),
                m.warm,
                m.refreshes,
                m.total_calls
            )]
        }
    };
    Ok(SnapshotSummary {
        kind,
        bytes: bytes.len(),
        sections,
        detail,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slugs_are_safe_and_names_collision_free() {
        assert_eq!(slug("ECG 108"), "ecg-108");
        assert_eq!(slug("../../etc/passwd"), "------etc-passwd");
        assert_eq!(slug(""), "-");
        // same slug, different raw names -> different files
        let a = monitor_file_name("a b");
        let b = monitor_file_name("a-b");
        assert_ne!(a, b);
        assert!(a.starts_with("stream_a-b_"));
        assert!(a.ends_with(".hsts"));
        let c = context_file_name("ECG 108", 8, 96, 4, 4);
        let d = context_file_name("ECG 108", 4, 96, 4, 4);
        assert_ne!(c, d, "scale_div is part of the key");
    }

    #[test]
    fn missing_dir_lists_empty() {
        let dir = Path::new("/nonexistent/hstime-snapshot-test");
        assert!(list_dir(dir).unwrap().is_empty());
    }

    #[test]
    fn inspect_summarizes_and_rejects_like_restore() {
        use crate::config::SaxParams;
        use crate::discord::NndProfile;
        use crate::dist::DistanceKind;
        use crate::snapshot::context::{encode_context, ProfileEntry};
        use crate::snapshot::{SeriesFingerprint, SnapshotError};

        let snap = super::super::ContextSnapshot {
            dataset: "ECG 108".to_string(),
            scale_div: 8,
            sax: SaxParams { s: 96, p: 4, alphabet: 4 },
            fingerprint: SeriesFingerprint { len: 10, hash: 1 },
            profiles: vec![ProfileEntry {
                s: 96,
                kind: DistanceKind::Znorm,
                allow_self_match: false,
                profile: NndProfile::new(4),
            }],
        };
        let mut bytes = encode_context(&snap);
        let summary = inspect(&bytes).expect("inspect ok");
        assert_eq!(summary.kind, SnapshotKind::Context);
        assert_eq!(summary.sections.len(), 2);
        assert_eq!(summary.sections[0].name, "fingerprint");
        assert_eq!(summary.sections[1].name, "profile");
        assert!(summary.detail[0].contains("ECG 108"));
        // corrupt a payload byte: inspect fails with the restore's error
        let off = summary.sections[1].offset + 12 + 3;
        bytes[off] ^= 0xFF;
        assert!(matches!(
            inspect(&bytes).unwrap_err(),
            SnapshotError::BadChecksum { section: "profile", .. }
        ));
    }
}
