//! Monitor snapshots: the complete state of one
//! [`StreamingMonitor`](crate::stream::StreamingMonitor) — window deque,
//! global stream offset, rolling per-sequence stats, SAX words, and the
//! shifted warm profile — so a restarted service resumes the stream
//! mid-flight with zero re-preparation.
//!
//! Layout (after the file header): `monitor_meta`, `monitor_window`,
//! `monitor_stats`, `monitor_words`, `monitor_profile`, in that order.
//! Search params travel as their strict JSON form (the same
//! [`SearchParams::from_json`] validator the service protocol uses), so a
//! tampered params blob is rejected by name, not absorbed.

use crate::config::SearchParams;
use crate::dist::Kernel;
use crate::sax::SaxWord;
use crate::util::json::Json;

use super::{
    assemble, decode_sections, expect_section, kernel_code, kernel_from_code,
    push_section, push_string, push_u64, Reader, SnapshotError, SnapshotKind,
    TAG_MONITOR_META, TAG_MONITOR_PROFILE, TAG_MONITOR_STATS, TAG_MONITOR_WINDOW,
    TAG_MONITOR_WORDS,
};

/// The full durable state of one streaming monitor. Field-for-field the
/// monitor's own private state; [`validate`](Self::validate) checks the
/// cross-field invariants that make the fields describe one coherent
/// window, and `StreamingMonitor::from_snapshot` rebuilds a live monitor
/// from a validated snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct MonitorSnapshot {
    /// Stream name (the service registry key).
    pub name: String,
    /// Search parameters the monitor refreshes with.
    pub params: SearchParams,
    /// Window capacity in points.
    pub capacity: usize,
    /// Auto-refresh cadence (0 = manual refresh only).
    pub refresh_every: usize,
    /// Distance kernel the monitor was running under. Restored verbatim
    /// for field-bitwise roundtrips; the kernels are bit-identical by
    /// construction, so this is a throughput knob, not a correctness one.
    pub kernel: Kernel,
    /// Window points, oldest first.
    pub buf: Vec<f64>,
    /// Global offset of `buf[0]` in the stream.
    pub start: u64,
    /// Rolling per-sequence means (one per in-window sequence).
    pub stats_mean: Vec<f64>,
    /// Rolling per-sequence standard deviations.
    pub stats_std: Vec<f64>,
    /// SAX word per in-window sequence.
    pub words: Vec<SaxWord>,
    /// Warm nnd bound per in-window sequence (window coordinates).
    pub nnd: Vec<f64>,
    /// Neighbor per bound, in *global* stream coordinates
    /// (`u64::MAX` = none).
    pub ngh: Vec<u64>,
    /// Whether the profile has been refined by at least one refresh.
    pub warm: bool,
    /// Points ingested since the last refresh.
    pub pending: usize,
    /// Completed refreshes.
    pub refreshes: u64,
    /// Total distance calls across all refreshes.
    pub total_calls: u64,
}

impl MonitorSnapshot {
    /// Check the cross-field invariants: the capacity bound every live
    /// monitor is constructed under, the window fitting its capacity, and
    /// all five per-sequence vectors describing exactly the sequences the
    /// window holds. A snapshot that fails here could never have come
    /// from a live monitor, so restoring it is refused by name.
    pub fn validate(&self) -> Result<(), SnapshotError> {
        let s = self.params.sax.s;
        if self.capacity < 2 * s {
            return Err(SnapshotError::Inconsistent {
                field: "capacity",
                detail: format!(
                    "window capacity {} cannot hold two length-{s} sequences",
                    self.capacity
                ),
            });
        }
        if self.buf.len() > self.capacity {
            return Err(SnapshotError::Inconsistent {
                field: "window",
                detail: format!(
                    "window holds {} points, above its capacity {}",
                    self.buf.len(),
                    self.capacity
                ),
            });
        }
        let expected = if self.buf.len() >= s {
            self.buf.len() - s + 1
        } else {
            0
        };
        for (field, len) in [
            ("stats_mean", self.stats_mean.len()),
            ("stats_std", self.stats_std.len()),
            ("words", self.words.len()),
            ("nnd", self.nnd.len()),
            ("ngh", self.ngh.len()),
        ] {
            if len != expected {
                return Err(SnapshotError::Inconsistent {
                    field,
                    detail: format!(
                        "{len} entries for a {}-point window with {expected} sequences",
                        self.buf.len()
                    ),
                });
            }
        }
        Ok(())
    }
}

/// Encode a monitor snapshot (deterministic: same state, same bytes).
pub fn encode_monitor(snap: &MonitorSnapshot) -> Vec<u8> {
    let mut body = Vec::new();

    let mut meta = Vec::new();
    push_string(&mut meta, &snap.name);
    push_string(&mut meta, &snap.params.to_json().to_string());
    push_u64(&mut meta, snap.capacity as u64);
    push_u64(&mut meta, snap.refresh_every as u64);
    meta.push(kernel_code(snap.kernel));
    meta.push(snap.warm as u8);
    push_u64(&mut meta, snap.start);
    push_u64(&mut meta, snap.pending as u64);
    push_u64(&mut meta, snap.refreshes);
    push_u64(&mut meta, snap.total_calls);
    push_section(&mut body, TAG_MONITOR_META, &meta);

    let mut window = Vec::new();
    push_u64(&mut window, snap.buf.len() as u64);
    for &x in &snap.buf {
        push_u64(&mut window, x.to_bits());
    }
    push_section(&mut body, TAG_MONITOR_WINDOW, &window);

    let mut stats = Vec::new();
    push_u64(&mut stats, snap.stats_mean.len() as u64);
    for &m in &snap.stats_mean {
        push_u64(&mut stats, m.to_bits());
    }
    push_u64(&mut stats, snap.stats_std.len() as u64);
    for &sd in &snap.stats_std {
        push_u64(&mut stats, sd.to_bits());
    }
    push_section(&mut body, TAG_MONITOR_STATS, &stats);

    let mut words = Vec::new();
    push_u64(&mut words, snap.words.len() as u64);
    for w in &snap.words {
        words.push(w.len() as u8);
        words.extend_from_slice(w.symbols());
    }
    push_section(&mut body, TAG_MONITOR_WORDS, &words);

    let mut profile = Vec::new();
    push_u64(&mut profile, snap.nnd.len() as u64);
    for &v in &snap.nnd {
        push_u64(&mut profile, v.to_bits());
    }
    for &g in &snap.ngh {
        push_u64(&mut profile, g);
    }
    push_section(&mut body, TAG_MONITOR_PROFILE, &profile);

    assemble(SnapshotKind::Monitor, 5, body)
}

/// Decode and fully validate a monitor snapshot: sections in layout
/// order, params through the strict JSON validator, and the cross-field
/// invariants of [`MonitorSnapshot::validate`]. A decoded snapshot is
/// safe to hand to `StreamingMonitor::from_snapshot`.
pub fn decode_monitor(bytes: &[u8]) -> Result<MonitorSnapshot, SnapshotError> {
    let sections = decode_sections(bytes)?;
    let (kind, _) = super::decode_header(bytes)?;
    if kind != SnapshotKind::Monitor {
        return Err(SnapshotError::SectionOrder {
            expected: "monitor_meta",
            found: "fingerprint",
        });
    }

    let meta = expect_section(&sections, 0, TAG_MONITOR_META)?;
    let mut r = Reader::new(meta.payload);
    let name = r.string("name")?;
    let params_text = r.string("params")?;
    let params_json = Json::parse(&params_text).map_err(|e| SnapshotError::BadParams {
        detail: e.to_string(),
    })?;
    let params = SearchParams::from_json(&params_json)
        .map_err(|detail| SnapshotError::BadParams { detail })?;
    let capacity = r.u64()? as usize;
    let refresh_every = r.u64()? as usize;
    let kernel = kernel_from_code(r.u8()?)?;
    let warm = match r.u8()? {
        0 => false,
        1 => true,
        other => {
            return Err(SnapshotError::Inconsistent {
                field: "warm",
                detail: format!("flag byte is {other}, must be 0 or 1"),
            })
        }
    };
    let start = r.u64()?;
    let pending = r.u64()? as usize;
    let refreshes = r.u64()?;
    let total_calls = r.u64()?;
    r.finish("monitor_meta")?;

    let window = expect_section(&sections, 1, TAG_MONITOR_WINDOW)?;
    let mut r = Reader::new(window.payload);
    let n_buf = r.count("window", 8)?;
    let buf = r.f64_bits(n_buf)?;
    r.finish("monitor_window")?;

    let stats = expect_section(&sections, 2, TAG_MONITOR_STATS)?;
    let mut r = Reader::new(stats.payload);
    let n_mean = r.count("stats_mean", 8)?;
    let stats_mean = r.f64_bits(n_mean)?;
    let n_std = r.count("stats_std", 8)?;
    let stats_std = r.f64_bits(n_std)?;
    r.finish("monitor_stats")?;

    let words_sec = expect_section(&sections, 3, TAG_MONITOR_WORDS)?;
    let mut r = Reader::new(words_sec.payload);
    let n_words = r.count("words", 1)?;
    let mut words = Vec::with_capacity(n_words);
    for _ in 0..n_words {
        let len = r.u8()? as usize;
        if len > crate::sax::word::MAX_INLINE {
            return Err(SnapshotError::Inconsistent {
                field: "word",
                detail: format!(
                    "word length {len} exceeds the {}-symbol inline cap",
                    crate::sax::word::MAX_INLINE
                ),
            });
        }
        words.push(SaxWord::new(r.bytes(len)?));
    }
    r.finish("monitor_words")?;

    let profile = expect_section(&sections, 4, TAG_MONITOR_PROFILE)?;
    let mut r = Reader::new(profile.payload);
    let n_prof = r.count("nnd", 16)?;
    let nnd = r.f64_bits(n_prof)?;
    let ngh = r.u64_vec(n_prof)?;
    r.finish("monitor_profile")?;

    let snap = MonitorSnapshot {
        name,
        params,
        capacity,
        refresh_every,
        kernel,
        buf,
        start,
        stats_mean,
        stats_std,
        words,
        nnd,
        ngh,
        warm,
        pending,
        refreshes,
        total_calls,
    };
    snap.validate()?;
    Ok(snap)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MonitorSnapshot {
        let s = 4;
        let buf: Vec<f64> = (0..10).map(|i| (i as f64).sin()).collect();
        let n = buf.len() - s + 1;
        MonitorSnapshot {
            name: "test-stream".to_string(),
            params: SearchParams::new(s, 2, 4).with_discords(2).with_seed(7),
            capacity: 16,
            refresh_every: 5,
            kernel: Kernel::Scalar,
            buf,
            start: 42,
            stats_mean: (0..n).map(|i| i as f64 * 0.5).collect(),
            stats_std: (0..n).map(|i| 1.0 + i as f64).collect(),
            words: (0..n).map(|i| SaxWord::new(&[(i % 4) as u8, 1])).collect(),
            nnd: (0..n)
                .map(|i| if i == 0 { f64::INFINITY } else { i as f64 })
                .collect(),
            ngh: (0..n)
                .map(|i| if i == 0 { u64::MAX } else { 42 + i as u64 })
                .collect(),
            warm: true,
            pending: 3,
            refreshes: 2,
            total_calls: 99,
        }
    }

    #[test]
    fn roundtrip_is_field_bitwise() {
        let mut snap = sample();
        snap.nnd[1] = f64::NAN;
        snap.nnd[2] = -0.0;
        let bytes = encode_monitor(&snap);
        let back = decode_monitor(&bytes).expect("roundtrip");
        assert_eq!(back.name, snap.name);
        assert_eq!(back.params, snap.params);
        assert_eq!(back.capacity, snap.capacity);
        assert_eq!(back.refresh_every, snap.refresh_every);
        assert_eq!(back.kernel, snap.kernel);
        assert_eq!(back.start, snap.start);
        assert_eq!(back.warm, snap.warm);
        assert_eq!(back.pending, snap.pending);
        assert_eq!(back.refreshes, snap.refreshes);
        assert_eq!(back.total_calls, snap.total_calls);
        assert_eq!(back.words, snap.words);
        assert_eq!(back.ngh, snap.ngh);
        for (field, a, b) in [
            ("buf", &snap.buf, &back.buf),
            ("stats_mean", &snap.stats_mean, &back.stats_mean),
            ("stats_std", &snap.stats_std, &back.stats_std),
            ("nnd", &snap.nnd, &back.nnd),
        ] {
            assert_eq!(a.len(), b.len(), "{field} length");
            for i in 0..a.len() {
                assert_eq!(a[i].to_bits(), b[i].to_bits(), "{field}[{i}] bits");
            }
        }
        assert!(back.nnd[1].is_nan(), "NaN survives");
        assert_eq!(back.nnd[2].to_bits(), (-0.0f64).to_bits(), "-0.0 survives");
    }

    #[test]
    fn inconsistent_deque_lengths_are_named() {
        let mut snap = sample();
        snap.stats_std.pop();
        let bytes = encode_monitor(&snap);
        let err = decode_monitor(&bytes).unwrap_err();
        assert!(
            matches!(err, SnapshotError::Inconsistent { field: "stats_std", .. }),
            "got {err:?}"
        );
    }

    #[test]
    fn undersized_capacity_is_named() {
        let mut snap = sample();
        snap.capacity = 2 * snap.params.sax.s - 1;
        assert!(matches!(
            snap.validate().unwrap_err(),
            SnapshotError::Inconsistent { field: "capacity", .. }
        ));
    }

    #[test]
    fn tampered_params_fail_the_strict_validator() {
        // Splice an invalid-but-parseable params blob into the encoded
        // meta section, with a recomputed CRC so only the validator can
        // catch it: the decode must fail with `BadParams`, never hand
        // back a monitor built on params the service would reject.
        let snap = sample();
        let mut bytes = encode_monitor(&snap);
        let needle = b"\"s\":4".as_slice();
        let at = bytes
            .windows(needle.len())
            .position(|w| w == needle)
            .expect("params JSON embedded in the meta section");
        bytes[at + 4] = b'0'; // "s":4 -> "s":0 (same length, CRC re-done below)
        // meta is the first section: header at 16, payload from 28
        let len = u32::from_le_bytes([bytes[20], bytes[21], bytes[22], bytes[23]]) as usize;
        let crc = super::super::crc32(&bytes[28..28 + len]);
        bytes[24..28].copy_from_slice(&crc.to_le_bytes());
        let err = decode_monitor(&bytes).unwrap_err();
        assert!(matches!(err, SnapshotError::BadParams { .. }), "got {err:?}");
        assert!(err.to_string().contains("`params`"));
    }

    #[test]
    fn wrong_kind_byte_is_a_layout_error() {
        let snap = sample();
        let mut bytes = encode_monitor(&snap);
        bytes[3] = SnapshotKind::Context.code();
        let err = decode_monitor(&bytes).unwrap_err();
        // the first section is monitor_meta where the context layout
        // expects its fingerprint
        assert!(
            matches!(err, SnapshotError::SectionOrder { .. }),
            "got {err:?}"
        );
    }
}
