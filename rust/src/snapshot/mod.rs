//! Durable warm state: the versioned `.hsts` snapshot codec.
//!
//! Everything the paper's warm-up machinery earns — the exactly-evaluated
//! [`NndProfile`](crate::discord::NndProfile) upper bounds in a
//! [`SearchContext`](crate::context::SearchContext) and the rolling window
//! state of a [`StreamingMonitor`](crate::stream::StreamingMonitor) — dies
//! with the process today. This module gives that state a durable binary
//! form so a restarted service resumes *warm*: a restore-then-refresh is
//! bit-identical to the run that never stopped, with `prep_calls == 0` and
//! strictly fewer distance calls than a cold restart (ROADMAP item 3b).
//!
//! # Format
//!
//! A snapshot file follows the [`crate::service::frame`] conventions —
//! little-endian, a fixed header validated before any payload allocation,
//! every decode failure a **named** [`SnapshotError`], never a panic:
//!
//! ```text
//! offset  size  field
//! 0       2     magic          0xB5 0x53
//! 2       1     version        1
//! 3       1     kind           context = 1 | monitor = 2
//! 4       4     section_count  u32 LE
//! 8       8     payload_len    u64 LE (bytes after this 16-byte header)
//! ```
//!
//! The payload is `section_count` back-to-back sections, each:
//!
//! ```text
//! offset  size  field
//! 0       2     tag            u16 LE (see the section tags below)
//! 2       2     reserved       must be 0
//! 4       4     payload_len    u32 LE
//! 8       4     crc32          u32 LE (IEEE, over the section payload)
//! 12      …     payload
//! ```
//!
//! Floats travel as raw `u64` bit patterns, so NaN payloads, `-0.0`, and
//! the `+inf` init sentinel survive a round trip bit for bit — the same
//! property the golden conformance snapshots pin with `{:016x}` hex.
//! Every section is CRC-protected; every length is checked against a hard
//! cap *and* the remaining input before a vector is allocated, so a
//! corrupted or hostile length can never drive an unbounded allocation.
//!
//! # Trust boundary
//!
//! The CRC + [`SeriesFingerprint`] catch corruption and
//! wrong-series restores; they do not make a snapshot *author* trusted. A
//! deliberately crafted profile with understated nnd entries would violate
//! the exactness invariant, so snapshot directories deserve the same trust
//! as the binary itself.

pub mod context;
pub mod monitor;
pub mod store;

pub use context::{decode_context, encode_context, ContextSnapshot, ProfileEntry};
pub use monitor::{decode_monitor, encode_monitor, MonitorSnapshot};
pub use store::{inspect, SectionInfo, Snapshot, SnapshotSummary};

use crate::dist::{DistanceKind, Kernel};

/// Snapshot file magic: `0xB5` (same first byte as the service frame
/// codec, top bit set so a text line can never alias it) then `0x53`
/// (ASCII `S` for snapshot; frames use `0x48`).
pub const SNAPSHOT_MAGIC: [u8; 2] = [0xB5, 0x53];

/// Snapshot format version. Any layout change bumps this; old readers
/// reject newer files with [`SnapshotError::BadVersion`] instead of
/// misreading them.
pub const SNAPSHOT_VERSION: u8 = 1;

/// File header length in bytes (validated before any payload read).
pub const SNAPSHOT_HEADER_LEN: usize = 16;

/// Per-section header length in bytes.
pub const SECTION_HEADER_LEN: usize = 12;

/// Canonical file extension for snapshot files.
pub const SNAPSHOT_EXT: &str = "hsts";

/// Hard cap on a whole snapshot payload (sections + bodies).
pub const MAX_SNAPSHOT_LEN: u64 = 256 * 1024 * 1024;

/// Hard cap on one section payload.
pub const MAX_SECTION_LEN: u32 = 32 * 1024 * 1024;

/// Hard cap on the number of sections in one file.
pub const MAX_SECTIONS: u32 = 4096;

/// Hard cap on one serialized vector's element count (matches the
/// service-layer `MAX_STREAM_WINDOW` bound with headroom).
pub const MAX_POINTS: u64 = 2 * 1024 * 1024;

/// What a snapshot file carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnapshotKind {
    /// A [`SearchContext`](crate::context::SearchContext) warm-profile
    /// cache, fingerprint-bound to its series.
    Context,
    /// A full [`StreamingMonitor`](crate::stream::StreamingMonitor)
    /// state: window deques, offsets, rolling stats, warm profile.
    Monitor,
}

impl SnapshotKind {
    /// Every defined kind, for sweeping tests and docs.
    pub const ALL: [SnapshotKind; 2] = [SnapshotKind::Context, SnapshotKind::Monitor];

    /// Wire code of this kind.
    pub fn code(self) -> u8 {
        match self {
            SnapshotKind::Context => 1,
            SnapshotKind::Monitor => 2,
        }
    }

    /// Human-readable name (stable; used by `hst snapshot inspect`).
    pub fn name(self) -> &'static str {
        match self {
            SnapshotKind::Context => "context",
            SnapshotKind::Monitor => "monitor",
        }
    }

    /// Decode a wire code.
    pub fn from_code(code: u8) -> Option<SnapshotKind> {
        match code {
            1 => Some(SnapshotKind::Context),
            2 => Some(SnapshotKind::Monitor),
            _ => None,
        }
    }
}

// Section tags. Context sections first, monitor sections from 0x0010.
pub(crate) const TAG_FINGERPRINT: u16 = 0x0001;
pub(crate) const TAG_PROFILE: u16 = 0x0002;
pub(crate) const TAG_MONITOR_META: u16 = 0x0010;
pub(crate) const TAG_MONITOR_WINDOW: u16 = 0x0011;
pub(crate) const TAG_MONITOR_STATS: u16 = 0x0012;
pub(crate) const TAG_MONITOR_WORDS: u16 = 0x0013;
pub(crate) const TAG_MONITOR_PROFILE: u16 = 0x0014;

/// Stable name of a section tag, if the tag is defined.
pub fn tag_name(tag: u16) -> Option<&'static str> {
    match tag {
        TAG_FINGERPRINT => Some("fingerprint"),
        TAG_PROFILE => Some("profile"),
        TAG_MONITOR_META => Some("monitor_meta"),
        TAG_MONITOR_WINDOW => Some("monitor_window"),
        TAG_MONITOR_STATS => Some("monitor_stats"),
        TAG_MONITOR_WORDS => Some("monitor_words"),
        TAG_MONITOR_PROFILE => Some("monitor_profile"),
        _ => None,
    }
}

/// Every way a snapshot decode or restore can fail. Each variant names
/// the offending field — corruption must surface as one of these, never
/// as a panic or a silently-warm state.
#[derive(Debug, Clone, PartialEq)]
pub enum SnapshotError {
    /// The first two bytes are not [`SNAPSHOT_MAGIC`].
    BadMagic {
        /// The bytes found instead.
        found: [u8; 2],
    },
    /// The version byte is not [`SNAPSHOT_VERSION`].
    BadVersion {
        /// The version byte found.
        found: u8,
    },
    /// The kind byte maps to no [`SnapshotKind`].
    BadKind {
        /// The kind byte found.
        found: u8,
    },
    /// A declared length exceeds its hard cap (rejected before any
    /// allocation).
    Oversized {
        /// Which length field overflowed.
        field: &'static str,
        /// The declared value.
        len: u64,
        /// The cap it violated.
        max: u64,
    },
    /// The input ends before a declared structure.
    Truncated {
        /// Bytes the decoder needed.
        needed: usize,
        /// Bytes actually available.
        have: usize,
    },
    /// Bytes remain after the declared payload.
    TrailingBytes {
        /// How many undeclared bytes follow.
        extra: usize,
    },
    /// The header declares more sections than [`MAX_SECTIONS`].
    SectionCount {
        /// The declared section count.
        declared: u32,
    },
    /// A section tag maps to no defined section.
    BadSectionTag {
        /// The tag found.
        found: u16,
    },
    /// A known section appeared where the kind's layout expects another.
    SectionOrder {
        /// The section the layout expects here.
        expected: &'static str,
        /// The section actually found.
        found: &'static str,
    },
    /// A section's reserved bytes are not zero.
    BadReserved {
        /// The reserved value found.
        found: u16,
    },
    /// A section payload failed its CRC32 check.
    BadChecksum {
        /// Which section failed.
        section: &'static str,
        /// CRC stored in the section header.
        stored: u32,
        /// CRC computed over the payload.
        computed: u32,
    },
    /// A distance-kind code maps to no [`DistanceKind`].
    BadDistanceKind {
        /// The code found.
        found: u8,
    },
    /// A kernel code maps to no [`Kernel`].
    BadKernel {
        /// The code found.
        found: u8,
    },
    /// The embedded search params failed strict JSON validation.
    BadParams {
        /// The validator's message.
        detail: String,
    },
    /// A string field is not valid UTF-8.
    BadUtf8 {
        /// Which field failed.
        field: &'static str,
    },
    /// Decoded fields violate a cross-field invariant (e.g. deque
    /// lengths that cannot describe one window).
    Inconsistent {
        /// Which field is inconsistent.
        field: &'static str,
        /// What relationship it violates.
        detail: String,
    },
    /// The snapshot's series fingerprint does not match the series it
    /// was asked to warm — restoring would seed bounds for the wrong
    /// data, so the restore is refused.
    FingerprintMismatch {
        /// Fingerprint stored in the snapshot.
        expected: SeriesFingerprint,
        /// Fingerprint of the series offered at restore.
        found: SeriesFingerprint,
    },
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::BadMagic { found } => write!(
                f,
                "snapshot field `magic` is {found:02x?}, expected {SNAPSHOT_MAGIC:02x?}"
            ),
            SnapshotError::BadVersion { found } => write!(
                f,
                "snapshot field `version` is {found}, this build reads version \
                 {SNAPSHOT_VERSION}"
            ),
            SnapshotError::BadKind { found } => {
                write!(f, "snapshot field `kind` is {found}, not a defined snapshot kind")
            }
            SnapshotError::Oversized { field, len, max } => write!(
                f,
                "snapshot field `{field}` declares {len}, above the cap of {max}"
            ),
            SnapshotError::Truncated { needed, have } => write!(
                f,
                "snapshot truncated: field `payload` needs {needed} bytes, only \
                 {have} present"
            ),
            SnapshotError::TrailingBytes { extra } => write!(
                f,
                "snapshot field `payload_len` leaves {extra} undeclared trailing bytes"
            ),
            SnapshotError::SectionCount { declared } => write!(
                f,
                "snapshot field `section_count` is {declared}, above the cap of \
                 {MAX_SECTIONS}"
            ),
            SnapshotError::BadSectionTag { found } => write!(
                f,
                "snapshot field `tag` is {found:#06x}, not a defined section tag"
            ),
            SnapshotError::SectionOrder { expected, found } => write!(
                f,
                "snapshot field `tag` holds section `{found}` where the layout \
                 expects `{expected}`"
            ),
            SnapshotError::BadReserved { found } => write!(
                f,
                "snapshot field `reserved` is {found}, must be 0"
            ),
            SnapshotError::BadChecksum {
                section,
                stored,
                computed,
            } => write!(
                f,
                "snapshot field `crc32` of section `{section}` is {stored:#010x}, \
                 payload hashes to {computed:#010x}"
            ),
            SnapshotError::BadDistanceKind { found } => write!(
                f,
                "snapshot field `distance_kind` is {found}, not a defined kind"
            ),
            SnapshotError::BadKernel { found } => {
                write!(f, "snapshot field `kernel` is {found}, not a defined kernel")
            }
            SnapshotError::BadParams { detail } => {
                write!(f, "snapshot field `params` failed validation: {detail}")
            }
            SnapshotError::BadUtf8 { field } => {
                write!(f, "snapshot field `{field}` is not valid UTF-8")
            }
            SnapshotError::Inconsistent { field, detail } => {
                write!(f, "snapshot field `{field}` is inconsistent: {detail}")
            }
            SnapshotError::FingerprintMismatch { expected, found } => write!(
                f,
                "snapshot field `fingerprint` is len={} hash={:016x}, the offered \
                 series is len={} hash={:016x} — refusing to warm the wrong series",
                expected.len, expected.hash, found.len, found.hash
            ),
        }
    }
}

impl std::error::Error for SnapshotError {}

/// Identity of the series a context snapshot may warm: point count plus
/// an FNV-1a hash over the raw `f64` bit patterns. Two series that differ
/// in any bit of any point fingerprint differently, so a snapshot can
/// never silently seed bounds for data it was not computed on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeriesFingerprint {
    /// Number of points.
    pub len: u64,
    /// FNV-1a 64-bit hash over each point's little-endian bit pattern.
    pub hash: u64,
}

impl SeriesFingerprint {
    /// Fingerprint a series.
    pub fn of(points: &[f64]) -> SeriesFingerprint {
        let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
        for &p in points {
            for b in p.to_bits().to_le_bytes() {
                hash ^= b as u64;
                hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        SeriesFingerprint {
            len: points.len() as u64,
            hash,
        }
    }
}

/// CRC32 (IEEE polynomial, reflected — the zlib/PNG variant), bitwise so
/// the crate stays dependency-free.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Wire code of a [`DistanceKind`].
pub fn distance_kind_code(kind: DistanceKind) -> u8 {
    match kind {
        DistanceKind::Znorm => 1,
        DistanceKind::Raw => 2,
    }
}

/// Decode a [`DistanceKind`] wire code.
pub fn distance_kind_from_code(code: u8) -> Result<DistanceKind, SnapshotError> {
    match code {
        1 => Ok(DistanceKind::Znorm),
        2 => Ok(DistanceKind::Raw),
        other => Err(SnapshotError::BadDistanceKind { found: other }),
    }
}

/// Wire code of a [`Kernel`].
pub fn kernel_code(kernel: Kernel) -> u8 {
    match kernel {
        Kernel::Scalar => 1,
        Kernel::Simd => 2,
    }
}

/// Decode a [`Kernel`] wire code.
pub fn kernel_from_code(code: u8) -> Result<Kernel, SnapshotError> {
    match code {
        1 => Ok(Kernel::Scalar),
        2 => Ok(Kernel::Simd),
        other => Err(SnapshotError::BadKernel { found: other }),
    }
}

// ---------------------------------------------------------------------
// wire primitives shared by the context and monitor codecs
// ---------------------------------------------------------------------

/// Bounds-checked little-endian reader over a byte slice. Every read
/// fails with [`SnapshotError::Truncated`] instead of slicing past the
/// end, and nothing here allocates.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn need(&self, n: usize) -> Result<(), SnapshotError> {
        match self.pos.checked_add(n) {
            Some(end) if end <= self.buf.len() => Ok(()),
            _ => Err(SnapshotError::Truncated {
                needed: self.pos.saturating_add(n),
                have: self.buf.len(),
            }),
        }
    }

    pub(crate) fn bytes(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        self.need(n)?;
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.bytes(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> Result<u16, SnapshotError> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub(crate) fn u32(&mut self) -> Result<u32, SnapshotError> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, SnapshotError> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Element count for a vector about to be read: capped, and the
    /// bytes it implies must actually be present *before* allocating.
    pub(crate) fn count(
        &mut self,
        field: &'static str,
        elem_bytes: usize,
    ) -> Result<usize, SnapshotError> {
        let n = self.u64()?;
        if n > MAX_POINTS {
            return Err(SnapshotError::Oversized {
                field,
                len: n,
                max: MAX_POINTS,
            });
        }
        let n = n as usize;
        self.need(n.saturating_mul(elem_bytes))?;
        Ok(n)
    }

    /// `n` raw f64 bit patterns (no float math — bits survive verbatim).
    pub(crate) fn f64_bits(&mut self, n: usize) -> Result<Vec<f64>, SnapshotError> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f64::from_bits(self.u64()?));
        }
        Ok(out)
    }

    pub(crate) fn u64_vec(&mut self, n: usize) -> Result<Vec<u64>, SnapshotError> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    /// A length-prefixed UTF-8 string (u16 length).
    pub(crate) fn string(&mut self, field: &'static str) -> Result<String, SnapshotError> {
        let n = self.u16()? as usize;
        let raw = self.bytes(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| SnapshotError::BadUtf8 { field })
    }

    /// The section payload must be fully consumed — leftover bytes mean
    /// the writer and reader disagree about the layout.
    pub(crate) fn finish(&self, field: &'static str) -> Result<(), SnapshotError> {
        if self.remaining() != 0 {
            return Err(SnapshotError::Inconsistent {
                field,
                detail: format!("{} undeclared bytes at the section tail", self.remaining()),
            });
        }
        Ok(())
    }
}

pub(crate) fn push_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_string(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    debug_assert!(bytes.len() <= u16::MAX as usize);
    push_u16(out, bytes.len().min(u16::MAX as usize) as u16);
    out.extend_from_slice(&bytes[..bytes.len().min(u16::MAX as usize)]);
}

/// Append one CRC-protected section.
pub(crate) fn push_section(out: &mut Vec<u8>, tag: u16, payload: &[u8]) {
    debug_assert!(payload.len() <= MAX_SECTION_LEN as usize);
    push_u16(out, tag);
    push_u16(out, 0); // reserved
    push_u32(out, payload.len() as u32);
    push_u32(out, crc32(payload));
    out.extend_from_slice(payload);
}

/// Assemble a complete snapshot file from its sections body.
pub(crate) fn assemble(kind: SnapshotKind, section_count: u32, body: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(SNAPSHOT_HEADER_LEN + body.len());
    out.extend_from_slice(&SNAPSHOT_MAGIC);
    out.push(SNAPSHOT_VERSION);
    out.push(kind.code());
    push_u32(&mut out, section_count);
    push_u64(&mut out, body.len() as u64);
    out.extend_from_slice(&body);
    out
}

/// Decode and validate the 16-byte file header: magic, version, kind,
/// section count and payload length (both capped, and the payload length
/// must match the input exactly — short is [`SnapshotError::Truncated`],
/// long is [`SnapshotError::TrailingBytes`]).
pub fn decode_header(bytes: &[u8]) -> Result<(SnapshotKind, u32), SnapshotError> {
    if bytes.len() < SNAPSHOT_HEADER_LEN {
        return Err(SnapshotError::Truncated {
            needed: SNAPSHOT_HEADER_LEN,
            have: bytes.len(),
        });
    }
    let magic = [bytes[0], bytes[1]];
    if magic != SNAPSHOT_MAGIC {
        return Err(SnapshotError::BadMagic { found: magic });
    }
    if bytes[2] != SNAPSHOT_VERSION {
        return Err(SnapshotError::BadVersion { found: bytes[2] });
    }
    let kind = SnapshotKind::from_code(bytes[3])
        .ok_or(SnapshotError::BadKind { found: bytes[3] })?;
    let section_count = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if section_count > MAX_SECTIONS {
        return Err(SnapshotError::SectionCount {
            declared: section_count,
        });
    }
    let payload_len = u64::from_le_bytes([
        bytes[8], bytes[9], bytes[10], bytes[11], bytes[12], bytes[13], bytes[14],
        bytes[15],
    ]);
    if payload_len > MAX_SNAPSHOT_LEN {
        return Err(SnapshotError::Oversized {
            field: "payload_len",
            len: payload_len,
            max: MAX_SNAPSHOT_LEN,
        });
    }
    let have = (bytes.len() - SNAPSHOT_HEADER_LEN) as u64;
    if have < payload_len {
        return Err(SnapshotError::Truncated {
            needed: SNAPSHOT_HEADER_LEN + payload_len as usize,
            have: bytes.len(),
        });
    }
    if have > payload_len {
        return Err(SnapshotError::TrailingBytes {
            extra: (have - payload_len) as usize,
        });
    }
    Ok((kind, section_count))
}

/// The kind of a snapshot, from its header alone (used by restore-on-boot
/// to dispatch files and by `hst snapshot inspect`).
pub fn decode_kind(bytes: &[u8]) -> Result<SnapshotKind, SnapshotError> {
    decode_header(bytes).map(|(kind, _)| kind)
}

/// One decoded section: its tag and CRC-verified payload.
pub(crate) struct Section<'a> {
    pub(crate) tag: u16,
    pub(crate) payload: &'a [u8],
    /// Byte offset of this section's header within the file.
    pub(crate) offset: usize,
}

/// Walk the section table after [`decode_header`] accepted the file.
/// Tags must be defined, reserved bytes zero, lengths capped and inside
/// the input, and every payload must hash to its stored CRC.
pub(crate) fn decode_sections(bytes: &[u8]) -> Result<Vec<Section<'_>>, SnapshotError> {
    let (_, section_count) = decode_header(bytes)?;
    let mut r = Reader::new(&bytes[SNAPSHOT_HEADER_LEN..]);
    let mut out = Vec::with_capacity(section_count.min(64) as usize);
    for _ in 0..section_count {
        let offset = SNAPSHOT_HEADER_LEN + r.pos;
        let tag = r.u16()?;
        let name = tag_name(tag).ok_or(SnapshotError::BadSectionTag { found: tag })?;
        let reserved = r.u16()?;
        if reserved != 0 {
            return Err(SnapshotError::BadReserved { found: reserved });
        }
        let len = r.u32()?;
        if len > MAX_SECTION_LEN {
            return Err(SnapshotError::Oversized {
                field: "section payload_len",
                len: len as u64,
                max: MAX_SECTION_LEN as u64,
            });
        }
        let stored = r.u32()?;
        let payload = r.bytes(len as usize)?;
        let computed = crc32(payload);
        if stored != computed {
            return Err(SnapshotError::BadChecksum {
                section: name,
                stored,
                computed,
            });
        }
        out.push(Section {
            tag,
            payload,
            offset,
        });
    }
    if r.remaining() != 0 {
        return Err(SnapshotError::TrailingBytes {
            extra: r.remaining(),
        });
    }
    Ok(out)
}

/// Expect the next section to carry `tag`, by layout position.
pub(crate) fn expect_section<'a, 'b>(
    sections: &'b [Section<'a>],
    index: usize,
    tag: u16,
) -> Result<&'b Section<'a>, SnapshotError> {
    let expected = tag_name(tag).expect("expect_section called with a defined tag");
    let Some(s) = sections.get(index) else {
        return Err(SnapshotError::SectionOrder {
            expected,
            found: "end of file",
        });
    };
    if s.tag != tag {
        return Err(SnapshotError::SectionOrder {
            expected,
            found: tag_name(s.tag).unwrap_or("unknown"),
        });
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // standard IEEE check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn fingerprint_separates_series_and_is_bit_sensitive() {
        let a = SeriesFingerprint::of(&[1.0, 2.0, 3.0]);
        let b = SeriesFingerprint::of(&[1.0, 2.0, 3.0]);
        assert_eq!(a, b);
        let c = SeriesFingerprint::of(&[1.0, 2.0, 3.0000000000000004]);
        assert_ne!(a.hash, c.hash, "one-ulp change must re-fingerprint");
        // -0.0 and 0.0 are distinct bit patterns, so they must differ
        assert_ne!(
            SeriesFingerprint::of(&[0.0]).hash,
            SeriesFingerprint::of(&[-0.0]).hash
        );
        assert_eq!(SeriesFingerprint::of(&[]).len, 0);
    }

    #[test]
    fn header_rejects_each_field_by_name() {
        let good = assemble(SnapshotKind::Context, 0, Vec::new());
        assert_eq!(decode_header(&good), Ok((SnapshotKind::Context, 0)));

        let mut bad = good.clone();
        bad[0] = 0x00;
        let err = decode_header(&bad).unwrap_err();
        assert_eq!(err, SnapshotError::BadMagic { found: [0x00, 0x53] });
        assert!(err.to_string().contains("`magic`"));

        let mut bad = good.clone();
        bad[2] = SNAPSHOT_VERSION + 1;
        let err = decode_header(&bad).unwrap_err();
        assert_eq!(
            err,
            SnapshotError::BadVersion {
                found: SNAPSHOT_VERSION + 1
            }
        );
        assert!(err.to_string().contains("`version`"));

        let mut bad = good.clone();
        bad[3] = 9;
        assert_eq!(
            decode_header(&bad).unwrap_err(),
            SnapshotError::BadKind { found: 9 }
        );

        // short input: truncated by name, never a slice panic
        assert_eq!(
            decode_header(&good[..7]).unwrap_err(),
            SnapshotError::Truncated {
                needed: SNAPSHOT_HEADER_LEN,
                have: 7
            }
        );

        // an oversized payload_len is rejected from the header alone
        let mut bad = good.clone();
        bad[8..16].copy_from_slice(&(MAX_SNAPSHOT_LEN + 1).to_le_bytes());
        assert!(matches!(
            decode_header(&bad).unwrap_err(),
            SnapshotError::Oversized {
                field: "payload_len",
                ..
            }
        ));

        // undeclared trailing bytes are named, not ignored
        let mut bad = good;
        bad.push(0xAA);
        assert_eq!(
            decode_header(&bad).unwrap_err(),
            SnapshotError::TrailingBytes { extra: 1 }
        );
    }

    #[test]
    fn sections_validate_reserved_len_and_crc() {
        let mut body = Vec::new();
        push_section(&mut body, TAG_FINGERPRINT, b"hello");
        let n = body.len() as u64;
        let mut file = assemble(SnapshotKind::Context, 1, body);
        assert_eq!(file.len() as u64, SNAPSHOT_HEADER_LEN as u64 + n);
        let sections = decode_sections(&file).expect("valid sections");
        assert_eq!(sections.len(), 1);
        assert_eq!(sections[0].payload, b"hello");
        assert_eq!(sections[0].offset, SNAPSHOT_HEADER_LEN);

        // flip one payload byte -> CRC failure names the section
        let last = file.len() - 1;
        file[last] ^= 0x01;
        let err = decode_sections(&file).unwrap_err();
        assert!(
            matches!(
                err,
                SnapshotError::BadChecksum {
                    section: "fingerprint",
                    ..
                }
            ),
            "got {err:?}"
        );
        assert!(err.to_string().contains("`crc32`"));
        file[last] ^= 0x01;

        // corrupt the reserved bytes
        file[SNAPSHOT_HEADER_LEN + 2] = 7;
        assert_eq!(
            decode_sections(&file).unwrap_err(),
            SnapshotError::BadReserved { found: 7 }
        );
        file[SNAPSHOT_HEADER_LEN + 2] = 0;

        // unknown tag
        file[SNAPSHOT_HEADER_LEN] = 0xEE;
        file[SNAPSHOT_HEADER_LEN + 1] = 0xEE;
        assert_eq!(
            decode_sections(&file).unwrap_err(),
            SnapshotError::BadSectionTag { found: 0xEEEE }
        );
    }

    #[test]
    fn reader_never_reads_past_the_end() {
        let mut r = Reader::new(&[1, 2, 3]);
        assert_eq!(r.u16().unwrap(), 0x0201);
        assert_eq!(
            r.u64().unwrap_err(),
            SnapshotError::Truncated { needed: 10, have: 3 }
        );
        // a huge declared count fails before allocating
        let mut buf = Vec::new();
        push_u64(&mut buf, u64::MAX);
        let mut r = Reader::new(&buf);
        assert!(matches!(
            r.count("nnd", 8).unwrap_err(),
            SnapshotError::Oversized { field: "nnd", .. }
        ));
    }

    #[test]
    fn codes_roundtrip() {
        for kind in SnapshotKind::ALL {
            assert_eq!(SnapshotKind::from_code(kind.code()), Some(kind));
            assert!(!kind.name().is_empty());
        }
        for k in [DistanceKind::Znorm, DistanceKind::Raw] {
            assert_eq!(distance_kind_from_code(distance_kind_code(k)).unwrap(), k);
        }
        for k in [Kernel::Scalar, Kernel::Simd] {
            assert_eq!(kernel_from_code(kernel_code(k)).unwrap(), k);
        }
        assert_eq!(
            distance_kind_from_code(0).unwrap_err(),
            SnapshotError::BadDistanceKind { found: 0 }
        );
        assert_eq!(
            kernel_from_code(9).unwrap_err(),
            SnapshotError::BadKernel { found: 9 }
        );
    }
}
