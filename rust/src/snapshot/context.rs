//! Context snapshots: the warm [`NndProfile`] cache of one
//! [`SearchContext`](crate::context::SearchContext), bound to its series
//! by a [`SeriesFingerprint`].
//!
//! Layout (after the file header): one `fingerprint` section carrying the
//! context's cache key (dataset spec, scale divisor, SAX params) and the
//! series identity, then one `profile` section per cached
//! `(s, DistanceKind, allow_self_match)` entry. Profiles are written in
//! sorted key order so encoding is deterministic — the same warm state
//! always produces the same bytes, which is what lets a `.hsts` golden
//! fixture pin the format.

use crate::config::SaxParams;
use crate::discord::{NndProfile, NO_NEIGHBOR};
use crate::dist::DistanceKind;

use super::{
    assemble, decode_sections, distance_kind_code, distance_kind_from_code,
    expect_section, push_section, push_string, push_u64, Reader, SeriesFingerprint,
    SnapshotError, SnapshotKind, MAX_POINTS, TAG_FINGERPRINT, TAG_PROFILE,
};

/// One cached warm profile and the cache key it lives under.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileEntry {
    /// Sequence length the profile covers.
    pub s: usize,
    /// Distance the bounds were evaluated under.
    pub kind: DistanceKind,
    /// Whether trivial self-matches were allowed.
    pub allow_self_match: bool,
    /// The exact-upper-bound profile itself.
    pub profile: NndProfile,
}

/// A [`SearchContext`](crate::context::SearchContext)'s durable warm
/// state, plus the coordinator cache key needed to rebuild the context it
/// belongs to on restore.
#[derive(Debug, Clone, PartialEq)]
pub struct ContextSnapshot {
    /// Dataset spec (registry name or `synthetic:` spec) the service
    /// rebuilds the series from.
    pub dataset: String,
    /// Length divisor the series was generated at.
    pub scale_div: u64,
    /// SAX params of the coordinator cache key.
    pub sax: SaxParams,
    /// Identity of the exact series the profiles were computed on.
    pub fingerprint: SeriesFingerprint,
    /// The cached profiles, one per `(s, kind, allow_self_match)` key.
    pub profiles: Vec<ProfileEntry>,
}

impl ContextSnapshot {
    /// Refuse to warm `points` unless they fingerprint identically to the
    /// series this snapshot was computed on.
    pub fn check_series(&self, points: &[f64]) -> Result<(), SnapshotError> {
        let found = SeriesFingerprint::of(points);
        if found != self.fingerprint {
            return Err(SnapshotError::FingerprintMismatch {
                expected: self.fingerprint,
                found,
            });
        }
        Ok(())
    }
}

/// Encode a context snapshot. Profiles are sorted by key so the output
/// is byte-deterministic regardless of cache iteration order.
pub fn encode_context(snap: &ContextSnapshot) -> Vec<u8> {
    let mut profiles: Vec<&ProfileEntry> = snap.profiles.iter().collect();
    profiles.sort_by_key(|e| (e.s, distance_kind_code(e.kind), e.allow_self_match));

    let mut body = Vec::new();
    let mut fp = Vec::new();
    push_string(&mut fp, &snap.dataset);
    push_u64(&mut fp, snap.scale_div);
    push_u64(&mut fp, snap.sax.s as u64);
    push_u64(&mut fp, snap.sax.p as u64);
    push_u64(&mut fp, snap.sax.alphabet as u64);
    push_u64(&mut fp, snap.fingerprint.len);
    push_u64(&mut fp, snap.fingerprint.hash);
    push_section(&mut body, TAG_FINGERPRINT, &fp);

    for entry in &profiles {
        let mut p = Vec::new();
        push_u64(&mut p, entry.s as u64);
        p.push(distance_kind_code(entry.kind));
        p.push(entry.allow_self_match as u8);
        push_u64(&mut p, entry.profile.nnd.len() as u64);
        for &v in &entry.profile.nnd {
            push_u64(&mut p, v.to_bits());
        }
        for &g in &entry.profile.ngh {
            push_u64(&mut p, g as u64);
        }
        push_section(&mut body, TAG_PROFILE, &p);
    }

    assemble(SnapshotKind::Context, 1 + profiles.len() as u32, body)
}

/// Decode a context snapshot, validating every field by name. Neighbor
/// entries must be in-range or the `u64::MAX` no-neighbor sentinel, and
/// the two profile vectors must agree in length — a file that decodes is
/// structurally safe to install.
pub fn decode_context(bytes: &[u8]) -> Result<ContextSnapshot, SnapshotError> {
    let (kind, _) = super::decode_header(bytes)?;
    if kind != SnapshotKind::Context {
        return Err(SnapshotError::SectionOrder {
            expected: "fingerprint",
            found: "monitor_meta",
        });
    }
    let sections = decode_sections(bytes)?;

    let fp = expect_section(&sections, 0, TAG_FINGERPRINT)?;
    let mut r = Reader::new(fp.payload);
    let dataset = r.string("dataset")?;
    let scale_div = r.u64()?;
    let sax = read_sax(&mut r)?;
    let fingerprint = SeriesFingerprint {
        len: r.u64()?,
        hash: r.u64()?,
    };
    r.finish("fingerprint")?;

    let mut profiles = Vec::with_capacity(sections.len() - 1);
    for i in 1..sections.len() {
        let sec = expect_section(&sections, i, TAG_PROFILE)?;
        let mut r = Reader::new(sec.payload);
        let s = r.u64()?;
        if s == 0 || s > MAX_POINTS {
            return Err(SnapshotError::Inconsistent {
                field: "profile s",
                detail: format!("sequence length {s} is outside (0, {MAX_POINTS}]"),
            });
        }
        let kind = distance_kind_from_code(r.u8()?)?;
        let allow_self_match = match r.u8()? {
            0 => false,
            1 => true,
            other => {
                return Err(SnapshotError::Inconsistent {
                    field: "allow_self_match",
                    detail: format!("flag byte is {other}, must be 0 or 1"),
                })
            }
        };
        let n = r.count("profile nnd", 16)?;
        let nnd = r.f64_bits(n)?;
        let ngh_raw = r.u64_vec(n)?;
        r.finish("profile")?;
        let mut ngh = Vec::with_capacity(n);
        for &g in &ngh_raw {
            if g == u64::MAX {
                ngh.push(NO_NEIGHBOR);
            } else if (g as usize) < n {
                ngh.push(g as usize);
            } else {
                return Err(SnapshotError::Inconsistent {
                    field: "profile ngh",
                    detail: format!("neighbor {g} is outside the {n}-sequence profile"),
                });
            }
        }
        profiles.push(ProfileEntry {
            s: s as usize,
            kind,
            allow_self_match,
            profile: NndProfile { nnd, ngh },
        });
    }

    Ok(ContextSnapshot {
        dataset,
        scale_div,
        sax,
        fingerprint,
        profiles,
    })
}

fn read_sax(r: &mut Reader<'_>) -> Result<SaxParams, SnapshotError> {
    let s = r.u64()?;
    let p = r.u64()?;
    let alphabet = r.u64()?;
    if s == 0 || s > MAX_POINTS || p == 0 || p > s || alphabet == 0 || alphabet > 256 {
        return Err(SnapshotError::Inconsistent {
            field: "sax",
            detail: format!("s={s} p={p} alphabet={alphabet} is not a valid SAX triple"),
        });
    }
    let sax = SaxParams {
        s: s as usize,
        p: p as usize,
        alphabet: alphabet as usize,
    };
    sax.validate().map_err(|detail| SnapshotError::Inconsistent {
        field: "sax",
        detail,
    })?;
    Ok(sax)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::discord::NND_INIT;

    fn sample() -> ContextSnapshot {
        let mut profile = NndProfile::new(6);
        profile.observe(0, 3, 1.25);
        profile.observe(1, 4, f64::MIN_POSITIVE);
        profile.nnd[5] = -0.0; // awkward bit patterns must survive
        profile.ngh[5] = 2;
        ContextSnapshot {
            dataset: "ECG 108".to_string(),
            scale_div: 8,
            sax: SaxParams { s: 96, p: 4, alphabet: 4 },
            fingerprint: SeriesFingerprint { len: 1500, hash: 0xDEAD_BEEF_1234_5678 },
            profiles: vec![ProfileEntry {
                s: 96,
                kind: DistanceKind::Znorm,
                allow_self_match: false,
                profile,
            }],
        }
    }

    #[test]
    fn roundtrip_is_bitwise() {
        let snap = sample();
        let bytes = encode_context(&snap);
        let back = decode_context(&bytes).expect("roundtrip");
        assert_eq!(back.dataset, snap.dataset);
        assert_eq!(back.scale_div, snap.scale_div);
        assert_eq!(back.sax, snap.sax);
        assert_eq!(back.fingerprint, snap.fingerprint);
        assert_eq!(back.profiles.len(), 1);
        let (a, b) = (&snap.profiles[0].profile, &back.profiles[0].profile);
        for i in 0..a.nnd.len() {
            assert_eq!(a.nnd[i].to_bits(), b.nnd[i].to_bits(), "nnd[{i}] bits");
            assert_eq!(a.ngh[i], b.ngh[i]);
        }
        assert_eq!(b.nnd[2].to_bits(), NND_INIT.to_bits(), "inf sentinel survives");
    }

    #[test]
    fn encoding_is_deterministic_under_profile_order() {
        let mut snap = sample();
        let mut second = snap.profiles[0].clone();
        second.s = 48;
        second.kind = DistanceKind::Raw;
        snap.profiles.push(second);
        let a = encode_context(&snap);
        snap.profiles.reverse();
        let b = encode_context(&snap);
        assert_eq!(a, b, "profile iteration order must not leak into bytes");
    }

    #[test]
    fn out_of_range_neighbor_is_named() {
        let mut snap = sample();
        snap.profiles[0].profile.ngh[0] = 1_000; // > n = 6
        let bytes = encode_context(&snap);
        let err = decode_context(&bytes).unwrap_err();
        assert!(
            matches!(err, SnapshotError::Inconsistent { field: "profile ngh", .. }),
            "got {err:?}"
        );
        assert!(err.to_string().contains("`profile ngh`"));
    }

    #[test]
    fn fingerprint_guard_refuses_other_series() {
        let points: Vec<f64> = (0..1500).map(|i| i as f64).collect();
        let mut snap = sample();
        snap.fingerprint = SeriesFingerprint::of(&points);
        assert!(snap.check_series(&points).is_ok());
        let mut other = points.clone();
        other[700] += 1.0e-9;
        let err = snap.check_series(&other).unwrap_err();
        assert!(matches!(err, SnapshotError::FingerprintMismatch { .. }));
        assert!(err.to_string().contains("`fingerprint`"));
    }
}
