//! `hst-md` — SAX-guided exact multivariate discord search, serial and
//! sharded-parallel.
//!
//! The engine lifts the HOT SAX Time machinery to the k-of-d aggregate:
//!
//! * **Per-channel SAX words** (each channel's cached
//!   [`SaxIndex`](crate::sax::SaxIndex), built with the shared
//!   [`WordBuilder`](crate::sax::WordBuilder) kernel) combine into a
//!   *joint* index: two sequences share a joint cluster iff they share a
//!   word in every selected channel.
//! * **Outer loop** — candidates ordered by *summed per-channel bucket
//!   rarity* (ascending Σ_c |cluster_c(i)|): a sequence rare in several
//!   channels at once is the most promising aggregate discord, the
//!   multivariate reading of HOT SAX's smallest-bucket-first heuristic.
//!   On a warm profile the order switches to descending approximate nnd,
//!   as in HST's later passes.
//! * **Inner loop** — literally the serial HST minimization
//!   ([`algo::hst::minimize`](crate::algo::hst)) running over the
//!   aggregate [`MdimDistance`](super::MdimDistance): same-joint-cluster
//!   first, then remaining joint clusters smallest-first, pruning the
//!   candidate as soon as its aggregate nnd upper bound drops strictly
//!   below the best-so-far. The aggregate's *cross-channel early
//!   abandoning* means each pair evaluation stops — mid-channel or
//!   between channels — the moment its partial sum proves it useless.
//! * **Warm profiles** — the evolving aggregate profile persists across
//!   searches through the [`MdimContext`] cache (single-channel subsets
//!   interoperate with the univariate `SearchContext` cache directly).
//! * **Sharding** — at ≥ 2 resolved workers each pass seeds the
//!   best-so-far bound with the top candidate serially, then shards the
//!   remaining candidates over the [`exec`](crate::exec) pool exactly
//!   like `hst-par`: per-worker profile clones and private distance
//!   sessions, a shared [`AtomicF64`] CAS-max bound re-read inside the
//!   inner loop, pointwise-min merge in worker order, lowest-index
//!   tie-break.
//!
//! **Result determinism** follows the `hst-par` argument verbatim: a
//! candidate is only ever discarded when its aggregate upper bound drops
//! *strictly* below an exact aggregate nnd of the same pass, so the
//! global maximum always survives with its exact (bit-identical to
//! serial, hence to `brute-md`) aggregate distance at any thread count.
//! Distance-call *counts* at ≥ 2 workers depend on bound propagation
//! (each is still the exact sum of per-worker counters), and a tied
//! `neighbor` may be any of the bit-equal witnesses.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::algo::hst::{minimize, sort_by_nnd_desc, ScanOrder};
use crate::algo::{Algorithm, SearchReport};
use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::{Discord, ExclusionZones, NndProfile};
use crate::dist::Distance as _;
use crate::exec::{AtomicF64, ChunkQueue, ExecPolicy};
use crate::sax::SaxIndex;
use crate::ts::{MultiSeries, SeqStats};
use crate::util::rng::Rng64;

use super::dist::MdimDistance;
use super::{MdimAlgorithm, MdimContext, MdimParams, MdimReport};

/// The SAX-guided multivariate engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct HstMd {
    /// Worker threads. `0` (the default) falls through to
    /// [`SearchParams::threads`], then the shared [`ExecPolicy`]
    /// resolution (`HST_THREADS`, then available parallelism).
    ///
    /// [`SearchParams::threads`]: crate::config::SearchParams::threads
    pub threads: usize,
}

/// One worker's pass contribution: refined profile clone, confirmed
/// candidates (position, exact aggregate nnd), distance calls.
type WorkerOutcome = Result<(NndProfile, Vec<(usize, f64)>, u64)>;

/// Everything a pass needs that is fixed per search (bundled to keep the
/// serial/parallel pass signatures readable).
struct PassState<'a> {
    ms: &'a MultiSeries,
    stats: &'a [Arc<SeqStats>],
    channels: &'a [usize],
    joint: &'a SaxIndex,
    /// Σ_c |cluster_c(i)| per sequence — the outer-loop rarity key.
    rarity: &'a [f64],
    params: &'a SearchParams,
    /// Inner-loop kernel every aggregate session of this search runs on
    /// (the context's choice, fixed per search).
    kernel: crate::dist::Kernel,
}

impl HstMd {
    fn resolve_threads(&self, params: &SearchParams) -> usize {
        let requested = if self.threads > 0 {
            self.threads
        } else {
            params.threads
        };
        ExecPolicy::new(requested).resolve()
    }

    /// The outer candidate order for one pass: summed-bucket-rarity
    /// ascending while the profile is cold, descending approximate nnd
    /// once it carries information (ties by index either way).
    fn pass_order(
        st: &PassState,
        profile: &NndProfile,
        zones: &ExclusionZones,
        warm: bool,
    ) -> Vec<usize> {
        let s = st.params.sax.s;
        let mut order: Vec<usize> = (0..st.joint.len())
            .filter(|&i| zones.allowed(i, s))
            .collect();
        if warm {
            sort_by_nnd_desc(&mut order, &profile.nnd);
        } else {
            order.sort_unstable_by(|&a, &b| {
                st.rarity[a]
                    .partial_cmp(&st.rarity[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
        }
        order
    }

    /// One serial pass: find the best aggregate discord not excluded by
    /// `zones`, refining the shared profile. Returns the discord (if
    /// any) and the pass's distance-call total.
    #[allow(clippy::too_many_arguments)] // mirrors the univariate pass
    fn pass_serial(
        &self,
        ctx: &MdimContext,
        st: &PassState,
        profile: &mut NndProfile,
        zones: &ExclusionZones,
        rng: &mut Rng64,
        warm: bool,
        base_calls: u64,
    ) -> Result<(Option<Discord>, u64)> {
        let s = st.params.sax.s;
        let allow = st.params.allow_self_match;
        let kind = st.params.distance_kind();
        let scan = ScanOrder::build(st.joint, rng);
        let order = Self::pass_order(st, profile, zones, warm);
        let agg =
            MdimDistance::with_kernel(st.ms, st.stats, st.channels, kind, st.kernel);

        let mut best_dist = 0.0f64;
        let mut best: Option<Discord> = None;
        for &i in &order {
            ctx.check(base_calls + agg.calls())?;
            // Avoid_low_nnds(): the carried aggregate upper bound prunes
            // for free; only a strict drop below an exact nnd discards.
            let mut can = profile.nnd[i] >= best_dist;
            if can {
                can = minimize(
                    i, &agg, st.joint, &scan, profile, &best_dist, s, allow,
                );
            }
            if can && profile.nnd[i].is_finite() {
                let nnd_i = profile.nnd[i];
                let better = match &best {
                    None => true,
                    Some(b) => {
                        nnd_i > b.nnd || (nnd_i == b.nnd && i < b.position)
                    }
                };
                if better {
                    best_dist = nnd_i;
                    best = Some(Discord {
                        position: i,
                        nnd: nnd_i,
                        neighbor: profile.ngh[i],
                    });
                }
            }
        }
        Ok((best, agg.calls()))
    }

    /// One sharded pass (≥ 2 workers), mirroring `hst-par`: serial seed,
    /// chunked candidate claims against a shared CAS-max bound, ordered
    /// pointwise-min merge, lowest-index tie-break.
    #[allow(clippy::too_many_arguments)]
    fn pass_par(
        &self,
        ctx: &MdimContext,
        st: &PassState,
        profile: &mut NndProfile,
        zones: &ExclusionZones,
        rng: &mut Rng64,
        warm: bool,
        threads: usize,
        published: &AtomicU64,
    ) -> Result<(Option<Discord>, u64)> {
        let s = st.params.sax.s;
        let allow = st.params.allow_self_match;
        let kind = st.params.distance_kind();
        let scan = ScanOrder::build(st.joint, rng);
        let order = Self::pass_order(st, profile, zones, warm);
        let Some(&lead) = order.first() else {
            return Ok((None, 0));
        };

        // Phase 1 — seed: the top candidate minimized serially on the
        // master profile, so no worker prunes against an empty bound.
        let seed =
            MdimDistance::with_kernel(st.ms, st.stats, st.channels, kind, st.kernel);
        let lead_ok =
            minimize(lead, &seed, st.joint, &scan, profile, &0.0f64, s, allow);
        let mut best: Option<(usize, f64)> = (lead_ok
            && profile.nnd[lead].is_finite())
        .then_some((lead, profile.nnd[lead]));
        let mut pass_calls = seed.calls();
        published.fetch_add(pass_calls, Ordering::Relaxed);
        ctx.check(published.load(Ordering::Relaxed))?;

        // Phase 2 — shard the remaining candidates.
        let rest = &order[1..];
        if !rest.is_empty() {
            let bound = AtomicF64::new(best.map_or(0.0, |(_, nnd)| nnd));
            let chunk = (rest.len() / (threads * 8)).clamp(16, 1024);
            let queue = ChunkQueue::new(rest, chunk);
            let master: &NndProfile = profile;

            let outcomes: Vec<WorkerOutcome> =
                crate::exec::scope_workers(threads, |_w| {
                    let agg = MdimDistance::with_kernel(
                        st.ms,
                        st.stats,
                        st.channels,
                        kind,
                        st.kernel,
                    );
                    let mut local = master.clone();
                    let mut winners: Vec<(usize, f64)> = Vec::new();
                    let mut reported = 0u64;
                    while let Some((_ci, slice)) = queue.take() {
                        for &i in slice {
                            // publish this session's delta, enforce
                            // budget/cancellation on the global sum
                            let delta = agg.calls() - reported;
                            reported = agg.calls();
                            let total = published
                                .fetch_add(delta, Ordering::Relaxed)
                                + delta;
                            ctx.check(total)?;

                            let mut can = local.nnd[i] >= bound.load();
                            if can {
                                can = minimize(
                                    i, &agg, st.joint, &scan, &mut local,
                                    &bound, s, allow,
                                );
                            }
                            if can && local.nnd[i].is_finite() {
                                // exact aggregate nnd: publish so every
                                // worker prunes against it immediately
                                bound.fetch_max(local.nnd[i]);
                                winners.push((i, local.nnd[i]));
                            }
                        }
                    }
                    published.fetch_add(
                        agg.calls() - reported,
                        Ordering::Relaxed,
                    );
                    Ok((local, winners, agg.calls()))
                });

            // Phase 3 — ordered merge (worker 0 first).
            for outcome in outcomes {
                let (local, winners, calls) = outcome?;
                profile.merge_min(&local);
                pass_calls += calls;
                for (i, nnd) in winners {
                    best = match best {
                        None => Some((i, nnd)),
                        Some((bi, bn)) if nnd > bn || (nnd == bn && i < bi) => {
                            Some((i, nnd))
                        }
                        keep => keep,
                    };
                }
            }
        }

        let found = best.map(|(i, nnd)| Discord {
            position: i,
            nnd,
            neighbor: profile.ngh[i],
        });
        Ok((found, pass_calls))
    }
}

impl MdimAlgorithm for HstMd {
    fn name(&self) -> &'static str {
        "hst-md"
    }

    fn run_md(&self, ctx: &MdimContext, params: &MdimParams) -> Result<MdimReport> {
        let base = &params.base;
        let s = base.sax.s;
        let ms = ctx.series();
        let n = ms.num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ctx.check(0)?;
        let start = Instant::now();
        let threads = self.resolve_threads(base);
        let channels = ms.select(&params.channels)?;
        let kind = base.distance_kind();

        // Preparation is pure discretization — per-channel stats/indexes
        // from each channel's SearchContext cache, the joint index from
        // the mdim cache — and costs no distance calls (prep_calls = 0).
        let (stats, idxs) = ctx.prepared(&base.sax, &channels);
        let joint = ctx.joint_index(&base.sax, &channels, &idxs);
        let rarity: Vec<f64> = (0..n)
            .map(|i| {
                idxs.iter().map(|ix| ix.cluster_size(i) as f64).sum::<f64>()
            })
            .collect();
        let mut rng = Rng64::new(base.seed ^ 0x4D44_5354); // "MDST"

        // Warm start: any aggregate profile an earlier search on this
        // context left behind upper-bounds every exact aggregate nnd.
        let cached =
            ctx.warm_profile(s, kind, base.allow_self_match, &channels);
        let warm = matches!(&cached, Some(p) if p.len() == n);
        let mut profile = match cached {
            Some(p) if p.len() == n => p,
            _ => NndProfile::new(n),
        };

        let st = PassState {
            ms,
            stats: &stats,
            channels: &channels,
            joint: &joint,
            rarity: &rarity,
            params: base,
            kernel: ctx.kernel(),
        };
        let published = AtomicU64::new(0);
        let mut zones = ExclusionZones::new();
        let mut discords = Vec::new();
        let mut total_calls = 0u64;
        for ki in 0..base.k {
            // later passes always run on a warmed profile
            let pass_warm = warm || ki > 0;
            let (found, calls) = if threads <= 1 {
                self.pass_serial(
                    ctx,
                    &st,
                    &mut profile,
                    &zones,
                    &mut rng,
                    pass_warm,
                    total_calls,
                )?
            } else {
                self.pass_par(
                    ctx,
                    &st,
                    &mut profile,
                    &zones,
                    &mut rng,
                    pass_warm,
                    threads,
                    &published,
                )?
            };
            total_calls += calls;
            match found {
                Some(d) => {
                    zones.add(d.position, s);
                    discords.push(d);
                }
                None => break,
            }
        }

        // Leave the refined aggregate profile for the next search on
        // this context (and, single-channel, for univariate engines).
        ctx.store_warm_profile(
            s,
            kind,
            base.allow_self_match,
            &channels,
            profile,
        );

        Ok(MdimReport {
            // qualified: the type also has a univariate Algorithm face
            algo: MdimAlgorithm::name(self).to_string(),
            discords,
            distance_calls: total_calls,
            prep_calls: 0,
            elapsed: start.elapsed(),
            n_sequences: n,
            channels: channels
                .iter()
                .map(|&c| ms.channel(c).name.clone())
                .collect(),
        })
    }
}

impl Algorithm for HstMd {
    fn name(&self) -> &'static str {
        "hst-md"
    }

    /// Univariate face: one-channel aggregate search (bit-compatible
    /// with the Eq. 2 distance). Run controls, cached preparation, and
    /// warm profiles flow both ways (the shared `mdim::run_univariate`
    /// face).
    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        super::run_univariate(self, ctx, params)
    }
}

#[cfg(test)]
mod tests {
    use super::super::brute::BruteMd;
    use super::*;
    use crate::ts::generators;

    fn params(s: usize, k: usize) -> MdimParams {
        MdimParams::new(SearchParams::new(s, 4, 4).with_discords(k))
    }

    #[test]
    fn matches_brute_md_bitwise_across_thread_counts() {
        let ms = generators::correlated_channels(1_000, 3, 64, 21);
        let p = params(64, 2);
        let exact = BruteMd.run_multi(&ms, &p).unwrap();
        for threads in [1usize, 2, 4] {
            let fast = HstMd { threads }.run_multi(&ms, &p).unwrap();
            assert_eq!(fast.algo, "hst-md");
            assert_eq!(
                fast.discords.len(),
                exact.discords.len(),
                "threads={threads}"
            );
            for (a, b) in fast.discords.iter().zip(&exact.discords) {
                assert_eq!(a.position, b.position, "threads={threads}");
                assert_eq!(
                    a.nnd.to_bits(),
                    b.nnd.to_bits(),
                    "threads={threads}: {} vs {}",
                    a.nnd,
                    b.nnd
                );
            }
            assert!(
                fast.distance_calls < exact.distance_calls,
                "threads={threads}: {} !< {}",
                fast.distance_calls,
                exact.distance_calls
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        // serial engine: call counts are deterministic too (at >= 2
        // workers only the results are, as with hst-par)
        let ms = generators::correlated_channels(1_200, 2, 64, 33);
        let p = params(64, 2);
        let a = HstMd { threads: 1 }.run_multi(&ms, &p).unwrap();
        let b = HstMd { threads: 1 }.run_multi(&ms, &p).unwrap();
        assert_eq!(a.distance_calls, b.distance_calls);
        assert_eq!(
            a.discords.iter().map(|d| d.position).collect::<Vec<_>>(),
            b.discords.iter().map(|d| d.position).collect::<Vec<_>>()
        );
    }

    #[test]
    fn warm_context_reuses_the_aggregate_profile() {
        let ms = generators::correlated_channels(1_300, 3, 64, 8);
        let p = params(64, 1);
        let ctx = MdimContext::builder(&ms).build();
        let cold = HstMd { threads: 1 }.run_md(&ctx, &p).unwrap();
        let hot = HstMd { threads: 1 }.run_md(&ctx, &p).unwrap();
        assert_eq!(cold.discords[0].position, hot.discords[0].position);
        assert_eq!(
            cold.discords[0].nnd.to_bits(),
            hot.discords[0].nnd.to_bits()
        );
        assert!(
            hot.distance_calls <= cold.distance_calls,
            "warm run must not cost more: {} vs {}",
            hot.distance_calls,
            cold.distance_calls
        );
    }

    #[test]
    fn cancellation_and_budget_propagate() {
        use crate::context::CancellationToken;
        let ms = generators::correlated_channels(1_000, 2, 64, 6);
        let token = CancellationToken::new();
        token.cancel();
        let ctx = MdimContext::builder(&ms).cancel_token(token).build();
        let err = HstMd::default()
            .run_md(&ctx, &params(64, 1))
            .unwrap_err()
            .to_string();
        assert!(err.contains("cancelled"), "{err}");

        let ctx = MdimContext::builder(&ms).distance_budget(5).build();
        let err = HstMd { threads: 2 }
            .run_md(&ctx, &params(64, 1))
            .unwrap_err()
            .to_string();
        assert!(err.contains("budget"), "{err}");
    }

    #[test]
    fn univariate_face_matches_serial_hst_results() {
        let ts = crate::ts::series::IntoSeries::into_series(
            generators::ecg_like(1_200, 90, 1, 44),
            "e",
        );
        let sp = SearchParams::new(72, 4, 4);
        let uni = crate::algo::brute::BruteForce.run(&ts, &sp).unwrap();
        let md = Algorithm::run(&HstMd::default(), &ts, &sp).unwrap();
        assert_eq!(md.algo, "hst-md");
        assert_eq!(md.discords[0].position, uni.discords[0].position);
        assert_eq!(md.discords[0].nnd.to_bits(), uni.discords[0].nnd.to_bits());
    }
}
