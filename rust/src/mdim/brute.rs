//! `brute-md` — the exact multivariate reference.
//!
//! Every admissible pair is evaluated **in full** — all selected
//! channels, no early abandoning of any kind — so its call count is the
//! closed form `admissible_pairs × channels`: the denominator every
//! `hst-md` speedup is measured against, exactly as univariate `brute`
//! anchors the paper's cps tables. The aggregate profile it produces is
//! exact, which also makes it the best possible warm start for later
//! searches through the [`MdimContext`] cache.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::algo::brute::BruteForce;
use crate::algo::{non_self_match, Algorithm, SearchReport};
use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::NndProfile;
use crate::dist::Distance as _;

use super::dist::MdimDistance;
use super::{MdimAlgorithm, MdimContext, MdimParams, MdimReport};

/// The brute-force multivariate engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct BruteMd;

impl BruteMd {
    /// Exact aggregate nnd profile: every admissible pair evaluated once
    /// (symmetric update), in full across every selected channel. Checks
    /// the context's run controls once per outer row.
    pub fn exact_profile(
        ctx: &MdimContext,
        agg: &MdimDistance,
        s: usize,
        allow_self_match: bool,
    ) -> Result<NndProfile> {
        let n = ctx.series().num_sequences(s);
        let mut profile = NndProfile::new(n);
        for i in 0..n {
            ctx.check(agg.calls())?;
            for j in (i + 1)..n {
                if non_self_match(i, j, s, allow_self_match) {
                    let d = agg.dist(i, j);
                    profile.observe(i, j, d);
                }
            }
        }
        Ok(profile)
    }
}

impl MdimAlgorithm for BruteMd {
    fn name(&self) -> &'static str {
        "brute-md"
    }

    /// Brute force never reads a SAX index, so its univariate face skips
    /// the discretization entirely.
    fn uses_sax_index(&self) -> bool {
        false
    }

    fn run_md(&self, ctx: &MdimContext, params: &MdimParams) -> Result<MdimReport> {
        let s = params.base.sax.s;
        let ms = ctx.series();
        let n = ms.num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ctx.check(0)?;
        let start = Instant::now();
        let channels = ms.select(&params.channels)?;
        let kind = params.base.distance_kind();
        let stats: Vec<_> = channels
            .iter()
            .map(|&c| ctx.channel_ctx(c).stats(s))
            .collect();
        let agg =
            MdimDistance::with_kernel(ms, &stats, &channels, kind, ctx.kernel());
        let profile =
            Self::exact_profile(ctx, &agg, s, params.base.allow_self_match)?;
        // same extraction (and lowest-index tie-break) as univariate brute
        let discords =
            BruteForce::discords_from_profile(&profile, s, params.base.k);
        let calls = agg.calls();
        ctx.store_warm_profile(
            s,
            kind,
            params.base.allow_self_match,
            &channels,
            profile,
        );
        Ok(MdimReport {
            // qualified: the type also has a univariate Algorithm face
            algo: MdimAlgorithm::name(self).to_string(),
            discords,
            distance_calls: calls,
            prep_calls: 0,
            elapsed: start.elapsed(),
            n_sequences: n,
            channels: channels
                .iter()
                .map(|&c| ms.channel(c).name.clone())
                .collect(),
        })
    }
}

impl Algorithm for BruteMd {
    fn name(&self) -> &'static str {
        "brute-md"
    }

    /// Univariate face: the context's series is treated as a
    /// single-channel series (the one-channel aggregate is the Eq. 2
    /// distance bit for bit). Run controls, cached preparation, and warm
    /// profiles flow both ways (the shared `mdim::run_univariate` face).
    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        super::run_univariate(self, ctx, params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;

    #[test]
    fn call_count_is_pairs_times_channels() {
        let ms = generators::correlated_channels(500, 3, 50, 2);
        let params = MdimParams::new(SearchParams::new(50, 5, 4));
        let rep = BruteMd.run_multi(&ms, &params).unwrap();
        let n = ms.num_sequences(50);
        let mut pairs = 0u64;
        for i in 0..n {
            for j in (i + 1)..n {
                if j - i >= 50 {
                    pairs += 1;
                }
            }
        }
        assert_eq!(rep.distance_calls, pairs * 3);
        assert_eq!(rep.channels, vec!["c0", "c1", "c2"]);
        assert_eq!(rep.n_sequences, n);
    }

    #[test]
    fn single_channel_matches_univariate_brute_bitwise() {
        let ms = generators::correlated_channels(900, 2, 64, 5);
        let uni_params = SearchParams::new(64, 4, 4).with_discords(2);
        let uni = crate::algo::brute::BruteForce
            .run(ms.channel(1), &uni_params)
            .unwrap();
        let md = BruteMd
            .run_multi(
                &ms,
                &MdimParams::new(uni_params).with_channels(["c1"]),
            )
            .unwrap();
        assert_eq!(md.discords.len(), uni.discords.len());
        for (a, b) in md.discords.iter().zip(&uni.discords) {
            assert_eq!(a.position, b.position);
            assert_eq!(a.nnd.to_bits(), b.nnd.to_bits());
        }
        assert_eq!(md.distance_calls, uni.distance_calls);
    }

    #[test]
    fn unknown_channel_is_a_named_error() {
        let ms = generators::correlated_channels(600, 2, 50, 1);
        let params =
            MdimParams::new(SearchParams::new(50, 5, 4)).with_channels(["nope"]);
        let err = BruteMd.run_multi(&ms, &params).unwrap_err().to_string();
        assert!(err.contains("unknown channel `nope`"), "{err}");
    }

    #[test]
    fn univariate_face_matches_plain_brute() {
        let ts = crate::ts::series::IntoSeries::into_series(
            generators::ecg_like(900, 80, 1, 12),
            "e",
        );
        let params = SearchParams::new(64, 4, 4);
        let uni = crate::algo::brute::BruteForce.run(&ts, &params).unwrap();
        let md = Algorithm::run(&BruteMd, &ts, &params).unwrap();
        assert_eq!(md.algo, "brute-md");
        assert_eq!(md.discords[0].position, uni.discords[0].position);
        assert_eq!(
            md.discords[0].nnd.to_bits(),
            uni.discords[0].nnd.to_bits()
        );
        assert_eq!(md.distance_calls, uni.distance_calls);
    }
}
