//! Multivariate (k-of-d) discord search — the `mdim` subsystem.
//!
//! A multivariate discord is the sequence position whose **aggregate**
//! nearest-neighbor distance — the sum of per-channel z-normalized Eq. 2
//! distances over a selected channel subset, see
//! [`dist::MdimDistance`] — is largest under the usual non-self-match
//! condition. Summing per-channel distances is the k-of-d aggregate the
//! multidimensional discord literature builds on (Yeh et al. 2023,
//! *Sketching Multidimensional Time Series*; Linardi et al. 2020,
//! *Matrix Profile Goes MAD*): an anomaly too subtle for any single
//! channel still surfaces when every channel deviates *at the same
//! time*, because the per-channel contributions add while at any other
//! position at most one channel is far from its neighbor.
//!
//! Two engines implement [`MdimAlgorithm`], both registered in
//! [`algo::ALL_ENGINES`](crate::algo::ALL_ENGINES) (their univariate
//! [`Algorithm`](crate::algo::Algorithm) faces treat a plain series as
//! one channel):
//!
//! * [`brute::BruteMd`] (`brute-md`) — the exact reference: every
//!   admissible pair evaluated in full across every selected channel,
//!   with call counting. The correctness oracle.
//! * [`hst::HstMd`] (`hst-md`) — the headline: per-channel SAX words
//!   (shared [`WordBuilder`](crate::sax::WordBuilder) kernel) feed a
//!   *joint* cluster index; the outer candidate loop is ordered by
//!   summed per-channel bucket rarity; the inner loop is the serial HST
//!   minimization running over the aggregate distance, whose
//!   cross-channel early abandoning tightens each channel's cutoff as
//!   earlier channels accumulate; warm aggregate profiles persist across
//!   searches through the [`MdimContext`]; and the candidate loop shards
//!   across the [`exec`](crate::exec) worker pool exactly like
//!   `hst-par` (shared CAS-max bound, ordered bit-identical merge).
//!
//! Exactness is the contract: `hst-md` discord positions and aggregate
//! distances are **bit-identical** to `brute-md` at every thread count,
//! with strictly fewer distance calls (property-tested in
//! `tests/integration_mdim.rs`).
//!
//! ```
//! use hstime::mdim::{self, MdimAlgorithm as _, MdimParams};
//! use hstime::prelude::*;
//!
//! let ms = generators::correlated_channels(1_000, 3, 64, 42);
//! let params = MdimParams::new(SearchParams::new(64, 4, 4));
//! let ctx = mdim::MdimContext::builder(&ms).build();
//! let fast = mdim::hst::HstMd::default().run_md(&ctx, &params).unwrap();
//! let exact = mdim::brute::BruteMd.run_md(&ctx, &params).unwrap();
//! assert_eq!(fast.discords[0].position, exact.discords[0].position);
//! assert_eq!(fast.discords[0].nnd.to_bits(), exact.discords[0].nnd.to_bits());
//! assert!(fast.distance_calls < exact.distance_calls);
//! ```

pub mod brute;
mod context;
pub mod dist;
pub mod hst;

use std::time::Duration;

use anyhow::Result;

use crate::config::SearchParams;
use crate::discord::DiscordSet;
use crate::ts::MultiSeries;
use crate::util::json::Json;

pub use context::{MdimContext, MdimContextBuilder};
pub use dist::MdimDistance;

/// A multivariate search request: the shared univariate parameters plus
/// the channel selection.
#[derive(Debug, Clone, PartialEq)]
pub struct MdimParams {
    /// The univariate search parameters (s, P, alphabet, k, seed,
    /// distance protocol, threads) the aggregate search shares.
    pub base: SearchParams,
    /// Channel names to aggregate over; empty = all channels. Resolved
    /// to ascending storage indexes by
    /// [`MultiSeries::select`](crate::ts::MultiSeries::select), so the
    /// aggregate sum's accumulation order never depends on how this list
    /// was ordered.
    pub channels: Vec<String>,
}

impl MdimParams {
    /// A request over all channels.
    pub fn new(base: SearchParams) -> MdimParams {
        MdimParams {
            base,
            channels: Vec::new(),
        }
    }

    /// Restrict the aggregate to the named channels.
    pub fn with_channels<S: Into<String>>(
        mut self,
        channels: impl IntoIterator<Item = S>,
    ) -> MdimParams {
        self.channels = channels.into_iter().map(Into::into).collect();
        self
    }

    /// Serialize for the service protocol / reports: the base params
    /// object plus a `channels` array (omitted when empty).
    pub fn to_json(&self) -> Json {
        let mut j = self.base.to_json();
        if !self.channels.is_empty() {
            j = j.set(
                "channels",
                self.channels
                    .iter()
                    .map(|c| Json::from(c.as_str()))
                    .collect::<Vec<_>>(),
            );
        }
        j
    }

    /// Parse from the service protocol: the shared params object with an
    /// optional `channels` array of names. Unknown fields are rejected
    /// by name, as everywhere in the protocol.
    pub fn from_json(v: &Json) -> Result<MdimParams, String> {
        let mut channels = Vec::new();
        let mut base_fields = v.clone();
        if let Json::Obj(map) = &mut base_fields {
            if let Some(raw) = map.remove("channels") {
                let Some(arr) = raw.as_arr() else {
                    return Err(
                        "field `channels` must be an array of strings".into()
                    );
                };
                for (i, c) in arr.iter().enumerate() {
                    match c.as_str() {
                        Some(s) => channels.push(s.to_string()),
                        None => {
                            return Err(format!(
                                "channels[{i}] is not a string"
                            ))
                        }
                    }
                }
            }
        }
        let base = SearchParams::from_json(&base_fields)?;
        Ok(MdimParams { base, channels })
    }
}

/// Outcome of one multivariate discord search.
#[derive(Debug, Clone)]
pub struct MdimReport {
    /// Engine identifier (`brute-md` / `hst-md`).
    pub algo: String,
    /// Discords in rank order; `nnd` is the **aggregate** distance.
    pub discords: DiscordSet,
    /// Total per-channel distance calls (cross-channel abandoning means
    /// an aggregate evaluation may cost fewer calls than channels).
    pub distance_calls: u64,
    /// Distance calls spent on preparation (0 for both current engines:
    /// their preparation is SAX discretization, which costs none).
    pub prep_calls: u64,
    /// Wall-clock time of the search proper.
    pub elapsed: Duration,
    /// Number of sequence positions N in the search space.
    pub n_sequences: usize,
    /// Resolved channel names the aggregate summed over, in ascending
    /// storage order.
    pub channels: Vec<String>,
}

impl MdimReport {
    /// Cost per sequence *per channel*:
    /// `distance_calls / (N · k · channels)` — the paper's cps indicator
    /// extended to the multivariate workload (see
    /// [`metrics::cps_per_channel`](crate::metrics::cps_per_channel)).
    pub fn cps_per_channel(&self) -> f64 {
        crate::metrics::cps_per_channel(
            self.distance_calls,
            self.n_sequences,
            self.discords.len().max(1),
            self.channels.len().max(1),
        )
    }

    /// Serialize for reports and the service protocol.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("algo", self.algo.as_str())
            .set(
                "discords",
                self.discords.iter().map(|d| d.to_json()).collect::<Vec<_>>(),
            )
            .set("distance_calls", self.distance_calls)
            .set("prep_calls", self.prep_calls)
            .set("elapsed_secs", self.elapsed.as_secs_f64())
            .set("n_sequences", self.n_sequences)
            .set(
                "channels",
                self.channels
                    .iter()
                    .map(|c| Json::from(c.as_str()))
                    .collect::<Vec<_>>(),
            )
            .set("cps_per_channel", self.cps_per_channel())
    }

    /// Repackage as a univariate [`SearchReport`] (the `Algorithm` faces
    /// of the mdim engines report through this).
    ///
    /// [`SearchReport`]: crate::algo::SearchReport
    pub fn into_search_report(self) -> crate::algo::SearchReport {
        crate::algo::SearchReport {
            algo: self.algo,
            discords: self.discords,
            distance_calls: self.distance_calls,
            prep_calls: self.prep_calls,
            elapsed: self.elapsed,
            n_sequences: self.n_sequences,
        }
    }
}

/// A multivariate discord-search engine.
pub trait MdimAlgorithm {
    /// Short identifier (`"brute-md"`, `"hst-md"`).
    fn name(&self) -> &'static str;

    /// Find the first `params.base.k` aggregate discords of the
    /// context's series over the selected channels, reusing (and
    /// extending) the context's prepared state.
    fn run_md(&self, ctx: &MdimContext, params: &MdimParams)
        -> Result<MdimReport>;

    /// One-shot convenience over a throwaway context.
    fn run_multi(&self, ms: &MultiSeries, params: &MdimParams) -> Result<MdimReport> {
        let ctx = MdimContext::builder(ms).build();
        self.run_md(&ctx, params)
    }

    /// Does this engine consult SAX indexes? The shared univariate
    /// `Algorithm` face (`run_univariate`) only prepares and carries an
    /// index across the boundary for engines that do (`brute-md` never
    /// reads one, so its face must not pay the discretization).
    fn uses_sax_index(&self) -> bool {
        true
    }
}

/// Shared implementation of the engines' univariate
/// [`Algorithm`](crate::algo::Algorithm) faces: treat the context's
/// series as one channel, **carry the caller's prepared state across**
/// (cached stats and SAX index seed the channel context; a warm profile
/// seeds the aggregate cache — a one-channel aggregate is the univariate
/// Eq. 2 distance bit for bit), run, and flow the refined profile back
/// so the caller's [`SearchContext`](crate::context::SearchContext) —
/// e.g. an entry of the service coordinator's LRU — keeps warming across
/// repeated `*-md` jobs instead of silently rebuilding everything.
pub(crate) fn run_univariate(
    engine: &dyn MdimAlgorithm,
    ctx: &crate::context::SearchContext,
    params: &SearchParams,
) -> Result<crate::algo::SearchReport> {
    let s = params.sax.s;
    let kind = params.distance_kind();
    let ms = MultiSeries::from_univariate(ctx.series().clone());
    let mut builder = MdimContext::builder_owned(ms)
        .kernel(ctx.kernel())
        .cancel_token(ctx.cancel_token());
    if let Some(b) = ctx.budget() {
        builder = builder.distance_budget(b);
    }
    let mctx = builder.build();
    // The channel is a clone of the caller's series, so the seed
    // contracts hold verbatim; compute-on-miss goes through the caller's
    // caches, so preparation is paid at most once per context, not per
    // run.
    if ctx.series().num_sequences(s) > 0 && params.sax.validate().is_ok() {
        mctx.channel_ctx(0).seed_stats(ctx.stats(s));
        if engine.uses_sax_index() {
            mctx.channel_ctx(0)
                .seed_index(params.sax, ctx.index(&params.sax));
        }
    }
    if let Some(p) = ctx.warm_profile(s, kind, params.allow_self_match) {
        mctx.store_warm_profile(s, kind, params.allow_self_match, &[0], p);
    }
    ctx.notify_phase(engine.name(), "search");
    let report = engine.run_md(&mctx, &MdimParams::new(params.clone()))?;
    // Flow the refinement back (store merges by pointwise min, so the
    // caller's profile only ever tightens).
    if let Some(p) = mctx.warm_profile(s, kind, params.allow_self_match, &[0]) {
        ctx.store_warm_profile(s, kind, params.allow_self_match, p);
    }
    let sr = report.into_search_report();
    for (rank, d) in sr.discords.iter().enumerate() {
        ctx.notify_discord(rank, d);
    }
    // The inner run happens on the MdimContext, which carries no trace
    // sink; one covering pass keeps the span's call sum exact.
    ctx.trace_pass(&crate::obs::PassEvent {
        engine: engine.name(),
        phase: "search",
        index: 0,
        candidates: sr.n_sequences as u64,
        abandons: 0,
        calls: sr.distance_calls,
        best: sr.discords.first().map(|d| d.nnd).unwrap_or(f64::NAN),
    });
    Ok(sr)
}

/// Canonical id of every multivariate engine. Each id also resolves
/// through [`algo::by_name`](crate::algo::by_name) (the univariate face)
/// and therefore appears in [`algo::ALL_ENGINES`](crate::algo::ALL_ENGINES)
/// and the README Engines table — `tests/docs_consistency.rs` holds the
/// registries in lockstep in both directions.
pub const MDIM_ENGINES: [&str; 2] = ["brute-md", "hst-md"];

/// Look up a multivariate engine by name (CLI / service entry point).
pub fn by_name(name: &str) -> Option<Box<dyn MdimAlgorithm + Send + Sync>> {
    match name.to_ascii_lowercase().as_str() {
        "brute-md" | "brutemd" | "brute_md" => Some(Box::new(brute::BruteMd)),
        "hst-md" | "hstmd" | "hst_md" => Some(Box::new(hst::HstMd::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_mdim_engines() {
        for id in MDIM_ENGINES {
            let engine = by_name(id).unwrap_or_else(|| panic!("{id} missing"));
            assert_eq!(engine.name(), id, "canonical id must round-trip");
        }
        assert!(by_name("hst").is_none(), "univariate ids stay out");
    }

    #[test]
    fn params_json_roundtrip_with_channels() {
        let p = MdimParams::new(SearchParams::new(96, 4, 4).with_discords(2))
            .with_channels(["c0", "c2"]);
        let back = MdimParams::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        // empty channel list is omitted and parses back as empty
        let p = MdimParams::new(SearchParams::new(96, 4, 4));
        assert!(p.to_json().get("channels").is_none());
        assert_eq!(MdimParams::from_json(&p.to_json()).unwrap(), p);
    }

    #[test]
    fn params_json_rejects_malformed_channels_and_unknown_fields() {
        let j = Json::parse(r#"{"s": 64, "channels": "c0"}"#).unwrap();
        let err = MdimParams::from_json(&j).unwrap_err();
        assert!(err.contains("`channels`"), "{err}");
        let j = Json::parse(r#"{"s": 64, "channels": [1]}"#).unwrap();
        let err = MdimParams::from_json(&j).unwrap_err();
        assert!(err.contains("channels[0]"), "{err}");
        // unknown base fields still rejected by the shared parser
        let j = Json::parse(r#"{"s": 64, "chanels": ["c0"]}"#).unwrap();
        let err = MdimParams::from_json(&j).unwrap_err();
        assert!(err.contains("`chanels`"), "{err}");
    }
}
