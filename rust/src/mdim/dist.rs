//! The aggregate (k-of-d) distance session with cross-channel early
//! abandoning.
//!
//! The multivariate distance between the sequences starting at `i` and
//! `j` over a channel subset is the **sum of the per-channel Eq. 2
//! distances**, accumulated in ascending channel-index order:
//! `D(i, j) = d_0(i, j) + d_1(i, j) + …`. Each term is evaluated by the
//! channel's own [`CountingDistance`] session, so one aggregate
//! evaluation costs up to `channels` distance calls — and the paper's
//! call-count metric extends naturally
//! ([`cps_per_channel`](crate::metrics::cps_per_channel)).
//!
//! **Cross-channel early abandoning**: under a cutoff, channel `c` is
//! given only the *remaining* budget `cutoff − (d_0 + … + d_{c−1})`, so
//! each channel's early-abandoning cutoff tightens as earlier channels
//! accumulate, and the pair is abandoned — later channels never
//! evaluated, never counted — the moment the partial sum proves
//! `D ≥ cutoff`.
//!
//! [`MdimDistance`] implements the univariate [`Distance`] trait, and
//! honors its exactness contract: whenever the true aggregate is below
//! the cutoff, every per-channel term ran under a budget it finished
//! below (each exact by [`CountingDistance`]'s own contract), so the
//! returned sum is bit-identical to a full no-cutoff evaluation — which
//! is what lets `hst-md` reuse the serial HST inner loop unchanged and
//! still match `brute-md` bit for bit.

use crate::dist::{CountingDistance, Distance, DistanceKind, Kernel};
use crate::ts::{MultiSeries, SeqStats};

/// One aggregate-distance session over a resolved channel subset.
///
/// Like the scalar backend it wraps, a session is deliberately not
/// `Clone` and counts calls per instance: parallel workers construct
/// their own and the per-worker counts are summed after the join.
pub struct MdimDistance<'a> {
    per: Vec<CountingDistance<'a>>,
    kind: DistanceKind,
}

impl<'a> MdimDistance<'a> {
    /// A session over `ms`, summing the selected `channels` (resolved
    /// ascending storage indexes) with per-channel stats in selection
    /// order (`stats[c]` belongs to `channels[c]`).
    pub fn new(
        ms: &'a MultiSeries,
        stats: &'a [std::sync::Arc<SeqStats>],
        channels: &[usize],
        kind: DistanceKind,
    ) -> MdimDistance<'a> {
        Self::with_kernel(ms, stats, channels, kind, Kernel::active())
    }

    /// A session whose per-channel loops run on an explicit [`Kernel`]
    /// (the multivariate engines pass their context's choice through).
    pub fn with_kernel(
        ms: &'a MultiSeries,
        stats: &'a [std::sync::Arc<SeqStats>],
        channels: &[usize],
        kind: DistanceKind,
        kernel: Kernel,
    ) -> MdimDistance<'a> {
        debug_assert_eq!(stats.len(), channels.len());
        let per = channels
            .iter()
            .zip(stats)
            .map(|(&c, st)| {
                CountingDistance::with_kernel(ms.channel(c), st, kind, kernel)
            })
            .collect();
        MdimDistance { per, kind }
    }

    /// Number of channels the aggregate sums over.
    pub fn dims(&self) -> usize {
        self.per.len()
    }
}

impl Distance for MdimDistance<'_> {
    fn kind(&self) -> DistanceKind {
        self.kind
    }

    /// Total per-channel distance calls so far (each per-channel
    /// evaluation counts once, abandoned or not).
    fn calls(&self) -> u64 {
        self.per.iter().map(|d| d.calls()).sum()
    }

    fn dist_early(&self, i: usize, j: usize, cutoff: f64) -> f64 {
        let mut acc = 0.0f64;
        for d in &self.per {
            // the channel's budget is whatever the earlier channels left
            let remaining = cutoff - acc;
            if remaining <= 0.0 {
                // already provably >= cutoff: a valid aggregate lower
                // bound, later channels never evaluated (nor counted)
                return acc;
            }
            acc += d.dist_early(i, j, remaining);
            if acc >= cutoff {
                return acc; // abandoned: lower bound >= cutoff
            }
        }
        acc // every term ran below its budget: exact
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;

    fn setup() -> (MultiSeries, Vec<std::sync::Arc<SeqStats>>) {
        let ms = generators::correlated_channels(1_500, 3, 80, 9);
        let stats = (0..3)
            .map(|c| std::sync::Arc::new(SeqStats::compute(ms.channel(c), 80)))
            .collect();
        (ms, stats)
    }

    #[test]
    fn aggregate_is_the_sum_of_per_channel_distances() {
        let (ms, stats) = setup();
        let agg = MdimDistance::new(&ms, &stats, &[0, 1, 2], DistanceKind::Znorm);
        for (i, j) in [(0usize, 500usize), (100, 1200), (777, 93)] {
            let mut want = 0.0;
            for c in 0..3 {
                let d = CountingDistance::new(
                    ms.channel(c),
                    &stats[c],
                    DistanceKind::Znorm,
                );
                want += d.dist(i, j);
            }
            let got = agg.dist(i, j);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "({i},{j}): same order, same sum"
            );
        }
        assert_eq!(agg.calls(), 9, "3 pairs x 3 channels, no cutoff");
        assert_eq!(agg.dims(), 3);
    }

    #[test]
    fn below_cutoff_is_bit_identical_to_full_evaluation() {
        let (ms, stats) = setup();
        let agg = MdimDistance::new(&ms, &stats, &[0, 1, 2], DistanceKind::Znorm);
        for (i, j) in [(0usize, 400usize), (50, 900), (321, 1111)] {
            let exact = agg.dist(i, j);
            let with_cutoff = agg.dist_early(i, j, exact + 1.0);
            assert_eq!(exact.to_bits(), with_cutoff.to_bits());
        }
    }

    #[test]
    fn abandoned_pairs_return_a_bound_at_least_cutoff_with_fewer_calls() {
        let (ms, stats) = setup();
        let agg = MdimDistance::new(&ms, &stats, &[0, 1, 2], DistanceKind::Znorm);
        let exact = agg.dist(10, 700);
        let before = agg.calls();
        // a cutoff below the first channel's distance: later channels
        // must never be evaluated
        let d = agg.dist_early(10, 700, exact * 0.1);
        let spent = agg.calls() - before;
        assert!(d >= exact * 0.1, "bound {d} below cutoff");
        assert!(d <= exact + 1e-9, "bound cannot exceed the true aggregate");
        assert!(
            spent < 3,
            "cross-channel abandoning must skip later channels ({spent} calls)"
        );
    }

    #[test]
    fn channel_subsets_sum_only_their_channels() {
        let (ms, stats) = setup();
        let sub: Vec<std::sync::Arc<SeqStats>> =
            vec![stats[0].clone(), stats[2].clone()];
        let agg = MdimDistance::new(&ms, &sub, &[0, 2], DistanceKind::Znorm);
        let d0 = CountingDistance::new(ms.channel(0), &stats[0], DistanceKind::Znorm);
        let d2 = CountingDistance::new(ms.channel(2), &stats[2], DistanceKind::Znorm);
        let want = d0.dist(5, 600) + d2.dist(5, 600);
        assert_eq!(agg.dist(5, 600).to_bits(), want.to_bits());
    }

    #[test]
    fn single_channel_aggregate_is_the_univariate_distance() {
        let (ms, stats) = setup();
        let sub = vec![stats[1].clone()];
        let agg = MdimDistance::new(&ms, &sub, &[1], DistanceKind::Znorm);
        let uni = CountingDistance::new(ms.channel(1), &stats[1], DistanceKind::Znorm);
        for (i, j) in [(0usize, 300usize), (42, 1000)] {
            assert_eq!(agg.dist(i, j).to_bits(), uni.dist(i, j).to_bits());
        }
    }
}
