//! The multivariate search session: per-channel prepared state plus the
//! aggregate warm-profile cache.
//!
//! An [`MdimContext`] owns one univariate
//! [`SearchContext`](crate::context::SearchContext) per channel, so every
//! per-channel artifact — rolling [`SeqStats`], per-channel
//! [`SaxIndex`] — is cached exactly the way univariate sessions cache it
//! (same keys, same or-insert semantics). On top it adds what only exists
//! multivariately:
//!
//! * the **joint SAX index** (sequences clustered by the concatenation of
//!   their per-channel words), cached per `(SaxParams, channel subset)`;
//! * warm **aggregate** [`NndProfile`]s keyed by
//!   `(s, DistanceKind, allow_self_match, channel subset)`. Aggregate
//!   profiles live in their own cache because an aggregate distance sums
//!   per-channel distances — its entries upper-bound *aggregate* nnds,
//!   which is a different invariant from the univariate caches. The one
//!   exception: a **single-channel** subset's aggregate distance *is* the
//!   univariate Eq. 2 distance bit for bit, so that case reads and feeds
//!   the channel's own `SearchContext` warm-profile cache — a univariate
//!   `hst` run warms a single-channel `hst-md` search and vice versa;
//! * run controls (cancellation + distance-call budget) with the same
//!   checkpoint contract as the univariate context.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use crate::config::SaxParams;
use crate::context::{CancellationToken, SearchContext};
use crate::discord::NndProfile;
use crate::dist::{DistanceKind, Kernel};
use crate::sax::{SaxIndex, SaxWord};
use crate::ts::{MultiSeries, SeqStats};

/// Key of the aggregate warm-profile cache: the distance protocol plus
/// the resolved (ascending) channel subset the aggregate sums over.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct MdimProfileKey {
    s: usize,
    kind: DistanceKind,
    allow_self_match: bool,
    channels: Vec<usize>,
}

/// Builder for [`MdimContext`] (see [`MdimContext::builder`]).
pub struct MdimContextBuilder {
    ms: MultiSeries,
    kernel: Kernel,
    cancel: CancellationToken,
    budget: Option<u64>,
}

impl MdimContextBuilder {
    /// Attach a cancellation token (clone it to keep a cancelling handle).
    pub fn cancel_token(mut self, token: CancellationToken) -> MdimContextBuilder {
        self.cancel = token;
        self
    }

    /// Pin the inner-loop [`Kernel`] every per-channel distance session
    /// (and lazily built channel context) runs on. Default:
    /// [`Kernel::active`]. Bit-neutral — the kernels are bit-identical.
    pub fn kernel(mut self, kernel: Kernel) -> MdimContextBuilder {
        self.kernel = kernel;
        self
    }

    /// Cap the distance calls any single search through this context may
    /// spend (checkpoint semantics as in the univariate
    /// [`SearchContext`]: enforced once per outer-loop candidate).
    pub fn distance_budget(mut self, max_calls: u64) -> MdimContextBuilder {
        self.budget = Some(max_calls);
        self
    }

    /// Finish the builder.
    pub fn build(self) -> MdimContext {
        // channel contexts are built lazily (each one owns a copy of its
        // channel's points — see `channel_ctx` — so unselected channels
        // must never pay that copy)
        let channels =
            (0..self.ms.dims()).map(|_| OnceLock::new()).collect();
        MdimContext {
            ms: self.ms,
            kernel: self.kernel,
            channels,
            cancel: self.cancel,
            budget: self.budget,
            joint_index_cache: Mutex::new(HashMap::new()),
            profile_cache: Mutex::new(HashMap::new()),
        }
    }
}

/// Prepared multivariate search state (see the [module docs](self)).
///
/// `Send + Sync`; all caches use interior mutability, so `&MdimContext`
/// is all an engine needs.
pub struct MdimContext {
    ms: MultiSeries,
    kernel: Kernel,
    channels: Vec<OnceLock<SearchContext>>,
    cancel: CancellationToken,
    budget: Option<u64>,
    #[allow(clippy::type_complexity)]
    joint_index_cache: Mutex<HashMap<(SaxParams, Vec<usize>), Arc<SaxIndex>>>,
    profile_cache: Mutex<HashMap<MdimProfileKey, NndProfile>>,
}

impl MdimContext {
    /// Start building a context over a copy of `ms`.
    pub fn builder(ms: &MultiSeries) -> MdimContextBuilder {
        MdimContext::builder_owned(ms.clone())
    }

    /// Start building a context that takes ownership of `ms`.
    pub fn builder_owned(ms: MultiSeries) -> MdimContextBuilder {
        MdimContextBuilder {
            ms,
            kernel: Kernel::active(),
            cancel: CancellationToken::new(),
            budget: None,
        }
    }

    /// The inner-loop [`Kernel`] sessions from this context run on.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The multivariate series this context prepares.
    pub fn series(&self) -> &MultiSeries {
        &self.ms
    }

    /// The per-search distance-call budget, if any.
    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// A handle on the context's cancellation token.
    pub fn cancel_token(&self) -> CancellationToken {
        self.cancel.clone()
    }

    /// The univariate session of channel `c` — per-channel stats and SAX
    /// indexes are cached there, exactly as a univariate search would
    /// cache them (and for single-channel subsets, warm profiles too).
    /// Built on first use: a `SearchContext` owns a copy of its channel's
    /// points, so only channels a search actually touches pay that copy.
    pub fn channel_ctx(&self, c: usize) -> &SearchContext {
        self.channels[c].get_or_init(|| {
            SearchContext::builder(self.ms.channel(c))
                .kernel(self.kernel)
                .build()
        })
    }

    /// Has channel `c`'s univariate session been built yet?
    /// (Diagnostics / tests: unselected channels must stay lazy.)
    pub fn channel_is_built(&self, c: usize) -> bool {
        self.channels[c].get().is_some()
    }

    /// Per-channel `(stats, index)` for `sax` over the selected channels,
    /// in selection order (each served from the channel's own
    /// [`SearchContext`] cache).
    pub fn prepared(
        &self,
        sax: &SaxParams,
        channels: &[usize],
    ) -> (Vec<Arc<SeqStats>>, Vec<Arc<SaxIndex>>) {
        let mut stats = Vec::with_capacity(channels.len());
        let mut idxs = Vec::with_capacity(channels.len());
        for &c in channels {
            let (st, ix) = self.channel_ctx(c).prepared(sax);
            stats.push(st);
            idxs.push(ix);
        }
        (stats, idxs)
    }

    /// The joint SAX index over the selected channels: sequence `k`'s
    /// joint word is the concatenation of its per-channel words (built by
    /// the shared [`WordBuilder`](crate::sax::WordBuilder) kernel inside
    /// each channel's index), so two sequences share a joint cluster iff
    /// they share a cluster in *every* selected channel. Computed once
    /// per `(sax, channel subset)` and cached.
    pub fn joint_index(
        &self,
        sax: &SaxParams,
        channels: &[usize],
        per_channel: &[Arc<SaxIndex>],
    ) -> Arc<SaxIndex> {
        let key = (*sax, channels.to_vec());
        let mut cache = self.joint_index_cache.lock().unwrap();
        Arc::clone(cache.entry(key).or_insert_with(|| {
            let n = per_channel.first().map_or(0, |ix| ix.len());
            let mut buf = Vec::with_capacity(sax.p * per_channel.len());
            let words: Vec<SaxWord> = (0..n)
                .map(|k| {
                    buf.clear();
                    for ix in per_channel {
                        buf.extend_from_slice(ix.words[k].symbols());
                    }
                    SaxWord::new(&buf)
                })
                .collect();
            Arc::new(SaxIndex::from_words(words))
        }))
    }

    /// Run-control checkpoint — the same rule (and wording) as
    /// [`SearchContext::check`](crate::context::SearchContext::check),
    /// through the one shared implementation.
    pub fn check(&self, distance_calls: u64) -> Result<()> {
        crate::context::check_run_controls(
            &self.cancel,
            self.budget,
            distance_calls,
        )
    }

    /// A warm aggregate profile for the protocol and channel subset, if an
    /// earlier search left one behind. Single-channel subsets are served
    /// from the channel's own [`SearchContext`] cache (the aggregate over
    /// one channel is the univariate distance bit for bit).
    pub fn warm_profile(
        &self,
        s: usize,
        kind: DistanceKind,
        allow_self_match: bool,
        channels: &[usize],
    ) -> Option<NndProfile> {
        if let [c] = channels {
            return self.channel_ctx(*c).warm_profile(s, kind, allow_self_match);
        }
        let key = MdimProfileKey {
            s,
            kind,
            allow_self_match,
            channels: channels.to_vec(),
        };
        self.profile_cache.lock().unwrap().get(&key).cloned()
    }

    /// Store an aggregate profile for later searches (pointwise-min merge
    /// on collision, as in the univariate cache — a looser profile never
    /// displaces a tighter one). Single-channel subsets feed the
    /// channel's own [`SearchContext`] cache, so a later univariate `hst`
    /// run starts warm too.
    pub fn store_warm_profile(
        &self,
        s: usize,
        kind: DistanceKind,
        allow_self_match: bool,
        channels: &[usize],
        profile: NndProfile,
    ) {
        if let [c] = channels {
            self.channel_ctx(*c)
                .store_warm_profile(s, kind, allow_self_match, profile);
            return;
        }
        let key = MdimProfileKey {
            s,
            kind,
            allow_self_match,
            channels: channels.to_vec(),
        };
        let mut cache = self.profile_cache.lock().unwrap();
        match cache.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut entry) => {
                entry.get_mut().absorb(profile);
            }
            std::collections::hash_map::Entry::Vacant(slot) => {
                slot.insert(profile);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;

    fn ms() -> MultiSeries {
        generators::correlated_channels(1_200, 3, 64, 17)
    }

    #[test]
    fn per_channel_state_is_cached_in_channel_contexts() {
        let ctx = MdimContext::builder(&ms()).build();
        let sax = SaxParams::new(64, 4, 4);
        let (s1, i1) = ctx.prepared(&sax, &[0, 2]);
        let (s2, i2) = ctx.prepared(&sax, &[0, 2]);
        assert_eq!(s1.len(), 2);
        assert!(Arc::ptr_eq(&s1[0], &s2[0]), "stats computed once");
        assert!(Arc::ptr_eq(&i1[1], &i2[1]), "index computed once");
        assert!(ctx.channel_ctx(0).is_prepared(&sax));
        // the unselected channel never even built its session (no copy
        // of its points was made)
        assert!(!ctx.channel_is_built(1), "unselected channel stays lazy");
        assert!(!ctx.channel_ctx(1).is_prepared(&sax), "…and unprepared");
    }

    #[test]
    fn joint_index_is_cached_and_conjunctive() {
        let ctx = MdimContext::builder(&ms()).build();
        let sax = SaxParams::new(64, 4, 4);
        let chans = vec![0usize, 1];
        let (_, idxs) = ctx.prepared(&sax, &chans);
        let j1 = ctx.joint_index(&sax, &chans, &idxs);
        let j2 = ctx.joint_index(&sax, &chans, &idxs);
        assert!(Arc::ptr_eq(&j1, &j2), "joint index computed once per key");
        assert_eq!(j1.len(), idxs[0].len());
        // sharing a joint cluster requires sharing both per-channel words
        for members in &j1.clusters {
            let m0 = members[0];
            for &m in members {
                assert_eq!(idxs[0].words[m], idxs[0].words[m0]);
                assert_eq!(idxs[1].words[m], idxs[1].words[m0]);
            }
        }
        // a different subset gets its own joint index
        let chans2 = vec![0usize];
        let (_, idxs2) = ctx.prepared(&sax, &chans2);
        let j3 = ctx.joint_index(&sax, &chans2, &idxs2);
        assert!(!Arc::ptr_eq(&j1, &j3));
        // single-channel joint clusters coincide with the channel's own
        assert_eq!(j3.cluster_of, idxs2[0].cluster_of);
    }

    #[test]
    fn aggregate_profiles_are_keyed_by_channel_subset() {
        let ctx = MdimContext::builder(&ms()).build();
        let n = ctx.series().num_sequences(64);
        let mut p = NndProfile::new(n);
        p.observe(0, 500, 2.5);
        ctx.store_warm_profile(64, DistanceKind::Znorm, false, &[0, 1], p);
        assert!(ctx
            .warm_profile(64, DistanceKind::Znorm, false, &[0, 1])
            .is_some());
        assert!(
            ctx.warm_profile(64, DistanceKind::Znorm, false, &[0, 2])
                .is_none(),
            "different subset, different profile"
        );
        assert!(ctx
            .warm_profile(64, DistanceKind::Raw, false, &[0, 1])
            .is_none());
    }

    #[test]
    fn single_channel_subset_shares_the_univariate_cache() {
        let ctx = MdimContext::builder(&ms()).build();
        let n = ctx.series().num_sequences(64);
        let mut p = NndProfile::new(n);
        p.observe(3, 400, 1.25);
        // stored through the mdim face, visible in the channel context …
        ctx.store_warm_profile(64, DistanceKind::Znorm, false, &[1], p);
        let got = ctx
            .channel_ctx(1)
            .warm_profile(64, DistanceKind::Znorm, false)
            .expect("single-channel store must feed the channel cache");
        assert_eq!(got.nnd[3], 1.25);
        // … and the other direction
        let mut q = NndProfile::new(n);
        q.observe(7, 600, 0.5);
        ctx.channel_ctx(0)
            .store_warm_profile(64, DistanceKind::Znorm, false, q);
        let got = ctx
            .warm_profile(64, DistanceKind::Znorm, false, &[0])
            .expect("univariate store must serve the mdim face");
        assert_eq!(got.nnd[7], 0.5);
    }

    #[test]
    fn check_enforces_cancellation_and_budget() {
        let token = CancellationToken::new();
        let ctx = MdimContext::builder(&ms())
            .cancel_token(token.clone())
            .distance_budget(10)
            .build();
        assert!(ctx.check(10).is_ok(), "budget is inclusive");
        assert!(ctx.check(11).is_err());
        token.cancel();
        let err = ctx.check(0).unwrap_err().to_string();
        assert!(err.contains("cancelled"), "{err}");
    }
}
