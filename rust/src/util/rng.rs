//! Seeded, reproducible PRNG: xoshiro256++ with SplitMix64 seeding.
//!
//! Every pseudo-random choice in the crate (SAX-cluster shuffles, the
//! HOT SAX inner-loop order, synthetic dataset noise) flows through this
//! generator so that experiments are bit-reproducible from a single seed —
//! the paper averages 10 runs per dataset precisely because these choices
//! make call counts fluctuate, and seeding lets us freeze each run.

/// xoshiro256++ generator (Blackman & Vigna). Passes BigCrush; not
/// cryptographic — exactly what a simulation PRNG should be.
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng64 {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng64 { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method (unbiased).
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n || n.is_power_of_two() {
                return (m >> 64) as usize;
            }
            if l >= l.wrapping_sub(n) % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generators are not on any hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A fresh generator derived from this one (stream splitting).
    pub fn split(&mut self) -> Rng64 {
        Rng64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng64::new(1);
        let mut b = Rng64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng64::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b), "all residues hit");
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffled order changed");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Rng64::new(5);
        let mut c = a.split();
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let cv: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(av, cv);
    }
}
