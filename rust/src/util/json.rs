//! Minimal JSON value model, parser, and writer.
//!
//! Used by the batch-search service protocol (JSON-lines over TCP) and for
//! machine-readable experiment reports. Supports the full JSON grammar
//! except `\u` surrogate-pair edge validation (lone surrogates are mapped
//! to U+FFFD rather than rejected).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// A new empty object (builder entry point for [`set`](Self::set)).
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object field lookup; `None` on non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as an exact non-negative integer (≤ 2⁵³), if it is one.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= 2f64.powi(53) {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: input.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
    /// Human-readable failure description.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.i,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, lit: &str) -> Result<(), JsonError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.eat("null").map(|_| Json::Null),
            Some(b't') => self.eat("true").map(|_| Json::Bool(true)),
            Some(b'f') => self.eat("false").map(|_| Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // [
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // {
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                return Err(self.err("expected object key string"));
            }
            let key = self.string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected `:`"));
            }
            self.i += 1;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.i += 1; // opening quote
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: look for \uXXXX low half
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if (0xDC00..0xE000).contains(&lo) {
                                        let c = 0x10000
                                            + ((cp - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        out.push(
                                            char::from_u32(c).unwrap_or('\u{FFFD}'),
                                        );
                                    } else {
                                        out.push('\u{FFFD}');
                                        out.push(
                                            char::from_u32(lo).unwrap_or('\u{FFFD}'),
                                        );
                                    }
                                } else {
                                    out.push('\u{FFFD}');
                                }
                            } else {
                                out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            }
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 scalar starting at i-1.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    match std::str::from_utf8(&self.b[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.i = end;
                        }
                        Err(_) => return Err(self.err("invalid utf-8")),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    /// Compact single-line serialization (JSON-lines friendly).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_into(&mut s, self);
        f.write_str(&s)
    }
}

fn write_into(out: &mut String, v: &Json) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape_into(out, s),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(out, item);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(out, k);
                out.push(':');
                write_into(out, val);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn builder_and_access() {
        let v = Json::obj()
            .set("job", 3u64)
            .set("algo", "hst")
            .set("ok", true);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("job").unwrap().as_u64(), Some(3));
        assert_eq!(back.get("algo").unwrap().as_str(), Some("hst"));
        assert_eq!(back.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "{\"a\"}", "nul", "1 2", "\"\\x\""] {
            assert!(Json::parse(src).is_err(), "{src:?} should fail");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
