//! Summary statistics for benchmark reporting (mean, std, min, max,
//! percentiles) and small numeric helpers shared across modules.

/// Summary of a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest observation.
    pub min: f64,
    /// Largest observation.
    pub max: f64,
    /// Median (interpolated).
    pub p50: f64,
    /// 95th percentile (interpolated).
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; empty input yields NaNs with n = 0.
    pub fn of(xs: &[f64]) -> Summary {
        let n = xs.len();
        if n == 0 {
            return Summary {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
                p50: f64::NAN,
                p95: f64::NAN,
            };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Acklam's rational approximation of the inverse standard-normal CDF.
/// Max absolute error ~1.15e-9 over (0, 1) — more than enough for SAX
/// breakpoints (the paper's alphabets are 3–4 symbols).
pub fn inv_norm_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "inv_norm_cdf domain: 0 < p < 1, got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[3.0; 10]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 3.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 1.0), 10.0);
    }

    #[test]
    fn inv_norm_cdf_known_values() {
        // N^{-1}(0.5) = 0; N^{-1}(0.8413) ~ 1; symmetric tails.
        assert!(inv_norm_cdf(0.5).abs() < 1e-9);
        assert!((inv_norm_cdf(0.841344746) - 1.0).abs() < 1e-6);
        assert!((inv_norm_cdf(0.158655254) + 1.0).abs() < 1e-6);
        assert!((inv_norm_cdf(0.975) - 1.959964).abs() < 1e-5);
        // deep tails stay finite and monotone
        assert!(inv_norm_cdf(1e-10) < inv_norm_cdf(1e-9));
    }

    #[test]
    fn inv_norm_cdf_sax_breakpoints_alphabet3() {
        // Classic SAX table, alphabet 3: breakpoints at ±0.43.
        assert!((inv_norm_cdf(1.0 / 3.0) + 0.4307).abs() < 1e-3);
        assert!((inv_norm_cdf(2.0 / 3.0) - 0.4307).abs() < 1e-3);
    }
}
