//! A small generative property-testing harness (the offline registry has no
//! `proptest`, so the crate ships its own).
//!
//! A property is a closure over a [`Gen`] (a seeded random source with
//! convenience samplers). [`check`] runs it for `cases` seeds; on failure it
//! re-runs a deterministic shrink pass over the *size* parameters the
//! property exposed via [`Gen::size`], then panics with the failing seed so
//! the case can be replayed exactly.

use crate::util::rng::Rng64;

/// Random source handed to properties.
pub struct Gen {
    /// The seeded generator backing every sampler.
    pub rng: Rng64,
    /// Seed of this case (printed on failure for replay).
    pub seed: u64,
    /// Scale factor in (0, 1]; shrinking lowers it to re-run the property
    /// on smaller inputs.
    pub scale: f64,
}

impl Gen {
    /// A size in [lo, hi], scaled down during shrinking (never below lo).
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.scale).round() as usize;
        if span == 0 {
            lo
        } else {
            self.rng.range(lo, lo + span + 1)
        }
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Vector of standard-normal samples.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal()).collect()
    }
}

/// Outcome of a property: Ok or a failure message.
pub type PropResult = Result<(), String>;

/// Run `prop` for `cases` deterministic seeds derived from `base_seed`.
///
/// Panics (with replay info) on the first failing case after attempting a
/// 4-step shrink by re-running the same seed at smaller `scale`.
pub fn check<F>(name: &str, base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let run = |scale: f64, prop: &mut F| -> PropResult {
            let mut g = Gen {
                rng: Rng64::new(seed),
                seed,
                scale,
            };
            prop(&mut g)
        };
        if let Err(msg) = run(1.0, &mut prop) {
            // Shrink: same seed, smaller sizes. Report the smallest failure.
            let mut final_msg = msg;
            let mut final_scale = 1.0;
            for &scale in &[0.5, 0.25, 0.1, 0.05] {
                if let Err(m) = run(scale, &mut prop) {
                    final_msg = m;
                    final_scale = scale;
                }
            }
            panic!(
                "property `{name}` failed (case {case}, seed {seed}, \
                 scale {final_scale}):\n  {final_msg}\n  \
                 replay: check(\"{name}\", {base_seed}, ...) case {case}"
            );
        }
    }
}

/// Assert helper returning PropResult.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-ok", 1, 25, |g| {
            count += 1;
            let n = g.size(1, 100);
            if n >= 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        // 25 cases, one invocation each (no shrink attempts on success)
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_name() {
        check("fails", 2, 5, |g| {
            let n = g.size(10, 50);
            if n < 10 {
                Ok(())
            } else {
                Err(format!("n = {n} too big"))
            }
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut first: Vec<usize> = Vec::new();
        check("det-a", 7, 3, |g| {
            first.push(g.size(0, 1000));
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        check("det-b", 7, 3, |g| {
            second.push(g.size(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn shrinking_reduces_size() {
        let mut g_full = Gen {
            rng: Rng64::new(3),
            seed: 3,
            scale: 1.0,
        };
        let mut g_small = Gen {
            rng: Rng64::new(3),
            seed: 3,
            scale: 0.05,
        };
        let a = g_full.size(10, 1000);
        let b = g_small.size(10, 1000);
        assert!(b <= a, "shrunk size {b} <= full size {a}");
        assert!(b <= 10 + ((1000 - 10) as f64 * 0.05).round() as usize);
    }
}
