//! Small self-contained utilities.
//!
//! The offline build environment ships only the `xla` crate's dependency
//! closure, so the conveniences a networked project would pull from
//! crates.io (`rand`, `serde_json`, `clap`, `proptest`) are implemented
//! here from scratch: a seeded xoshiro256++ PRNG, a minimal JSON
//! reader/writer, a tiny argv parser, summary statistics, and a
//! generative property-test harness used by `rust/tests/`.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
