//! Tiny argv parser for the `hst` binary and the bench/example drivers.
//!
//! Grammar: `prog <subcommand> [positional...] [--flag] [--key value]`.
//! `--key=value` is also accepted. Unknown flags are collected so callers
//! can reject them with a helpful message.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First bare token (e.g. `discover` in `hst discover ecg300`).
    pub subcommand: Option<String>,
    /// Remaining bare tokens, in order.
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    order: Vec<String>,
}

/// Value stored for boolean flags given without an argument (`--full`).
pub const FLAG_SET: &str = "true";

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                let (key, val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => {
                        // value is the next token unless it's another flag
                        let takes_value = it
                            .peek()
                            .map(|n| !n.starts_with("--"))
                            .unwrap_or(false);
                        if takes_value {
                            (rest.to_string(), it.next().unwrap())
                        } else {
                            (rest.to_string(), FLAG_SET.to_string())
                        }
                    }
                };
                out.order.push(key.clone());
                out.flags.insert(key, val);
            } else if out.subcommand.is_none() && out.positionals.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Was `--key` present (with or without a value)?
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Value of `--key`, or `default` when absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// `--key` parsed as usize; panics with a usage message on bad input.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    /// `--key` parsed as u64; panics with a usage message on bad input.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    /// `--key` parsed as f64; panics with a usage message on bad input.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects a number, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    /// Flags the caller did not recognize (for strict validation).
    pub fn unknown_flags(&self, known: &[&str]) -> Vec<String> {
        self.order
            .iter()
            .filter(|k| !known.contains(&k.as_str()))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("discover ecg300 extra");
        assert_eq!(a.subcommand.as_deref(), Some("discover"));
        assert_eq!(a.positionals, vec!["ecg300", "extra"]);
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse("table 1 --seed 9 --runs=3 --full");
        assert_eq!(a.get_u64("seed", 0), 9);
        assert_eq!(a.get_usize("runs", 1), 3);
        assert!(a.has("full"));
        assert!(!a.has("absent"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --verbose --k 10");
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("k", 1), 10);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("name", "d"), "d");
        assert_eq!(a.get_f64("noise", 0.5), 0.5);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("x --good 1 --oops 2");
        assert_eq!(a.unknown_flags(&["good"]), vec!["oops".to_string()]);
    }
}
