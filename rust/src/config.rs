//! Search configuration types shared by every algorithm, the CLI, the
//! service protocol, and the bench harness.

use crate::util::json::Json;

/// SAX discretization parameters (paper notation: s, P, alphabet).
///
/// `Hash`/`Eq` so the type can key prepared-state caches (the
/// [`SearchContext`](crate::context::SearchContext) index cache, the
/// service coordinator's context LRU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SaxParams {
    /// Sequence (discord) length s.
    pub s: usize,
    /// PAA segments P; must divide s.
    pub p: usize,
    /// Alphabet size (2..=20).
    pub alphabet: usize,
}

impl SaxParams {
    /// Build and validate; panics on invalid combinations (use
    /// [`validate`](Self::validate) for fallible construction).
    pub fn new(s: usize, p: usize, alphabet: usize) -> SaxParams {
        let sp = SaxParams { s, p, alphabet };
        sp.validate().expect("invalid SAX params");
        sp
    }

    /// The default PAA segment count for sequence length `s`: the
    /// largest value ≤ 4 that divides `s`, so the default always passes
    /// [`validate`](Self::validate). One rule shared by every defaulting
    /// path (service JSON, CLI `stream`) so the same `s` never gets two
    /// different default discretizations.
    pub fn default_p(s: usize) -> usize {
        (1..=4.min(s)).rev().find(|d| s % d == 0).unwrap_or(1)
    }

    /// Check the paper's constraints: s > 0, P divides s, alphabet 2..=20.
    pub fn validate(&self) -> Result<(), String> {
        if self.s == 0 {
            return Err("s must be > 0".into());
        }
        if self.p == 0 || self.s % self.p != 0 {
            return Err(format!("P={} must divide s={}", self.p, self.s));
        }
        if !(2..=20).contains(&self.alphabet) {
            return Err(format!("alphabet={} out of 2..=20", self.alphabet));
        }
        Ok(())
    }
}

/// Full search request.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchParams {
    /// SAX discretization parameters (s, P, alphabet).
    pub sax: SaxParams,
    /// How many discords to report (k).
    pub k: usize,
    /// Seed for the pseudo-random choices (shuffles, inner-loop order).
    pub seed: u64,
    /// Z-normalize sequences before distance (paper default: yes;
    /// the DADD comparison of Table 7 turns it off).
    pub znormalize: bool,
    /// Allow overlapping (self-match) comparisons (Table 7 protocol only).
    pub allow_self_match: bool,
    /// Worker threads for the parallel engines (`hst-par`, `scamp-par`).
    /// `0` (the default) resolves through
    /// [`ExecPolicy`](crate::exec::ExecPolicy): the `HST_THREADS`
    /// environment variable, then the machine's available parallelism.
    /// Serial engines ignore it.
    pub threads: usize,
}

impl SearchParams {
    /// Standard paper-protocol search.
    pub fn new(s: usize, p: usize, alphabet: usize) -> SearchParams {
        SearchParams {
            sax: SaxParams::new(s, p, alphabet),
            k: 1,
            seed: 0,
            znormalize: true,
            allow_self_match: false,
            threads: 0,
        }
    }

    /// Set the number of discords to report.
    pub fn with_discords(mut self, k: usize) -> SearchParams {
        self.k = k;
        self
    }

    /// Set the seed for the pseudo-random search-order choices.
    pub fn with_seed(mut self, seed: u64) -> SearchParams {
        self.seed = seed;
        self
    }

    /// Request a worker-thread count for the parallel engines (`0` =
    /// resolve automatically; see the [`threads`](Self::threads) field).
    pub fn with_threads(mut self, threads: usize) -> SearchParams {
        self.threads = threads;
        self
    }

    /// Table 7 (DADD) protocol: raw Euclidean distance, overlaps allowed.
    pub fn dadd_protocol(mut self) -> SearchParams {
        self.znormalize = false;
        self.allow_self_match = true;
        self
    }

    /// The distance variant this protocol implies (shared by every
    /// engine's session setup).
    pub fn distance_kind(&self) -> crate::dist::DistanceKind {
        if self.znormalize {
            crate::dist::DistanceKind::Znorm
        } else {
            crate::dist::DistanceKind::Raw
        }
    }

    /// Serialize for the service protocol / reports.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("s", self.sax.s)
            .set("p", self.sax.p)
            .set("alphabet", self.sax.alphabet)
            .set("k", self.k)
            .set("seed", self.seed)
            .set("znormalize", self.znormalize)
            .set("allow_self_match", self.allow_self_match)
            .set("threads", self.threads)
    }

    /// Field names [`from_json`](Self::from_json) accepts.
    pub const JSON_FIELDS: [&'static str; 8] = [
        "s",
        "p",
        "alphabet",
        "k",
        "seed",
        "znormalize",
        "allow_self_match",
        "threads",
    ];

    /// Parse from the service protocol. Missing fields get defaults;
    /// unknown fields are rejected by name (a typo must not silently run
    /// a different search).
    pub fn from_json(v: &Json) -> Result<SearchParams, String> {
        if let Json::Obj(map) = v {
            if let Some(bad) =
                map.keys().find(|k| !Self::JSON_FIELDS.contains(&k.as_str()))
            {
                return Err(format!(
                    "unknown field `{bad}` in params (known: {})",
                    Self::JSON_FIELDS.join(", ")
                ));
            }
        } else {
            return Err("params must be a JSON object".into());
        }
        let u = |key: &str, default: usize| -> Result<usize, String> {
            match v.get(key) {
                None => Ok(default),
                Some(j) => j
                    .as_u64()
                    .map(|x| x as usize)
                    .ok_or_else(|| format!("field `{key}` must be an integer")),
            }
        };
        let s = u("s", 0)?;
        if s == 0 {
            return Err("field `s` is required".into());
        }
        // Default P: the shared rule (a plain `4.min(s)` fails for valid
        // lengths like s = 10).
        let p = u("p", SaxParams::default_p(s))?;
        let alphabet = u("alphabet", 4)?;
        let sax = SaxParams { s, p, alphabet };
        sax.validate()?;
        Ok(SearchParams {
            sax,
            k: u("k", 1)?,
            seed: v.get("seed").and_then(|j| j.as_u64()).unwrap_or(0),
            znormalize: v
                .get("znormalize")
                .and_then(|j| j.as_bool())
                .unwrap_or(true),
            allow_self_match: v
                .get("allow_self_match")
                .and_then(|j| j.as_bool())
                .unwrap_or(false),
            threads: u("threads", 0)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_bad_params() {
        assert!(SaxParams { s: 0, p: 1, alphabet: 4 }.validate().is_err());
        assert!(SaxParams { s: 10, p: 3, alphabet: 4 }.validate().is_err());
        assert!(SaxParams { s: 10, p: 5, alphabet: 1 }.validate().is_err());
        assert!(SaxParams { s: 10, p: 5, alphabet: 4 }.validate().is_ok());
    }

    #[test]
    fn json_roundtrip() {
        let p = SearchParams::new(120, 4, 4)
            .with_discords(10)
            .with_seed(7)
            .with_threads(4);
        let j = p.to_json();
        let back = SearchParams::from_json(&j).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn from_json_rejects_unknown_fields_by_name() {
        // regression: a typo'd field used to be silently ignored, running
        // a different search than the caller asked for
        let j = Json::parse(r#"{"s": 64, "treads": 4}"#).unwrap();
        let err = SearchParams::from_json(&j).unwrap_err();
        assert!(err.contains("`treads`"), "{err}");
        assert!(SearchParams::from_json(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn threads_defaults_to_auto() {
        let j = Json::parse(r#"{"s": 64}"#).unwrap();
        assert_eq!(SearchParams::from_json(&j).unwrap().threads, 0);
        let j = Json::parse(r#"{"s": 64, "threads": 2}"#).unwrap();
        assert_eq!(SearchParams::from_json(&j).unwrap().threads, 2);
        assert_eq!(SearchParams::new(64, 4, 4).threads, 0);
    }

    #[test]
    fn explicit_zero_threads_resolves_to_auto() {
        // regression: an explicit `"threads": 0` over JSON (or
        // `--threads 0` on the CLI, which lands in the same field) used
        // to rely on every consumer special-casing zero; the sentinel now
        // normalizes through ExecPolicy alone, so it must resolve to the
        // auto worker count, never to a zero-worker pool
        use crate::exec::ExecPolicy;
        let j = Json::parse(r#"{"s": 64, "threads": 0}"#).unwrap();
        let p = SearchParams::from_json(&j).unwrap();
        assert_eq!(p.threads, 0, "the sentinel is preserved");
        assert_eq!(
            ExecPolicy::new(p.threads).resolve(),
            ExecPolicy::auto().resolve(),
            "and resolves exactly like the auto policy"
        );
        assert!(ExecPolicy::new(p.threads).resolve() >= 1);
        // builder path carries the same sentinel
        let p = SearchParams::new(64, 4, 4).with_threads(0);
        assert_eq!(ExecPolicy::new(p.threads), ExecPolicy::auto());
    }

    #[test]
    fn from_json_defaults() {
        let j = Json::parse(r#"{"s": 128}"#).unwrap();
        let p = SearchParams::from_json(&j).unwrap();
        assert_eq!(p.sax.p, 4);
        assert_eq!(p.sax.alphabet, 4);
        assert_eq!(p.k, 1);
        assert!(p.znormalize);
    }

    #[test]
    fn default_p_is_the_largest_divisor_up_to_four() {
        for (s, want) in [(128usize, 4usize), (10, 2), (9, 3), (7, 1), (90, 3)] {
            assert_eq!(SaxParams::default_p(s), want, "s={s}");
        }
    }

    #[test]
    fn from_json_default_p_always_divides_s() {
        // regression: s = 10 used to default to p = 4, which fails
        // SaxParams::validate (4 does not divide 10)
        for (s, want_p) in [(128usize, 4usize), (10, 2), (9, 3), (7, 1), (12, 4)] {
            let j = Json::parse(&format!(r#"{{"s": {s}}}"#)).unwrap();
            let p = SearchParams::from_json(&j)
                .unwrap_or_else(|e| panic!("s={s}: {e}"));
            assert_eq!(p.sax.p, want_p, "s={s}");
            assert_eq!(p.sax.s % p.sax.p, 0, "s={s}");
        }
    }

    #[test]
    fn distance_kind_follows_protocol() {
        use crate::dist::DistanceKind;
        assert_eq!(SearchParams::new(64, 4, 4).distance_kind(), DistanceKind::Znorm);
        assert_eq!(
            SearchParams::new(64, 4, 4).dadd_protocol().distance_kind(),
            DistanceKind::Raw
        );
    }

    #[test]
    fn from_json_requires_s() {
        let j = Json::parse(r#"{"k": 3}"#).unwrap();
        assert!(SearchParams::from_json(&j).is_err());
    }

    #[test]
    fn dadd_protocol_flags() {
        let p = SearchParams::new(512, 4, 4).dadd_protocol();
        assert!(!p.znormalize);
        assert!(p.allow_self_match);
    }
}
