//! Search configuration types shared by every algorithm, the CLI, the
//! service protocol, and the bench harness.

use crate::util::json::Json;

/// SAX discretization parameters (paper notation: s, P, alphabet).
///
/// `Hash`/`Eq` so the type can key prepared-state caches (the
/// [`SearchContext`](crate::context::SearchContext) index cache, the
/// service coordinator's context LRU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SaxParams {
    /// Sequence (discord) length s.
    pub s: usize,
    /// PAA segments P; must divide s.
    pub p: usize,
    /// Alphabet size (2..=20).
    pub alphabet: usize,
}

impl SaxParams {
    /// Build and validate; panics on invalid combinations (use
    /// [`validate`](Self::validate) for fallible construction).
    pub fn new(s: usize, p: usize, alphabet: usize) -> SaxParams {
        let sp = SaxParams { s, p, alphabet };
        sp.validate().expect("invalid SAX params");
        sp
    }

    /// The default PAA segment count for sequence length `s`: the
    /// largest value ≤ 4 that divides `s`, so the default always passes
    /// [`validate`](Self::validate). One rule shared by every defaulting
    /// path (service JSON, CLI `stream`) so the same `s` never gets two
    /// different default discretizations.
    pub fn default_p(s: usize) -> usize {
        (1..=4.min(s)).rev().find(|d| s % d == 0).unwrap_or(1)
    }

    /// Check the paper's constraints: s > 0, P divides s, alphabet 2..=20.
    pub fn validate(&self) -> Result<(), String> {
        if self.s == 0 {
            return Err("s must be > 0".into());
        }
        if self.p == 0 || self.s % self.p != 0 {
            return Err(format!("P={} must divide s={}", self.p, self.s));
        }
        if !(2..=20).contains(&self.alphabet) {
            return Err(format!("alphabet={} out of 2..=20", self.alphabet));
        }
        Ok(())
    }
}

/// An inclusive range of sequence lengths `{min, max, step}` scanned by
/// the variable-length engines ([`hst-vl`](crate::vl::HstVl) and
/// [`merlin`](crate::algo::merlin::Merlin)).
///
/// The all-zero [`Default`] is the registry sentinel ("derive the range
/// from `SearchParams.sax.s` at run time"); a populated range must pass
/// [`validate`](Self::validate) before use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct LengthRange {
    /// Smallest scanned length (inclusive).
    pub min: usize,
    /// Largest scanned length (inclusive).
    pub max: usize,
    /// Stride between scanned lengths.
    pub step: usize,
}

impl LengthRange {
    /// Build a range; panics on invalid combinations (use
    /// [`validate`](Self::validate) for fallible construction).
    pub fn new(min: usize, max: usize, step: usize) -> LengthRange {
        let r = LengthRange { min, max, step };
        r.validate().expect("invalid length range");
        r
    }

    /// The run-time derivation both variable-length engines share when a
    /// request names only a single length `s`: scan `[s/2, s]` (min
    /// clamped to 4) in steps of `s/8` (at least 1).
    pub fn around(s: usize) -> LengthRange {
        LengthRange {
            min: (s / 2).max(4),
            max: s,
            step: (s / 8).max(1),
        }
    }

    /// Check the constraints every consumer relies on, naming the field
    /// that fails: `min` ≥ 4 (shorter windows degenerate under SAX),
    /// `max` ≥ `min`, `step` ≥ 1.
    pub fn validate(&self) -> Result<(), String> {
        if self.min < 4 {
            return Err(format!("length range min={} must be >= 4", self.min));
        }
        if self.max < self.min {
            return Err(format!(
                "length range max={} must be >= min={}",
                self.max, self.min
            ));
        }
        if self.step == 0 {
            return Err("length range step must be >= 1".into());
        }
        Ok(())
    }

    /// The lengths this range scans, ascending: `min, min+step, …, ≤ max`.
    pub fn lengths(&self) -> impl Iterator<Item = usize> {
        (self.min..=self.max).step_by(self.step.max(1))
    }

    /// Number of lengths [`lengths`](Self::lengths) yields.
    pub fn count(&self) -> usize {
        if self.max < self.min || self.step == 0 {
            return 0;
        }
        (self.max - self.min) / self.step + 1
    }

    /// Whether this is the all-zero registry sentinel (no explicit range).
    pub fn is_unset(&self) -> bool {
        *self == LengthRange::default()
    }
}

/// Full search request.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchParams {
    /// SAX discretization parameters (s, P, alphabet).
    pub sax: SaxParams,
    /// How many discords to report (k).
    pub k: usize,
    /// Seed for the pseudo-random choices (shuffles, inner-loop order).
    pub seed: u64,
    /// Z-normalize sequences before distance (paper default: yes;
    /// the DADD comparison of Table 7 turns it off).
    pub znormalize: bool,
    /// Allow overlapping (self-match) comparisons (Table 7 protocol only).
    pub allow_self_match: bool,
    /// Worker threads for the parallel engines (`hst-par`, `scamp-par`).
    /// `0` (the default) resolves through
    /// [`ExecPolicy`](crate::exec::ExecPolicy): the `HST_THREADS`
    /// environment variable, then the machine's available parallelism.
    /// Serial engines ignore it.
    pub threads: usize,
    /// Optional length range for the variable-length engines (`hst-vl`,
    /// `merlin`). `None` (the default) lets those engines derive
    /// [`LengthRange::around`]`(sax.s)` at run time; single-length
    /// engines ignore it. Serialized as `s_min`/`s_max`/`s_step`.
    pub s_range: Option<LengthRange>,
}

impl SearchParams {
    /// Standard paper-protocol search.
    pub fn new(s: usize, p: usize, alphabet: usize) -> SearchParams {
        SearchParams {
            sax: SaxParams::new(s, p, alphabet),
            k: 1,
            seed: 0,
            znormalize: true,
            allow_self_match: false,
            threads: 0,
            s_range: None,
        }
    }

    /// Set the number of discords to report.
    pub fn with_discords(mut self, k: usize) -> SearchParams {
        self.k = k;
        self
    }

    /// Set the seed for the pseudo-random search-order choices.
    pub fn with_seed(mut self, seed: u64) -> SearchParams {
        self.seed = seed;
        self
    }

    /// Request a worker-thread count for the parallel engines (`0` =
    /// resolve automatically; see the [`threads`](Self::threads) field).
    pub fn with_threads(mut self, threads: usize) -> SearchParams {
        self.threads = threads;
        self
    }

    /// Set the length range the variable-length engines scan (validated
    /// here, so an inverted or zero-step range fails at construction, not
    /// mid-search).
    pub fn with_length_range(mut self, range: LengthRange) -> SearchParams {
        range.validate().expect("invalid length range");
        self.s_range = Some(range);
        self
    }

    /// Table 7 (DADD) protocol: raw Euclidean distance, overlaps allowed.
    pub fn dadd_protocol(mut self) -> SearchParams {
        self.znormalize = false;
        self.allow_self_match = true;
        self
    }

    /// The distance variant this protocol implies (shared by every
    /// engine's session setup).
    pub fn distance_kind(&self) -> crate::dist::DistanceKind {
        if self.znormalize {
            crate::dist::DistanceKind::Znorm
        } else {
            crate::dist::DistanceKind::Raw
        }
    }

    /// Serialize for the service protocol / reports. The length range is
    /// emitted (as `s_min`/`s_max`/`s_step`) only when set, so
    /// single-length requests roundtrip unchanged.
    pub fn to_json(&self) -> Json {
        let j = Json::obj()
            .set("s", self.sax.s)
            .set("p", self.sax.p)
            .set("alphabet", self.sax.alphabet)
            .set("k", self.k)
            .set("seed", self.seed)
            .set("znormalize", self.znormalize)
            .set("allow_self_match", self.allow_self_match)
            .set("threads", self.threads);
        match self.s_range {
            None => j,
            Some(r) => j
                .set("s_min", r.min)
                .set("s_max", r.max)
                .set("s_step", r.step),
        }
    }

    /// Field names [`from_json`](Self::from_json) accepts.
    pub const JSON_FIELDS: [&'static str; 11] = [
        "s",
        "p",
        "alphabet",
        "k",
        "seed",
        "znormalize",
        "allow_self_match",
        "threads",
        "s_min",
        "s_max",
        "s_step",
    ];

    /// Parse from the service protocol. Missing fields get defaults;
    /// unknown fields are rejected by name (a typo must not silently run
    /// a different search).
    pub fn from_json(v: &Json) -> Result<SearchParams, String> {
        if let Json::Obj(map) = v {
            if let Some(bad) =
                map.keys().find(|k| !Self::JSON_FIELDS.contains(&k.as_str()))
            {
                return Err(format!(
                    "unknown field `{bad}` in params (known: {})",
                    Self::JSON_FIELDS.join(", ")
                ));
            }
        } else {
            return Err("params must be a JSON object".into());
        }
        let u = |key: &str, default: usize| -> Result<usize, String> {
            match v.get(key) {
                None => Ok(default),
                Some(j) => j
                    .as_u64()
                    .map(|x| x as usize)
                    .ok_or_else(|| format!("field `{key}` must be an integer")),
            }
        };
        let s = u("s", 0)?;
        if s == 0 {
            return Err("field `s` is required".into());
        }
        // Default P: the shared rule (a plain `4.min(s)` fails for valid
        // lengths like s = 10).
        let p = u("p", SaxParams::default_p(s))?;
        let alphabet = u("alphabet", 4)?;
        let sax = SaxParams { s, p, alphabet };
        sax.validate()?;
        // `s_min`/`s_max` travel together; `s_step` defaults to 1. The
        // parsed range must validate here, not at first use inside an
        // engine.
        let has_min = v.get("s_min").is_some();
        let has_max = v.get("s_max").is_some();
        let s_range = match (has_min, has_max) {
            (false, false) => {
                if v.get("s_step").is_some() {
                    return Err(
                        "field `s_step` requires `s_min` and `s_max`".into()
                    );
                }
                None
            }
            (true, true) => {
                let range = LengthRange {
                    min: u("s_min", 0)?,
                    max: u("s_max", 0)?,
                    step: u("s_step", 1)?,
                };
                range.validate()?;
                Some(range)
            }
            (true, false) => {
                return Err("field `s_min` requires `s_max`".into())
            }
            (false, true) => {
                return Err("field `s_max` requires `s_min`".into())
            }
        };
        Ok(SearchParams {
            sax,
            s_range,
            k: u("k", 1)?,
            seed: v.get("seed").and_then(|j| j.as_u64()).unwrap_or(0),
            znormalize: v
                .get("znormalize")
                .and_then(|j| j.as_bool())
                .unwrap_or(true),
            allow_self_match: v
                .get("allow_self_match")
                .and_then(|j| j.as_bool())
                .unwrap_or(false),
            threads: u("threads", 0)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_bad_params() {
        assert!(SaxParams { s: 0, p: 1, alphabet: 4 }.validate().is_err());
        assert!(SaxParams { s: 10, p: 3, alphabet: 4 }.validate().is_err());
        assert!(SaxParams { s: 10, p: 5, alphabet: 1 }.validate().is_err());
        assert!(SaxParams { s: 10, p: 5, alphabet: 4 }.validate().is_ok());
    }

    #[test]
    fn json_roundtrip() {
        let p = SearchParams::new(120, 4, 4)
            .with_discords(10)
            .with_seed(7)
            .with_threads(4);
        let j = p.to_json();
        let back = SearchParams::from_json(&j).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn from_json_rejects_unknown_fields_by_name() {
        // regression: a typo'd field used to be silently ignored, running
        // a different search than the caller asked for
        let j = Json::parse(r#"{"s": 64, "treads": 4}"#).unwrap();
        let err = SearchParams::from_json(&j).unwrap_err();
        assert!(err.contains("`treads`"), "{err}");
        assert!(SearchParams::from_json(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn threads_defaults_to_auto() {
        let j = Json::parse(r#"{"s": 64}"#).unwrap();
        assert_eq!(SearchParams::from_json(&j).unwrap().threads, 0);
        let j = Json::parse(r#"{"s": 64, "threads": 2}"#).unwrap();
        assert_eq!(SearchParams::from_json(&j).unwrap().threads, 2);
        assert_eq!(SearchParams::new(64, 4, 4).threads, 0);
    }

    #[test]
    fn explicit_zero_threads_resolves_to_auto() {
        // regression: an explicit `"threads": 0` over JSON (or
        // `--threads 0` on the CLI, which lands in the same field) used
        // to rely on every consumer special-casing zero; the sentinel now
        // normalizes through ExecPolicy alone, so it must resolve to the
        // auto worker count, never to a zero-worker pool
        use crate::exec::ExecPolicy;
        let j = Json::parse(r#"{"s": 64, "threads": 0}"#).unwrap();
        let p = SearchParams::from_json(&j).unwrap();
        assert_eq!(p.threads, 0, "the sentinel is preserved");
        assert_eq!(
            ExecPolicy::new(p.threads).resolve(),
            ExecPolicy::auto().resolve(),
            "and resolves exactly like the auto policy"
        );
        assert!(ExecPolicy::new(p.threads).resolve() >= 1);
        // builder path carries the same sentinel
        let p = SearchParams::new(64, 4, 4).with_threads(0);
        assert_eq!(ExecPolicy::new(p.threads), ExecPolicy::auto());
    }

    #[test]
    fn from_json_defaults() {
        let j = Json::parse(r#"{"s": 128}"#).unwrap();
        let p = SearchParams::from_json(&j).unwrap();
        assert_eq!(p.sax.p, 4);
        assert_eq!(p.sax.alphabet, 4);
        assert_eq!(p.k, 1);
        assert!(p.znormalize);
    }

    #[test]
    fn default_p_is_the_largest_divisor_up_to_four() {
        for (s, want) in [(128usize, 4usize), (10, 2), (9, 3), (7, 1), (90, 3)] {
            assert_eq!(SaxParams::default_p(s), want, "s={s}");
        }
    }

    #[test]
    fn from_json_default_p_always_divides_s() {
        // regression: s = 10 used to default to p = 4, which fails
        // SaxParams::validate (4 does not divide 10)
        for (s, want_p) in [(128usize, 4usize), (10, 2), (9, 3), (7, 1), (12, 4)] {
            let j = Json::parse(&format!(r#"{{"s": {s}}}"#)).unwrap();
            let p = SearchParams::from_json(&j)
                .unwrap_or_else(|e| panic!("s={s}: {e}"));
            assert_eq!(p.sax.p, want_p, "s={s}");
            assert_eq!(p.sax.s % p.sax.p, 0, "s={s}");
        }
    }

    #[test]
    fn distance_kind_follows_protocol() {
        use crate::dist::DistanceKind;
        assert_eq!(SearchParams::new(64, 4, 4).distance_kind(), DistanceKind::Znorm);
        assert_eq!(
            SearchParams::new(64, 4, 4).dadd_protocol().distance_kind(),
            DistanceKind::Raw
        );
    }

    #[test]
    fn from_json_requires_s() {
        let j = Json::parse(r#"{"k": 3}"#).unwrap();
        assert!(SearchParams::from_json(&j).is_err());
    }

    #[test]
    fn dadd_protocol_flags() {
        let p = SearchParams::new(512, 4, 4).dadd_protocol();
        assert!(!p.znormalize);
        assert!(p.allow_self_match);
    }

    #[test]
    fn length_range_validation_names_the_field() {
        let err = LengthRange { min: 2, max: 8, step: 1 }.validate().unwrap_err();
        assert!(err.contains("min=2"), "{err}");
        let err = LengthRange { min: 8, max: 4, step: 1 }.validate().unwrap_err();
        assert!(err.contains("max=4"), "{err}");
        let err = LengthRange { min: 4, max: 8, step: 0 }.validate().unwrap_err();
        assert!(err.contains("step"), "{err}");
        assert!(LengthRange { min: 4, max: 4, step: 1 }.validate().is_ok());
    }

    #[test]
    fn length_range_lengths_and_count_agree() {
        for r in [
            LengthRange::new(4, 4, 1),
            LengthRange::new(8, 32, 8),
            LengthRange::new(8, 30, 8), // max not on the grid
            LengthRange::new(5, 9, 2),
        ] {
            let lens: Vec<usize> = r.lengths().collect();
            assert_eq!(lens.len(), r.count(), "{r:?}");
            assert_eq!(lens.first(), Some(&r.min), "{r:?}");
            assert!(lens.iter().all(|&s| s <= r.max), "{r:?}");
            assert!(
                lens.windows(2).all(|w| w[1] - w[0] == r.step),
                "{r:?}"
            );
        }
    }

    #[test]
    fn length_range_around_matches_the_merlin_derivation() {
        let r = LengthRange::around(64);
        assert_eq!(r, LengthRange { min: 32, max: 64, step: 8 });
        // small s clamps: min >= 4, step >= 1
        let r = LengthRange::around(6);
        assert_eq!(r, LengthRange { min: 4, max: 6, step: 1 });
        assert!(r.validate().is_ok());
        assert!(!r.is_unset());
        assert!(LengthRange::default().is_unset());
    }

    #[test]
    fn length_range_json_roundtrip_on_search_params() {
        let p = SearchParams::new(64, 4, 4)
            .with_length_range(LengthRange::new(32, 64, 8));
        let j = p.to_json();
        assert_eq!(j.get("s_min").and_then(|v| v.as_u64()), Some(32));
        assert_eq!(j.get("s_max").and_then(|v| v.as_u64()), Some(64));
        assert_eq!(j.get("s_step").and_then(|v| v.as_u64()), Some(8));
        let back = SearchParams::from_json(&j).unwrap();
        assert_eq!(p, back);
        // no range → the keys stay absent and roundtrip to None
        let p = SearchParams::new(64, 4, 4);
        let j = p.to_json();
        assert!(j.get("s_min").is_none());
        assert_eq!(SearchParams::from_json(&j).unwrap().s_range, None);
    }

    #[test]
    fn length_range_json_rejects_partial_or_invalid_ranges() {
        let j = Json::parse(r#"{"s": 64, "s_min": 32}"#).unwrap();
        let err = SearchParams::from_json(&j).unwrap_err();
        assert!(err.contains("`s_min` requires `s_max`"), "{err}");
        let j = Json::parse(r#"{"s": 64, "s_max": 64}"#).unwrap();
        let err = SearchParams::from_json(&j).unwrap_err();
        assert!(err.contains("`s_max` requires `s_min`"), "{err}");
        let j = Json::parse(r#"{"s": 64, "s_step": 4}"#).unwrap();
        let err = SearchParams::from_json(&j).unwrap_err();
        assert!(err.contains("`s_step` requires"), "{err}");
        // an inverted range fails LengthRange::validate at parse time
        let j =
            Json::parse(r#"{"s": 64, "s_min": 64, "s_max": 32}"#).unwrap();
        let err = SearchParams::from_json(&j).unwrap_err();
        assert!(err.contains("max=32"), "{err}");
        // s_step defaults to 1 when the pair is present
        let j =
            Json::parse(r#"{"s": 64, "s_min": 32, "s_max": 40}"#).unwrap();
        let p = SearchParams::from_json(&j).unwrap();
        assert_eq!(p.s_range, Some(LengthRange { min: 32, max: 40, step: 1 }));
    }
}
