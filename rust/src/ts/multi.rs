//! The multivariate time-series container.
//!
//! A [`MultiSeries`] is a set of *channels* — named, equally long
//! univariate [`TimeSeries`] recorded over the same clock (column
//! storage: each channel owns its contiguous `Vec<f64>`, so per-channel
//! engines and distance sessions borrow plain slices with no striding).
//! Sequence terminology carries over unchanged from the univariate case:
//! a multivariate sequence of length `s` starting at `k` is the tuple of
//! per-channel windows `channel_c[k..k + s]`, and there are
//! `num_sequences(s) = n_total − s + 1` of them.
//!
//! Construction paths: [`MultiSeries::new`] from channels assembled in
//! code, [`crate::ts::io::load_multi_csv`] for delimited files, and
//! [`crate::ts::generators::correlated_channels`] for synthetic data.

use anyhow::{bail, ensure, Result};

use super::series::TimeSeries;

/// An in-memory multivariate time series: named channels in column
/// storage, all of equal length.
#[derive(Debug, Clone, PartialEq)]
pub struct MultiSeries {
    /// Human-readable identifier (dataset name).
    pub name: String,
    channels: Vec<TimeSeries>,
}

impl MultiSeries {
    /// Build from channels. Errors when no channel is given, channel
    /// lengths differ, or two channels share a name (channel names are
    /// the selection keys of [`select`](Self::select)).
    pub fn new(
        name: impl Into<String>,
        channels: Vec<TimeSeries>,
    ) -> Result<MultiSeries> {
        ensure!(!channels.is_empty(), "a MultiSeries needs >= 1 channel");
        let len = channels[0].n_total();
        for c in &channels {
            ensure!(
                c.n_total() == len,
                "channel `{}` has {} points but `{}` has {}: channels must \
                 share one clock",
                c.name,
                c.n_total(),
                channels[0].name,
                len
            );
        }
        for (i, c) in channels.iter().enumerate() {
            if let Some(dup) = channels[..i].iter().find(|o| o.name == c.name) {
                bail!("duplicate channel name `{}`", dup.name);
            }
        }
        Ok(MultiSeries {
            name: name.into(),
            channels,
        })
    }

    /// Wrap one univariate series as a single-channel multivariate one
    /// (the adapter the univariate [`Algorithm`] faces of the mdim
    /// engines use).
    ///
    /// [`Algorithm`]: crate::algo::Algorithm
    pub fn from_univariate(ts: TimeSeries) -> MultiSeries {
        let name = ts.name.clone();
        MultiSeries {
            name,
            channels: vec![ts],
        }
    }

    /// Number of channels d.
    #[inline]
    pub fn dims(&self) -> usize {
        self.channels.len()
    }

    /// Total points per channel N_tot.
    #[inline]
    pub fn n_total(&self) -> usize {
        self.channels[0].n_total()
    }

    /// Number of complete sequences of length `s` (same count in every
    /// channel): N = N_tot − s + 1, or 0 when the series is shorter.
    #[inline]
    pub fn num_sequences(&self, s: usize) -> usize {
        self.channels[0].num_sequences(s)
    }

    /// Borrow channel `c`.
    #[inline]
    pub fn channel(&self, c: usize) -> &TimeSeries {
        &self.channels[c]
    }

    /// All channels, in storage order.
    pub fn channels(&self) -> &[TimeSeries] {
        &self.channels
    }

    /// Channel names, in storage order.
    pub fn channel_names(&self) -> Vec<&str> {
        self.channels.iter().map(|c| c.name.as_str()).collect()
    }

    /// Index of the channel named `name`, if any.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.channels.iter().position(|c| c.name == name)
    }

    /// Resolve a channel selection to ascending storage indexes.
    ///
    /// An empty selection means *all channels*. Unknown and duplicate
    /// names are rejected by name (a typo'd channel must fail the
    /// search, not silently search a different subset). The result is
    /// sorted ascending, so the aggregate distance — accumulated in
    /// resolved order — is independent of how the caller ordered the
    /// selection list.
    pub fn select(&self, names: &[String]) -> Result<Vec<usize>> {
        if names.is_empty() {
            return Ok((0..self.dims()).collect());
        }
        let mut idxs = Vec::with_capacity(names.len());
        for n in names {
            let Some(i) = self.index_of(n) else {
                bail!(
                    "unknown channel `{n}` (known: {})",
                    self.channel_names().join(", ")
                );
            };
            if idxs.contains(&i) {
                bail!("duplicate channel `{n}` in selection");
            }
            idxs.push(i);
        }
        idxs.sort_unstable();
        Ok(idxs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_channel() -> MultiSeries {
        MultiSeries::new(
            "m",
            vec![
                TimeSeries::new("a", vec![1.0, 2.0, 3.0, 4.0]),
                TimeSeries::new("b", vec![4.0, 3.0, 2.0, 1.0]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn counting_mirrors_the_univariate_rules() {
        let ms = two_channel();
        assert_eq!(ms.dims(), 2);
        assert_eq!(ms.n_total(), 4);
        assert_eq!(ms.num_sequences(2), 3);
        assert_eq!(ms.num_sequences(5), 0);
        assert_eq!(ms.channel(1).points, vec![4.0, 3.0, 2.0, 1.0]);
        assert_eq!(ms.channel_names(), vec!["a", "b"]);
    }

    #[test]
    fn construction_rejects_bad_shapes() {
        assert!(MultiSeries::new("m", vec![]).is_err(), "no channels");
        let err = MultiSeries::new(
            "m",
            vec![
                TimeSeries::new("a", vec![1.0, 2.0]),
                TimeSeries::new("b", vec![1.0]),
            ],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("`b`"), "{err}");
        let err = MultiSeries::new(
            "m",
            vec![
                TimeSeries::new("a", vec![1.0]),
                TimeSeries::new("a", vec![2.0]),
            ],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("duplicate channel name `a`"), "{err}");
    }

    #[test]
    fn selection_resolves_sorted_and_strict() {
        let ms = two_channel();
        assert_eq!(ms.select(&[]).unwrap(), vec![0, 1], "empty = all");
        // order-independent: the resolved indexes come back ascending
        let sel = ms.select(&["b".into(), "a".into()]).unwrap();
        assert_eq!(sel, vec![0, 1]);
        assert_eq!(ms.select(&["b".into()]).unwrap(), vec![1]);
        let err = ms.select(&["c".into()]).unwrap_err().to_string();
        assert!(err.contains("unknown channel `c`"), "{err}");
        assert!(err.contains("a, b"), "{err}");
        let err = ms
            .select(&["a".into(), "a".into()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate channel `a`"), "{err}");
    }

    #[test]
    fn univariate_wrapper_is_one_channel() {
        let ms = MultiSeries::from_univariate(TimeSeries::new("u", vec![1.0, 2.0]));
        assert_eq!(ms.dims(), 1);
        assert_eq!(ms.name, "u");
        assert_eq!(ms.index_of("u"), Some(0));
        assert_eq!(ms.index_of("x"), None);
    }
}
