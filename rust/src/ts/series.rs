//! The core time-series container.

/// An in-memory univariate time series.
///
/// Terminology follows the paper (Sec. 2.1): the series has `n_total()`
/// points; a *sequence* of length `s` starting at time `k` is the window
/// `points[k..k + s]`; there are `num_sequences(s) = n_total - s + 1`
/// complete sequences.
#[derive(Debug, Clone, PartialEq)]
pub struct TimeSeries {
    /// Human-readable identifier (dataset name).
    pub name: String,
    /// The raw points p_j.
    pub points: Vec<f64>,
}

impl TimeSeries {
    /// Build a series from raw points.
    pub fn new(name: impl Into<String>, points: Vec<f64>) -> TimeSeries {
        TimeSeries {
            name: name.into(),
            points,
        }
    }

    /// Total number of points N_tot.
    #[inline]
    pub fn n_total(&self) -> usize {
        self.points.len()
    }

    /// Number of complete sequences of length `s`: N = N_tot - s + 1.
    /// Returns 0 when the series is shorter than `s`.
    #[inline]
    pub fn num_sequences(&self, s: usize) -> usize {
        if self.points.len() >= s {
            self.points.len() - s + 1
        } else {
            0
        }
    }

    /// Borrow the sequence starting at `k` (length `s`).
    #[inline]
    pub fn seq(&self, k: usize, s: usize) -> &[f64] {
        &self.points[k..k + s]
    }

    /// Truncate to the first `n` points (paper Sec. 4.5 slices ECG 300).
    pub fn slice_prefix(&self, n: usize) -> TimeSeries {
        let n = n.min(self.points.len());
        TimeSeries {
            name: format!("{}[:{}]", self.name, n),
            points: self.points[..n].to_vec(),
        }
    }

    /// Min/max of the raw points (NaN-free input assumed).
    pub fn min_max(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &p in &self.points {
            lo = lo.min(p);
            hi = hi.max(p);
        }
        (lo, hi)
    }
}

/// Helper trait so generators can end with `.into_series(name)`.
pub trait IntoSeries {
    /// Wrap `self` as a named [`TimeSeries`].
    fn into_series(self, name: &str) -> TimeSeries;
}

impl IntoSeries for Vec<f64> {
    fn into_series(self, name: &str) -> TimeSeries {
        TimeSeries::new(name, self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_counting() {
        let ts = TimeSeries::new("t", vec![0.0; 100]);
        assert_eq!(ts.n_total(), 100);
        assert_eq!(ts.num_sequences(10), 91);
        assert_eq!(ts.num_sequences(100), 1);
        assert_eq!(ts.num_sequences(101), 0);
    }

    #[test]
    fn seq_borrows_window() {
        let ts = TimeSeries::new("t", (0..10).map(|i| i as f64).collect());
        assert_eq!(ts.seq(3, 4), &[3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn slice_prefix_truncates() {
        let ts = TimeSeries::new("t", (0..10).map(|i| i as f64).collect());
        let sl = ts.slice_prefix(4);
        assert_eq!(sl.points, vec![0.0, 1.0, 2.0, 3.0]);
        let over = ts.slice_prefix(99);
        assert_eq!(over.n_total(), 10);
    }

    #[test]
    fn min_max() {
        let ts = TimeSeries::new("t", vec![3.0, -1.0, 2.0]);
        assert_eq!(ts.min_max(), (-1.0, 3.0));
    }
}
