//! Rolling per-sequence statistics.
//!
//! The paper's memory-saving trick (Sec. 2.1, Eq. 2/3): instead of storing
//! z-normalized copies of every sequence, store each sequence's mean μ_k and
//! standard deviation σ_k and fold the normalization into the distance
//! function.
//!
//! Each (μ_k, σ_k) pair is computed by [`window_stats`], a **pure function
//! of that sequence's points alone** (direct two-pass summation — sum, then
//! squared residuals about the mean). Purity is a load-bearing invariant,
//! not a style choice: the [`stream`](crate::stream) monitor extends its
//! stats incrementally (one new sequence per appended point) and relies on
//! those entries being bit-identical to what a cold [`SeqStats::compute`]
//! over the current window would produce — which in turn is what makes a
//! warm streaming search bit-identical to a cold batch search. A prefix-sum
//! formulation would be O(N) instead of O(N·s), but its per-window values
//! depend on the accumulation history of the whole series, breaking that
//! bit-equality (and it cancels catastrophically for large offsets anyway;
//! the two-pass form is the numerically stable one).

use super::series::TimeSeries;

/// Mean and standard deviation of one window, as a pure function of the
/// window's points: `m = Σp/s` then `σ = sqrt(Σ(p−m)²/s)`, floored at
/// [`SIGMA_FLOOR`]. The shared kernel of the batch [`SeqStats::compute`]
/// and the streaming monitor's incremental per-point updates — both paths
/// produce bit-identical values for the same window by construction.
pub fn window_stats(w: &[f64]) -> (f64, f64) {
    debug_assert!(!w.is_empty());
    let m = w.iter().sum::<f64>() / w.len() as f64;
    let var = w.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / w.len() as f64;
    (m, var.sqrt().max(SIGMA_FLOOR))
}

/// Per-sequence-start rolling mean and standard deviation for a fixed
/// sequence length `s`.
#[derive(Debug, Clone)]
pub struct SeqStats {
    /// Sequence length the stats were computed for.
    pub s: usize,
    /// mean[k] = μ of points[k..k+s]
    pub mean: Vec<f64>,
    /// std[k] = population σ of points[k..k+s]; floored at `SIGMA_FLOOR`
    /// so constant sequences don't divide by zero.
    pub std: Vec<f64>,
}

/// Lower bound on σ: constant (or numerically-constant) windows get this
/// value so z-normalization maps them to the zero vector instead of NaN.
pub const SIGMA_FLOOR: f64 = 1e-12;

impl SeqStats {
    /// Compute rolling stats for every complete window of length `s`.
    ///
    /// Each entry is [`window_stats`] of its window, so any sub-slice of
    /// the series yields bit-identical entries for the windows it covers —
    /// the invariant the streaming monitor's incremental updates rest on.
    pub fn compute(ts: &TimeSeries, s: usize) -> SeqStats {
        let n = ts.num_sequences(s);
        assert!(s >= 1, "sequence length must be >= 1");
        assert!(n > 0, "series shorter than sequence length");
        let mut mean = Vec::with_capacity(n);
        let mut std = Vec::with_capacity(n);
        for k in 0..n {
            let (m, sd) = window_stats(ts.seq(k, s));
            mean.push(m);
            std.push(sd);
        }
        SeqStats { s, mean, std }
    }

    /// Number of sequence starts covered.
    #[inline]
    pub fn len(&self) -> usize {
        self.mean.len()
    }

    /// Whether no sequence start is covered.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }

    /// Z-normalize the sequence starting at `k` into `out` (len `s`).
    pub fn znorm_into(&self, ts: &TimeSeries, k: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.s);
        let mu = self.mean[k];
        let inv_sd = 1.0 / self.std[k];
        for (o, &p) in out.iter_mut().zip(ts.seq(k, self.s)) {
            *o = (p - mu) * inv_sd;
        }
    }

    /// Allocating variant of [`znorm_into`].
    pub fn znorm(&self, ts: &TimeSeries, k: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.s];
        self.znorm_into(ts, k, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_stats(pts: &[f64], k: usize, s: usize) -> (f64, f64) {
        let w = &pts[k..k + s];
        let m = w.iter().sum::<f64>() / s as f64;
        let v = w.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / s as f64;
        (m, v.sqrt())
    }

    #[test]
    fn matches_naive_computation() {
        let mut rng = crate::util::rng::Rng64::new(3);
        let pts: Vec<f64> = (0..500).map(|_| rng.normal() * 3.0 + 7.0).collect();
        let ts = TimeSeries::new("t", pts.clone());
        let st = SeqStats::compute(&ts, 32);
        assert_eq!(st.len(), 500 - 32 + 1);
        for k in [0, 1, 100, 468] {
            let (m, sd) = naive_stats(&pts, k, 32);
            assert!((st.mean[k] - m).abs() < 1e-9, "mean k={k}");
            assert!((st.std[k] - sd).abs() < 1e-9, "std k={k}");
        }
    }

    #[test]
    fn stable_with_large_offset() {
        // 1e8 offset: naive prefix-of-squares would lose ~16 digits.
        let mut rng = crate::util::rng::Rng64::new(4);
        let pts: Vec<f64> = (0..2000).map(|_| 1.0e8 + rng.normal()).collect();
        let ts = TimeSeries::new("t", pts.clone());
        let st = SeqStats::compute(&ts, 64);
        for k in [0, 999, 1936] {
            let (m, sd) = naive_stats(&pts, k, 64);
            assert!((st.mean[k] - m).abs() / m.abs() < 1e-12);
            assert!(
                (st.std[k] - sd).abs() < 1e-6,
                "k={k}: {} vs naive {}",
                st.std[k],
                sd
            );
        }
    }

    #[test]
    fn constant_window_gets_floor() {
        let ts = TimeSeries::new("t", vec![5.0; 100]);
        let st = SeqStats::compute(&ts, 10);
        assert!(st.std.iter().all(|&sd| sd == SIGMA_FLOOR));
        let z = st.znorm(&ts, 0);
        assert!(z.iter().all(|&v| v == 0.0), "constant -> zero vector");
    }

    #[test]
    fn per_window_stats_are_pure_functions_of_the_window() {
        // the streaming invariant: a window's (μ, σ) must not depend on
        // the series around it, so a sliding-window monitor can extend its
        // stats incrementally and still match a cold recompute bit for bit
        let mut rng = crate::util::rng::Rng64::new(9);
        let pts: Vec<f64> = (0..400).map(|_| rng.normal() * 2.0 + 1.0e6).collect();
        let full = SeqStats::compute(&TimeSeries::new("f", pts.clone()), 32);
        for off in [0usize, 7, 123] {
            let slice = TimeSeries::new("w", pts[off..off + 200].to_vec());
            let sub = SeqStats::compute(&slice, 32);
            for k in 0..sub.len() {
                assert_eq!(full.mean[off + k].to_bits(), sub.mean[k].to_bits());
                assert_eq!(full.std[off + k].to_bits(), sub.std[k].to_bits());
            }
        }
        // window_stats is the shared kernel
        let (m, sd) = window_stats(&pts[5..37]);
        assert_eq!(m.to_bits(), full.mean[5].to_bits());
        assert_eq!(sd.to_bits(), full.std[5].to_bits());
    }

    #[test]
    fn znorm_has_zero_mean_unit_std() {
        let mut rng = crate::util::rng::Rng64::new(5);
        let pts: Vec<f64> = (0..200).map(|_| rng.normal() * 2.0 + 3.0).collect();
        let ts = TimeSeries::new("t", pts);
        let st = SeqStats::compute(&ts, 50);
        let z = st.znorm(&ts, 77);
        let m = z.iter().sum::<f64>() / 50.0;
        let v = z.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 50.0;
        assert!(m.abs() < 1e-10);
        assert!((v - 1.0).abs() < 1e-10);
    }
}
