//! Rolling per-sequence statistics.
//!
//! The paper's memory-saving trick (Sec. 2.1, Eq. 2/3): instead of storing
//! z-normalized copies of every sequence, store each sequence's mean μ_k and
//! standard deviation σ_k and fold the normalization into the distance
//! function. Both are computed for all N sequence starts in O(N) from
//! prefix sums of p and p².
//!
//! Numerical note: naive prefix-sum variance cancels catastrophically for
//! long series with large offsets, so sums are accumulated over points
//! re-centered by the global mean first (a standard stabilization that keeps
//! the O(N) cost).

use super::series::TimeSeries;

/// Per-sequence-start rolling mean and standard deviation for a fixed
/// sequence length `s`.
#[derive(Debug, Clone)]
pub struct SeqStats {
    /// Sequence length the stats were computed for.
    pub s: usize,
    /// mean[k] = μ of points[k..k+s]
    pub mean: Vec<f64>,
    /// std[k] = population σ of points[k..k+s]; floored at `SIGMA_FLOOR`
    /// so constant sequences don't divide by zero.
    pub std: Vec<f64>,
}

/// Lower bound on σ: constant (or numerically-constant) windows get this
/// value so z-normalization maps them to the zero vector instead of NaN.
pub const SIGMA_FLOOR: f64 = 1e-12;

impl SeqStats {
    /// Compute rolling stats for every complete window of length `s`.
    pub fn compute(ts: &TimeSeries, s: usize) -> SeqStats {
        let n = ts.num_sequences(s);
        assert!(s >= 1, "sequence length must be >= 1");
        assert!(n > 0, "series shorter than sequence length");
        let pts = &ts.points;

        // Re-center by the global mean for numerical stability.
        let g_mean = pts.iter().sum::<f64>() / pts.len() as f64;

        let mut prefix = Vec::with_capacity(pts.len() + 1);
        let mut prefix_sq = Vec::with_capacity(pts.len() + 1);
        prefix.push(0.0);
        prefix_sq.push(0.0);
        let mut acc = 0.0;
        let mut acc_sq = 0.0;
        for &p in pts {
            let c = p - g_mean;
            acc += c;
            acc_sq += c * c;
            prefix.push(acc);
            prefix_sq.push(acc_sq);
        }

        let inv_s = 1.0 / s as f64;
        let mut mean = Vec::with_capacity(n);
        let mut std = Vec::with_capacity(n);
        for k in 0..n {
            let sum = prefix[k + s] - prefix[k];
            let sum_sq = prefix_sq[k + s] - prefix_sq[k];
            let m_c = sum * inv_s; // mean of re-centered window
            let var = (sum_sq * inv_s - m_c * m_c).max(0.0);
            mean.push(m_c + g_mean);
            std.push(var.sqrt().max(SIGMA_FLOOR));
        }
        SeqStats { s, mean, std }
    }

    /// Number of sequence starts covered.
    #[inline]
    pub fn len(&self) -> usize {
        self.mean.len()
    }

    /// Whether no sequence start is covered.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }

    /// Z-normalize the sequence starting at `k` into `out` (len `s`).
    pub fn znorm_into(&self, ts: &TimeSeries, k: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.s);
        let mu = self.mean[k];
        let inv_sd = 1.0 / self.std[k];
        for (o, &p) in out.iter_mut().zip(ts.seq(k, self.s)) {
            *o = (p - mu) * inv_sd;
        }
    }

    /// Allocating variant of [`znorm_into`].
    pub fn znorm(&self, ts: &TimeSeries, k: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.s];
        self.znorm_into(ts, k, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_stats(pts: &[f64], k: usize, s: usize) -> (f64, f64) {
        let w = &pts[k..k + s];
        let m = w.iter().sum::<f64>() / s as f64;
        let v = w.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / s as f64;
        (m, v.sqrt())
    }

    #[test]
    fn matches_naive_computation() {
        let mut rng = crate::util::rng::Rng64::new(3);
        let pts: Vec<f64> = (0..500).map(|_| rng.normal() * 3.0 + 7.0).collect();
        let ts = TimeSeries::new("t", pts.clone());
        let st = SeqStats::compute(&ts, 32);
        assert_eq!(st.len(), 500 - 32 + 1);
        for k in [0, 1, 100, 468] {
            let (m, sd) = naive_stats(&pts, k, 32);
            assert!((st.mean[k] - m).abs() < 1e-9, "mean k={k}");
            assert!((st.std[k] - sd).abs() < 1e-9, "std k={k}");
        }
    }

    #[test]
    fn stable_with_large_offset() {
        // 1e8 offset: naive prefix-of-squares would lose ~16 digits.
        let mut rng = crate::util::rng::Rng64::new(4);
        let pts: Vec<f64> = (0..2000).map(|_| 1.0e8 + rng.normal()).collect();
        let ts = TimeSeries::new("t", pts.clone());
        let st = SeqStats::compute(&ts, 64);
        for k in [0, 999, 1936] {
            let (m, sd) = naive_stats(&pts, k, 64);
            assert!((st.mean[k] - m).abs() / m.abs() < 1e-12);
            assert!(
                (st.std[k] - sd).abs() < 1e-6,
                "k={k}: {} vs naive {}",
                st.std[k],
                sd
            );
        }
    }

    #[test]
    fn constant_window_gets_floor() {
        let ts = TimeSeries::new("t", vec![5.0; 100]);
        let st = SeqStats::compute(&ts, 10);
        assert!(st.std.iter().all(|&sd| sd == SIGMA_FLOOR));
        let z = st.znorm(&ts, 0);
        assert!(z.iter().all(|&v| v == 0.0), "constant -> zero vector");
    }

    #[test]
    fn znorm_has_zero_mean_unit_std() {
        let mut rng = crate::util::rng::Rng64::new(5);
        let pts: Vec<f64> = (0..200).map(|_| rng.normal() * 2.0 + 3.0).collect();
        let ts = TimeSeries::new("t", pts);
        let st = SeqStats::compute(&ts, 50);
        let z = st.znorm(&ts, 77);
        let m = z.iter().sum::<f64>() / 50.0;
        let v = z.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 50.0;
        assert!(m.abs() < 1e-10);
        assert!((v - 1.0).abs() < 1e-10);
    }
}
