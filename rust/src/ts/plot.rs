//! ASCII plotting for terminals: series strips, nnd-profile plots with
//! discord markers, and log-x speedup curves. Used by the CLI (`hst plot`)
//! and the examples; keeps the repo dependency-free while still giving
//! the Fig. 2/3/5-style visuals.

use crate::discord::Discord;
use crate::ts::TimeSeries;

/// Downsample `values` into `width` columns (mean per bucket).
fn buckets(values: &[f64], width: usize) -> Vec<f64> {
    assert!(width > 0);
    let n = values.len();
    if n == 0 {
        return Vec::new();
    }
    (0..width.min(n))
        .map(|c| {
            let lo = c * n / width.min(n);
            let hi = ((c + 1) * n / width.min(n)).max(lo + 1);
            values[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
        })
        .collect()
}

/// Render a series as a `height`-row ASCII strip.
pub fn plot_series(ts: &TimeSeries, width: usize, height: usize) -> String {
    plot_values(&ts.points, width, height, &format!("{} ({} pts)", ts.name, ts.n_total()))
}

/// Render any value vector (e.g. an nnd profile).
pub fn plot_values(values: &[f64], width: usize, height: usize, title: &str) -> String {
    let height = height.max(2);
    let cols = buckets(
        &values
            .iter()
            .map(|v| if v.is_finite() { *v } else { 0.0 })
            .collect::<Vec<_>>(),
        width,
    );
    if cols.is_empty() {
        return format!("{title}\n(empty)\n");
    }
    let lo = cols.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = cols.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut grid = vec![vec![' '; cols.len()]; height];
    for (c, v) in cols.iter().enumerate() {
        let r = (((v - lo) / span) * (height - 1) as f64).round() as usize;
        for (row, row_cells) in grid.iter_mut().enumerate() {
            let level = height - 1 - row; // top row = max
            if level == r {
                row_cells[c] = '*';
            } else if level < r {
                row_cells[c] = '.';
            }
        }
    }
    let mut out = format!("{title}  [min {lo:.3}, max {hi:.3}]\n");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(cols.len()));
    out.push('\n');
    out
}

/// Profile plot with `^` markers under discord positions.
pub fn plot_profile_with_discords(
    profile: &[f64],
    discords: &[Discord],
    width: usize,
    height: usize,
) -> String {
    let mut out = plot_values(profile, width, height, "nnd profile");
    let n = profile.len().max(1);
    let w = width.min(n);
    let mut marks = vec![' '; w];
    for d in discords {
        let c = d.position * w / n;
        marks[c.min(w - 1)] = '^';
    }
    out.push(' ');
    out.extend(marks);
    out.push_str("  (^ = discord)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn plot_has_expected_shape() {
        let ts = generators::sine_with_noise(1_000, 0.1, 1).into_series("sine");
        let p = plot_series(&ts, 60, 8);
        let lines: Vec<&str> = p.lines().collect();
        assert_eq!(lines.len(), 1 + 8 + 1); // title + rows + axis
        assert!(lines[0].contains("sine"));
        assert!(lines.iter().any(|l| l.contains('*')));
        assert!(lines.last().unwrap().starts_with('+'));
    }

    #[test]
    fn constant_series_does_not_divide_by_zero() {
        let ts = crate::ts::TimeSeries::new("flat", vec![2.0; 100]);
        let p = plot_series(&ts, 30, 4);
        assert!(p.contains("flat"));
    }

    #[test]
    fn discord_markers_land_in_range() {
        let profile: Vec<f64> = (0..500).map(|i| (i as f64 * 0.1).sin()).collect();
        let ds = vec![
            Discord { position: 0, nnd: 1.0, neighbor: 100 },
            Discord { position: 499, nnd: 0.9, neighbor: 10 },
        ];
        let p = plot_profile_with_discords(&profile, &ds, 50, 6);
        let marker_line = p.lines().last().unwrap();
        assert!(marker_line.contains('^'));
    }

    #[test]
    fn handles_short_input() {
        let p = plot_values(&[1.0, 2.0], 80, 5, "two");
        assert!(p.contains("two"));
        let p = plot_values(&[], 80, 5, "none");
        assert!(p.contains("empty"));
    }
}
