//! Registry of the paper's evaluation datasets.
//!
//! Each entry records the paper's search parameters (sequence length `s`,
//! PAA segments `p`, alphabet size) and length from Tables 1/6, plus the
//! synthetic generator family that substitutes for the original recording
//! (see DESIGN.md "Offline-environment substitutions").
//!
//! `Dataset::generate` materializes the series at full paper length;
//! `generate_scaled(f)` shrinks the length by `f` (keeping it ≥ 4·s) so the
//! whole benchmark suite runs in minutes instead of hours. Every table in
//! EXPERIMENTS.md records which scale was used.

use super::generators as g;
use super::series::TimeSeries;

/// Generator family for a dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Heartbeat trains with rhythm anomalies ([`super::generators::ecg_like`]).
    Ecg,
    /// Breathing oscillation with apnea spells ([`super::generators::respiration_like`]).
    Respiration,
    /// Actuation cycles with glitches ([`super::generators::valve_like`]).
    Valve,
    /// Daily/weekly demand with holiday weeks ([`super::generators::power_like`]).
    Power,
    /// Piecewise activity regimes ([`super::generators::regime_like`]).
    Regime,
    /// Long alternating feeding waveforms ([`super::generators::insect_feeding_like`]).
    Insect,
}

/// A registry entry: the paper's parameters plus our synthetic stand-in.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Paper's dataset name (e.g. "ECG 300").
    pub name: &'static str,
    /// Full length used in the paper.
    pub paper_len: usize,
    /// Sequence (discord) length s.
    pub s: usize,
    /// PAA segments P (must divide s).
    pub p: usize,
    /// SAX alphabet size.
    pub alphabet: usize,
    /// Synthetic family standing in for the recording.
    pub family: Family,
    /// Dominant pattern period fed to the generator.
    pub period: usize,
    /// Number of injected anomalies.
    pub anomalies: usize,
    /// Seed so the series is stable across runs.
    pub seed: u64,
}

impl Dataset {
    /// Materialize at a given length.
    pub fn generate_len(&self, n: usize) -> TimeSeries {
        let pts = match self.family {
            Family::Ecg => g::ecg_like(n, self.period, self.anomalies, self.seed),
            Family::Respiration => {
                g::respiration_like(n, self.period, self.anomalies, self.seed)
            }
            Family::Valve => g::valve_like(n, self.period, self.anomalies, self.seed),
            Family::Power => g::power_like(n, self.period, self.anomalies, self.seed),
            Family::Regime => g::regime_like(n, self.period, self.anomalies, self.seed),
            Family::Insect => g::insect_feeding_like(n, self.anomalies, self.seed),
        };
        TimeSeries::new(self.name, pts)
    }

    /// Materialize at full paper length.
    pub fn generate(&self) -> TimeSeries {
        self.generate_len(self.paper_len)
    }

    /// Materialize at `paper_len / scale_div`, floored at `4·s` points.
    pub fn generate_scaled(&self, scale_div: usize) -> TimeSeries {
        let n = (self.paper_len / scale_div.max(1)).max(4 * self.s);
        self.generate_len(n)
    }

    /// Max number of non-overlapping discords this dataset supports at the
    /// scaled length (paper: at most N/s + 1).
    pub fn max_discords(&self, n: usize) -> usize {
        (n.saturating_sub(self.s) + 1) / self.s + 1
    }
}

/// The 14 datasets of Tables 1/3/6 with the paper's (s, P, alphabet).
pub fn registry() -> Vec<Dataset> {
    vec![
        Dataset { name: "Daily commute", paper_len: 17_175, s: 345, p: 15, alphabet: 4, family: Family::Regime,      period: 690,  anomalies: 2, seed: 101 },
        Dataset { name: "Dutch Power",   paper_len: 35_040, s: 750, p: 6,  alphabet: 3, family: Family::Power,       period: 96,   anomalies: 1, seed: 102 },
        Dataset { name: "ECG 0606",      paper_len: 2_299,  s: 120, p: 4,  alphabet: 4, family: Family::Ecg,         period: 110,  anomalies: 1, seed: 103 },
        Dataset { name: "ECG 308",       paper_len: 5_400,  s: 300, p: 4,  alphabet: 4, family: Family::Ecg,         period: 260,  anomalies: 1, seed: 104 },
        Dataset { name: "ECG 15",        paper_len: 15_000, s: 300, p: 4,  alphabet: 4, family: Family::Ecg,         period: 280,  anomalies: 2, seed: 105 },
        Dataset { name: "ECG 108",       paper_len: 21_600, s: 300, p: 4,  alphabet: 4, family: Family::Ecg,         period: 250,  anomalies: 2, seed: 106 },
        Dataset { name: "ECG 300",       paper_len: 536_976, s: 300, p: 4, alphabet: 4, family: Family::Ecg,         period: 270,  anomalies: 5, seed: 107 },
        Dataset { name: "ECG 318",       paper_len: 586_086, s: 300, p: 4, alphabet: 4, family: Family::Ecg,         period: 290,  anomalies: 5, seed: 108 },
        Dataset { name: "NPRS 43",       paper_len: 4_000,  s: 128, p: 4,  alphabet: 4, family: Family::Respiration, period: 130,  anomalies: 1, seed: 109 },
        Dataset { name: "NPRS 44",       paper_len: 24_125, s: 128, p: 4,  alphabet: 4, family: Family::Respiration, period: 140,  anomalies: 2, seed: 110 },
        Dataset { name: "Video",         paper_len: 11_251, s: 150, p: 5,  alphabet: 3, family: Family::Regime,      period: 450,  anomalies: 2, seed: 111 },
        Dataset { name: "Shuttle TEK 14", paper_len: 5_000, s: 128, p: 4,  alphabet: 4, family: Family::Valve,       period: 250,  anomalies: 1, seed: 112 },
        Dataset { name: "Shuttle TEK 16", paper_len: 5_000, s: 128, p: 4,  alphabet: 4, family: Family::Valve,       period: 200,  anomalies: 1, seed: 113 },
        Dataset { name: "Shuttle TEK 17", paper_len: 5_000, s: 128, p: 4,  alphabet: 4, family: Family::Valve,       period: 230,  anomalies: 1, seed: 114 },
    ]
}

/// Look up a dataset by (case- and punctuation-insensitive) name.
pub fn by_name(name: &str) -> Option<Dataset> {
    let norm = |s: &str| {
        s.chars()
            .filter(|c| c.is_ascii_alphanumeric())
            .map(|c| c.to_ascii_lowercase())
            .collect::<String>()
    };
    let want = norm(name);
    registry().into_iter().find(|d| norm(d.name) == want)
}

/// The long-series case study of Sec. 4.6 (scaled stand-in).
pub fn insect_dataset() -> Dataset {
    Dataset {
        name: "Insect EPG (Sec 4.6)",
        paper_len: 170_326_411,
        s: 512,
        p: 128,
        alphabet: 4,
        family: Family::Insect,
        period: 160,
        anomalies: 10,
        seed: 115,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_params_are_consistent() {
        for d in registry() {
            assert_eq!(d.s % d.p, 0, "{}: P must divide s", d.name);
            assert!(d.alphabet >= 2 && d.alphabet <= 20, "{}", d.name);
            assert!(d.paper_len > 4 * d.s, "{}", d.name);
        }
    }

    #[test]
    fn fourteen_datasets_like_the_paper() {
        assert_eq!(registry().len(), 14);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("ECG 300").is_some());
        assert!(by_name("ecg300").is_some());
        assert!(by_name("shuttle-tek-14").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn generate_scaled_respects_floor() {
        let d = by_name("ECG 0606").unwrap();
        let ts = d.generate_scaled(1000);
        assert!(ts.n_total() >= 4 * d.s);
        let full = d.generate_scaled(1);
        assert_eq!(full.n_total(), d.paper_len);
    }

    #[test]
    fn generation_is_deterministic() {
        let d = by_name("NPRS 43").unwrap();
        assert_eq!(d.generate().points, d.generate().points);
    }

    #[test]
    fn max_discords_bound() {
        let d = by_name("Shuttle TEK 14").unwrap();
        // paper: at most N/s + 1 discords
        let n = 5_000;
        assert!(d.max_discords(n) >= 10, "suite uses 10 discords");
    }
}
