//! Loading and saving time series as plain text (one value per line, the
//! format used by the paper's dataset suite / Grammarviz) or CSV columns,
//! plus the delimited multi-column loader behind the multivariate
//! ([`MultiSeries`]) workload.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::multi::MultiSeries;
use super::series::TimeSeries;

/// Load a series from a text file: one f64 per line; blank lines and lines
/// starting with `#` are skipped. For CSV/TSV rows, `column` selects the
/// field (split on `,`, `;`, tab, or whitespace).
pub fn load_text(path: &Path, column: usize) -> Result<TimeSeries> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "series".to_string());
    let mut points = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let fields = split_row(trimmed);
        let Some(field) = fields.get(column) else {
            bail!(
                "{}:{}: no column {} in {:?}",
                path.display(),
                lineno + 1,
                column,
                trimmed
            );
        };
        let v: f64 = field.parse().with_context(|| {
            format!("{}:{}: bad number {:?}", path.display(), lineno + 1, field)
        })?;
        points.push(v);
    }
    if points.is_empty() {
        bail!("{}: no data points", path.display());
    }
    Ok(TimeSeries::new(name, points))
}

/// Split one delimited row into fields (`,`, `;`, tab, or whitespace —
/// the same delimiters [`load_text`] accepts).
fn split_row(line: &str) -> Vec<&str> {
    line.split(|c: char| c == ',' || c == ';' || c.is_whitespace())
        .filter(|t| !t.is_empty())
        .collect()
}

/// Load every column of a delimited file as one [`MultiSeries`] channel.
///
/// Format: one row per time step; fields split on `,`, `;`, tab, or
/// whitespace; blank lines and `#` comments skipped. When the first
/// non-comment row has any non-numeric field it is taken as the header
/// naming the channels; otherwise channels are named `c0`, `c1`, ….
///
/// Errors follow the strict named-field conventions of
/// [`JobSpec::series`](crate::service::JobSpec::series): a ragged row is
/// rejected with its line number and both column counts, a non-numeric
/// cell with its line number and the *channel name* of its column — a
/// malformed file must fail the load, never silently shift columns.
pub fn load_multi_csv(path: &Path) -> Result<MultiSeries> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "multi".to_string());
    let mut names: Option<Vec<String>> = None;
    let mut columns: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let fields = split_row(trimmed);
        if names.is_none() {
            // first data row: a header if any cell is non-numeric
            if fields.iter().any(|f| f.parse::<f64>().is_err()) {
                names = Some(fields.iter().map(|f| f.to_string()).collect());
                continue;
            }
            names = Some((0..fields.len()).map(|i| format!("c{i}")).collect());
        }
        let header = names.as_ref().unwrap();
        if fields.len() != header.len() {
            bail!(
                "{}:{}: ragged row: {} columns, expected {} ({})",
                path.display(),
                lineno + 1,
                fields.len(),
                header.len(),
                header.join(", ")
            );
        }
        if columns.is_empty() {
            columns = vec![Vec::new(); header.len()];
        }
        for (c, field) in fields.iter().enumerate() {
            let v: f64 = field.parse().with_context(|| {
                format!(
                    "{}:{}: column `{}`: bad number {:?}",
                    path.display(),
                    lineno + 1,
                    header[c],
                    field
                )
            })?;
            columns[c].push(v);
        }
    }
    if columns.is_empty() || columns[0].is_empty() {
        bail!("{}: no data rows", path.display());
    }
    let header = names.unwrap();
    let channels = header
        .into_iter()
        .zip(columns)
        .map(|(n, pts)| TimeSeries::new(n, pts))
        .collect();
    MultiSeries::new(name, channels)
}

/// Save a series as one value per line (round-trips with [`load_text`]).
pub fn save_text(ts: &TimeSeries, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    writeln!(f, "# {}", ts.name)?;
    for p in &ts.points {
        writeln!(f, "{p}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hstime_io_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn roundtrip() {
        let ts = TimeSeries::new("rt", vec![1.0, -2.5, 3.25e-3]);
        let path = tmp("roundtrip.txt");
        save_text(&ts, &path).unwrap();
        let back = load_text(&path, 0).unwrap();
        assert_eq!(back.points, ts.points);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_column_selection() {
        let path = tmp("cols.csv");
        std::fs::write(&path, "1,10\n2,20\n# comment\n3,30\n").unwrap();
        let c0 = load_text(&path, 0).unwrap();
        let c1 = load_text(&path, 1).unwrap();
        assert_eq!(c0.points, vec![1.0, 2.0, 3.0]);
        assert_eq!(c1.points, vec![10.0, 20.0, 30.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_column_is_error() {
        let path = tmp("missing.csv");
        std::fs::write(&path, "1\n").unwrap();
        assert!(load_text(&path, 3).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_file_is_error() {
        let path = tmp("empty.txt");
        std::fs::write(&path, "# only comments\n\n").unwrap();
        assert!(load_text(&path, 0).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn multi_csv_with_header_names_the_channels() {
        let path = tmp("multi_header.csv");
        std::fs::write(
            &path,
            "# a comment\ntemp,pressure,flow\n1,10,100\n2,20,200\n3,30,300\n",
        )
        .unwrap();
        let ms = load_multi_csv(&path).unwrap();
        assert_eq!(ms.dims(), 3);
        assert_eq!(ms.n_total(), 3);
        assert_eq!(ms.channel_names(), vec!["temp", "pressure", "flow"]);
        assert_eq!(ms.channel(1).points, vec![10.0, 20.0, 30.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn multi_csv_without_header_autonames_columns() {
        let path = tmp("multi_noheader.tsv");
        std::fs::write(&path, "1\t10\n2\t20\n").unwrap();
        let ms = load_multi_csv(&path).unwrap();
        assert_eq!(ms.channel_names(), vec!["c0", "c1"]);
        assert_eq!(ms.channel(0).points, vec![1.0, 2.0]);
        assert_eq!(ms.channel(1).points, vec![10.0, 20.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn multi_csv_ragged_row_is_a_named_error() {
        let path = tmp("multi_ragged.csv");
        std::fs::write(&path, "a,b\n1,2\n3\n").unwrap();
        let err = format!("{:#}", load_multi_csv(&path).unwrap_err());
        assert!(err.contains("ragged row"), "{err}");
        assert!(err.contains(":3:"), "line number named: {err}");
        assert!(err.contains("1 columns, expected 2"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn multi_csv_non_numeric_cell_names_the_channel() {
        let path = tmp("multi_badcell.csv");
        std::fs::write(&path, "a,b\n1,2\n3,oops\n").unwrap();
        let err = format!("{:#}", load_multi_csv(&path).unwrap_err());
        assert!(err.contains("column `b`"), "{err}");
        assert!(err.contains("\"oops\""), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn multi_csv_header_only_or_empty_is_error() {
        let path = tmp("multi_empty.csv");
        std::fs::write(&path, "a,b\n# nothing\n").unwrap();
        let err = format!("{:#}", load_multi_csv(&path).unwrap_err());
        assert!(err.contains("no data rows"), "{err}");
        std::fs::write(&path, "").unwrap();
        assert!(load_multi_csv(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
