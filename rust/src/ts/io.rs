//! Loading and saving time series as plain text (one value per line, the
//! format used by the paper's dataset suite / Grammarviz) or CSV columns.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::series::TimeSeries;

/// Load a series from a text file: one f64 per line; blank lines and lines
/// starting with `#` are skipped. For CSV/TSV rows, `column` selects the
/// field (split on `,`, `;`, tab, or whitespace).
pub fn load_text(path: &Path, column: usize) -> Result<TimeSeries> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "series".to_string());
    let mut points = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = trimmed
            .split(|c: char| c == ',' || c == ';' || c.is_whitespace())
            .filter(|t| !t.is_empty())
            .collect();
        let Some(field) = fields.get(column) else {
            bail!(
                "{}:{}: no column {} in {:?}",
                path.display(),
                lineno + 1,
                column,
                trimmed
            );
        };
        let v: f64 = field.parse().with_context(|| {
            format!("{}:{}: bad number {:?}", path.display(), lineno + 1, field)
        })?;
        points.push(v);
    }
    if points.is_empty() {
        bail!("{}: no data points", path.display());
    }
    Ok(TimeSeries::new(name, points))
}

/// Save a series as one value per line (round-trips with [`load_text`]).
pub fn save_text(ts: &TimeSeries, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    writeln!(f, "# {}", ts.name)?;
    for p in &ts.points {
        writeln!(f, "{p}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hstime_io_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn roundtrip() {
        let ts = TimeSeries::new("rt", vec![1.0, -2.5, 3.25e-3]);
        let path = tmp("roundtrip.txt");
        save_text(&ts, &path).unwrap();
        let back = load_text(&path, 0).unwrap();
        assert_eq!(back.points, ts.points);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_column_selection() {
        let path = tmp("cols.csv");
        std::fs::write(&path, "1,10\n2,20\n# comment\n3,30\n").unwrap();
        let c0 = load_text(&path, 0).unwrap();
        let c1 = load_text(&path, 1).unwrap();
        assert_eq!(c0.points, vec![1.0, 2.0, 3.0]);
        assert_eq!(c1.points, vec![10.0, 20.0, 30.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_column_is_error() {
        let path = tmp("missing.csv");
        std::fs::write(&path, "1\n").unwrap();
        assert!(load_text(&path, 3).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_file_is_error() {
        let path = tmp("empty.txt");
        std::fs::write(&path, "# only comments\n\n").unwrap();
        assert!(load_text(&path, 0).is_err());
        std::fs::remove_file(path).ok();
    }
}
