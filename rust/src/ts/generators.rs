//! Synthetic time-series generators.
//!
//! The paper validates on (a) a heterogeneous suite of real recordings
//! (ECG, respiration, shuttle valve, power demand, commute, video) and (b)
//! a controlled synthetic family (Eq. 7: rescaled sine + uniform noise).
//! The real recordings are not redistributable/offline, so each dataset
//! *family* gets a generator that reproduces the structural properties that
//! drive discord-search complexity: quasi-periodicity, the number of
//! distinct repeated patterns, the noise/signal ratio, and a small number
//! of injected anomalies (the discords to be found). See DESIGN.md
//! ("Offline-environment substitutions").
//!
//! All generators are deterministic functions of their seed.

use crate::util::rng::Rng64;

/// Paper Eq. 7: `p_i = (sin(0.1 i) + E ε + 1) / 2.5`, ε ~ U(0,1).
///
/// `e` is the noise amplitude studied in Table 4 / Fig. 5. One anomaly is
/// *implicit*: with pure low-noise sine every sequence repeats, so the
/// discord is whichever window the noise makes rarest — exactly the
/// "easy-looking but hard to search" regime the paper analyses.
pub fn sine_with_noise(n: usize, e: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng64::new(seed);
    (0..n)
        .map(|i| ((0.1 * i as f64).sin() + e * rng.f64() + 1.0) / 2.5)
        .collect()
}

/// Kinds of injected anomalies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Anomaly {
    /// Flatten a window to its mean (sensor dropout / apnea).
    Flatline,
    /// Add a transient bump (ectopic beat, valve glitch).
    Bump,
    /// Locally stretch time (rhythm disturbance).
    Stretch,
    /// Invert the window around its mean.
    Invert,
}

/// Inject `kind` into `pts[pos..pos+len]` (clamped to bounds).
pub fn inject(pts: &mut [f64], pos: usize, len: usize, kind: Anomaly, rng: &mut Rng64) {
    let end = (pos + len).min(pts.len());
    if pos >= end {
        return;
    }
    let w = end - pos;
    let mean = pts[pos..end].iter().sum::<f64>() / w as f64;
    match kind {
        Anomaly::Flatline => {
            for p in &mut pts[pos..end] {
                *p = mean + 0.002 * rng.normal();
            }
        }
        Anomaly::Bump => {
            let amp = (pts[pos..end]
                .iter()
                .map(|p| (p - mean).abs())
                .fold(0.0, f64::max))
            .max(0.1)
                * 1.6;
            for (i, p) in pts[pos..end].iter_mut().enumerate() {
                let t = (i as f64 / w as f64 - 0.5) * 6.0;
                *p += amp * (-t * t).exp();
            }
        }
        Anomaly::Stretch => {
            let src: Vec<f64> = pts[pos..end].to_vec();
            for (i, p) in pts[pos..end].iter_mut().enumerate() {
                // resample at 0.5x speed from the window start
                let j = (i as f64 * 0.5) as usize;
                *p = src[j.min(w - 1)];
            }
        }
        Anomaly::Invert => {
            for p in &mut pts[pos..end] {
                *p = 2.0 * mean - *p;
            }
        }
    }
}

/// One synthetic "heartbeat" of unit period: P, QRS complex, T bumps.
fn heartbeat(phase: f64) -> f64 {
    let bump = |c: f64, w: f64, a: f64| {
        let d = (phase - c) / w;
        a * (-d * d).exp()
    };
    bump(0.18, 0.045, 0.12)        // P
        + bump(0.38, 0.016, -0.18) // Q
        + bump(0.41, 0.018, 1.0)   // R
        + bump(0.45, 0.018, -0.25) // S
        + bump(0.68, 0.07, 0.28)   // T
}

/// ECG-like series: beat train with period jitter, baseline wander, noise,
/// and `n_anomalies` injected rhythm disturbances.
pub fn ecg_like(n: usize, beat_len: usize, n_anomalies: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng64::new(seed);
    let mut pts = Vec::with_capacity(n);
    let mut t_in_beat = 0.0f64;
    let mut period = beat_len as f64;
    for i in 0..n {
        let wander = 0.05 * (2.0 * std::f64::consts::PI * i as f64 / 1500.0).sin();
        pts.push(heartbeat(t_in_beat / period) + wander + 0.015 * rng.normal());
        t_in_beat += 1.0;
        if t_in_beat >= period {
            t_in_beat = 0.0;
            period = beat_len as f64 * (1.0 + 0.04 * rng.normal());
        }
    }
    for a in 0..n_anomalies {
        let pos = placed(n, beat_len, a, n_anomalies, &mut rng);
        let kind = match a % 3 {
            0 => Anomaly::Bump,
            1 => Anomaly::Stretch,
            _ => Anomaly::Invert,
        };
        inject(&mut pts, pos, beat_len, kind, &mut rng);
    }
    pts
}

/// Respiration-like series (NPRS family): slow oscillation with amplitude
/// modulation, drift, breath-by-breath period variation; anomalies are
/// apnea-like flat spells.
pub fn respiration_like(n: usize, breath_len: usize, n_anomalies: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng64::new(seed);
    let mut pts = Vec::with_capacity(n);
    let mut phase = 0.0f64;
    let mut period = breath_len as f64;
    let mut amp = 1.0;
    for i in 0..n {
        let drift = 0.2 * (2.0 * std::f64::consts::PI * i as f64 / 4000.0).sin();
        pts.push(amp * (2.0 * std::f64::consts::PI * phase).sin() + drift + 0.05 * rng.normal());
        phase += 1.0 / period;
        if phase >= 1.0 {
            phase -= 1.0;
            period = breath_len as f64 * (1.0 + 0.10 * rng.normal()).max(0.5);
            amp = (amp + 0.08 * rng.normal()).clamp(0.6, 1.4);
        }
    }
    for a in 0..n_anomalies {
        let pos = placed(n, breath_len * 2, a, n_anomalies, &mut rng);
        inject(&mut pts, pos, breath_len, Anomaly::Flatline, &mut rng);
    }
    pts
}

/// Shuttle-valve-like series (TEK family): repeating actuation cycles —
/// sharp rise, ringing decay, quiet tail. "Easy looking" (few, very similar
/// patterns) which is exactly the high-cps regime of Table 3. Anomalies are
/// one-off glitches inside a cycle.
pub fn valve_like(n: usize, cycle_len: usize, n_anomalies: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng64::new(seed);
    let mut pts = Vec::with_capacity(n);
    for i in 0..n {
        let ph = (i % cycle_len) as f64 / cycle_len as f64;
        let v = if ph < 0.08 {
            ph / 0.08 // sharp ramp
        } else if ph < 0.5 {
            // ringing decay
            let t = (ph - 0.08) / 0.42;
            (1.0 - t) * (2.0 * std::f64::consts::PI * 6.0 * t).cos() * 0.8 + 0.1
        } else {
            0.05
        };
        pts.push(v + 0.01 * rng.normal());
    }
    for a in 0..n_anomalies {
        let pos = placed(n, cycle_len, a, n_anomalies, &mut rng);
        inject(&mut pts, pos, cycle_len / 2, Anomaly::Bump, &mut rng);
    }
    pts
}

/// Power-demand-like series (Dutch Power family): daily cycle × weekly
/// structure (5 work days, 2 low days); the anomaly is a "holiday week"
/// where workday demand stays low — the classic discord in this dataset.
pub fn power_like(n: usize, day_len: usize, n_anomalies: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng64::new(seed);
    let week = day_len * 7;
    let mut pts = Vec::with_capacity(n);
    for i in 0..n {
        let day = (i / day_len) % 7;
        let ph = (i % day_len) as f64 / day_len as f64;
        let workday = day < 5;
        let daily = if workday {
            // morning + evening peaks
            let m = (-((ph - 0.35) / 0.1).powi(2)).exp();
            let e = 0.7 * (-((ph - 0.8) / 0.12).powi(2)).exp();
            0.3 + m + e
        } else {
            0.3 + 0.25 * (-((ph - 0.5) / 0.25).powi(2)).exp()
        };
        pts.push(daily + 0.03 * rng.normal());
    }
    // holiday weeks: suppress workday peaks
    for a in 0..n_anomalies {
        let wk = placed(n.saturating_sub(week), week, a, n_anomalies, &mut rng) / week;
        let start = wk * week;
        for i in start..(start + day_len * 5).min(n) {
            let ph = (i % day_len) as f64 / day_len as f64;
            pts[i] = 0.3 + 0.25 * (-((ph - 0.5) / 0.25).powi(2)).exp() + 0.03 * rng.normal();
        }
    }
    pts
}

/// Commute/gesture-like series (Daily commute / Video families):
/// piecewise regimes — segments of distinct quasi-periodic activity with
/// random-walk transitions; anomalies are rare one-off movements.
pub fn regime_like(n: usize, seg_len: usize, n_anomalies: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng64::new(seed);
    let mut pts = Vec::with_capacity(n);
    let mut level = 0.0f64;
    let mut freq = 0.05;
    let mut amp = 0.5;
    for i in 0..n {
        if i % seg_len == 0 {
            level += 0.3 * rng.normal();
            freq = rng.range_f64(0.02, 0.15);
            amp = rng.range_f64(0.2, 0.8);
        }
        pts.push(level + amp * (freq * i as f64).sin() + 0.05 * rng.normal());
    }
    for a in 0..n_anomalies {
        let pos = placed(n, seg_len, a, n_anomalies, &mut rng);
        inject(&mut pts, pos, seg_len / 2, Anomaly::Invert, &mut rng);
    }
    pts
}

/// Insect-feeding-like series (the 1.7e8-point EPG recording of Sec. 4.6):
/// long alternating regimes of distinct waveform families.
pub fn insect_feeding_like(n: usize, n_anomalies: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng64::new(seed);
    let mut pts = Vec::with_capacity(n);
    let mut regime = 0usize;
    let mut until = 0usize;
    for i in 0..n {
        if i >= until {
            regime = rng.below(3);
            until = i + rng.range(2_000, 12_000);
        }
        let t = i as f64;
        let v = match regime {
            0 => 0.6 * (0.08 * t).sin() + 0.2 * (0.31 * t).sin(), // probing
            1 => {
                // ingestion: sawtooth-ish
                let ph = (i % 160) as f64 / 160.0;
                ph * 0.9 - 0.45
            }
            _ => 0.1 * (0.02 * t).sin(), // rest
        };
        pts.push(v + 0.04 * rng.normal());
    }
    for a in 0..n_anomalies {
        let pos = placed(n, 1024, a, n_anomalies, &mut rng);
        inject(&mut pts, pos, 512, Anomaly::Bump, &mut rng);
    }
    pts
}

/// Ground truth for [`correlated_channels`]: the `(start, len)` span of
/// the injected *joint* anomaly, a deterministic function of the series
/// length and the anomaly length (so tests, benches, and the demo need no
/// side channel to know where it is).
pub fn correlated_anomaly_span(n: usize, len: usize) -> (usize, usize) {
    let start = (5 * n / 8).min(n.saturating_sub(2 * len));
    (start, len)
}

/// A smooth Gaussian bump in [0, 1] supported on `[start, start + len)`;
/// zero outside. The modulation window both anomaly kinds of
/// [`correlated_channels`] use — smooth, so the anomaly has no
/// edge discontinuity a univariate search would trivially flag.
fn phase_bump(i: usize, start: usize, len: usize) -> f64 {
    if i < start || i >= start + len || len == 0 {
        return 0.0;
    }
    let u = (i - start) as f64 / len as f64;
    let x = (u - 0.5) * 6.0;
    (-x * x).exp()
}

/// Synthetic multivariate series for the mdim workload: a **shared**
/// slow random walk plus a common quasi-periodic carrier, per-channel
/// amplitude and per-channel noise, and two kinds of injected anomaly:
///
/// * one **joint** anomaly at [`correlated_anomaly_span`]`(n, len)` — a
///   *moderate* smooth phase wobble (+0.7 rad peak) applied to **every**
///   channel at the same time span;
/// * one **decoy** per channel — a *stronger* wobble (−1.4 rad peak,
///   opposite direction so decoy and joint windows cannot match each
///   other) at a channel-specific position in the first half.
///
/// Per channel, the decoy is the clear top univariate discord (its
/// deviation is twice the joint one's, and phase-wobble distance grows
/// sublinearly, so the decoy strictly dominates), which is exactly what
/// makes the joint anomaly invisible to any single-channel search. The
/// k-of-d aggregate (sum of per-channel distances) sees it immediately:
/// at the joint span all `d` channels deviate *simultaneously*
/// (aggregate ≈ d · moderate), while at any decoy only one channel does
/// (aggregate ≈ strong ≤ 2 · moderate). With d ≥ 3 the joint anomaly is
/// the aggregate's top discord by construction.
///
/// `len` is the anomaly length (use the search's sequence length `s`).
/// Deterministic per seed; channels are named `c0`, `c1`, ….
pub fn correlated_channels(
    n: usize,
    channels: usize,
    len: usize,
    seed: u64,
) -> super::multi::MultiSeries {
    use super::multi::MultiSeries;
    use super::series::TimeSeries;

    let channels = channels.max(1);
    let mut rng = Rng64::new(seed);
    // shared background: a slow random walk every channel carries
    let mut walk = Vec::with_capacity(n);
    let mut v = 0.0f64;
    for _ in 0..n {
        v += 0.01 * rng.normal();
        walk.push(v);
    }
    let (q, alen) = correlated_anomaly_span(n, len);
    let period = len.max(8) as f64;
    let mut chans = Vec::with_capacity(channels);
    for c in 0..channels {
        let mut crng = rng.split(); // per-channel noise stream
        // channel-specific decoy position, spread over the first half
        let p_c = (n / 8 + c * n / (4 * channels)).min(n.saturating_sub(2 * alen));
        let amp = 0.9 + 0.2 * c as f64 / channels as f64;
        let pts: Vec<f64> = (0..n)
            .map(|i| {
                let mut phase =
                    2.0 * std::f64::consts::PI * i as f64 / period;
                phase += 0.7 * phase_bump(i, q, alen); // joint, every channel
                phase -= 1.4 * phase_bump(i, p_c, alen); // decoy, this channel
                walk[i] + amp * phase.sin() + 0.03 * crng.normal()
            })
            .collect();
        chans.push(TimeSeries::new(format!("c{c}"), pts));
    }
    MultiSeries::new(format!("correlated({channels}x{n})"), chans)
        .expect("generator emits equal-length, uniquely named channels")
}

/// Pure random walk (high-noise control).
pub fn random_walk(n: usize, step: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng64::new(seed);
    let mut v = 0.0;
    (0..n)
        .map(|_| {
            v += step * rng.normal();
            v
        })
        .collect()
}

/// Spread anomaly `a` of `total` across the series, jittered, keeping a
/// margin of `unit` at both ends so sequences containing the anomaly are
/// complete.
fn placed(n: usize, unit: usize, a: usize, total: usize, rng: &mut Rng64) -> usize {
    if n <= 4 * unit {
        return n / 2;
    }
    let span = n - 2 * unit;
    let base = unit + span * (a + 1) / (total + 1);
    let jitter = rng.range(0, unit.max(1));
    (base + jitter).min(n - 2 * unit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(sine_with_noise(100, 0.1, 7), sine_with_noise(100, 0.1, 7));
        assert_ne!(sine_with_noise(100, 0.1, 7), sine_with_noise(100, 0.1, 8));
        assert_eq!(
            ecg_like(1000, 120, 2, 3),
            ecg_like(1000, 120, 2, 3)
        );
    }

    #[test]
    fn eq7_range() {
        // For E <= 1: p in [(sin-1+0)/2.5, (sin+1+E)/2.5] ⊂ [0, 1.2]
        let pts = sine_with_noise(10_000, 1.0, 1);
        assert!(pts.iter().all(|&p| (0.0..=1.2).contains(&p)));
        let lo = sine_with_noise(10_000, 0.0001, 1);
        // almost pure sine: amplitude ~ (1±1)/2.5
        let (mn, mx) = (
            lo.iter().cloned().fold(f64::INFINITY, f64::min),
            lo.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        );
        assert!(mn >= -0.01 && mn <= 0.05, "min {mn}");
        assert!((0.79..=0.85).contains(&mx), "max {mx}");
    }

    #[test]
    fn lengths_match() {
        for n in [10, 1000, 4321] {
            assert_eq!(sine_with_noise(n, 0.1, 0).len(), n);
            assert_eq!(ecg_like(n, 100, 1, 0).len(), n);
            assert_eq!(respiration_like(n, 100, 1, 0).len(), n);
            assert_eq!(valve_like(n, 100, 1, 0).len(), n);
            assert_eq!(power_like(n, 96, 1, 0).len(), n);
            assert_eq!(regime_like(n, 200, 1, 0).len(), n);
            assert_eq!(insect_feeding_like(n, 1, 0).len(), n);
            assert_eq!(random_walk(n, 1.0, 0).len(), n);
        }
    }

    #[test]
    fn injection_changes_window_only() {
        let mut rng = Rng64::new(0);
        let base = ecg_like(2000, 120, 0, 5);
        let mut modified = base.clone();
        inject(&mut modified, 800, 120, Anomaly::Bump, &mut rng);
        assert_eq!(&modified[..800], &base[..800]);
        assert_eq!(&modified[920..], &base[920..]);
        assert!(modified[800..920]
            .iter()
            .zip(&base[800..920])
            .any(|(a, b)| (a - b).abs() > 0.05));
    }

    #[test]
    fn flatline_flattens() {
        let mut rng = Rng64::new(1);
        let mut pts = respiration_like(3000, 150, 0, 2);
        inject(&mut pts, 1000, 150, Anomaly::Flatline, &mut rng);
        let w = &pts[1000..1150];
        let m = w.iter().sum::<f64>() / w.len() as f64;
        let dev = w.iter().map(|p| (p - m).abs()).fold(0.0, f64::max);
        assert!(dev < 0.02, "flatline dev {dev}");
    }

    #[test]
    fn valve_cycles_repeat() {
        let pts = valve_like(5000, 250, 0, 9);
        // windows one cycle apart should be near-identical (low noise)
        let a = &pts[500..750];
        let b = &pts[750..1000];
        let d: f64 = a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt();
        assert!(d < 0.5, "cycle distance {d}");
    }

    #[test]
    fn correlated_channels_is_deterministic_and_shaped() {
        let a = correlated_channels(2_000, 3, 100, 7);
        let b = correlated_channels(2_000, 3, 100, 7);
        assert_eq!(a, b, "deterministic per seed");
        let c = correlated_channels(2_000, 3, 100, 8);
        assert_ne!(a, c, "seed changes the data");
        assert_eq!(a.dims(), 3);
        assert_eq!(a.n_total(), 2_000);
        assert_eq!(a.channel_names(), vec!["c0", "c1", "c2"]);
        // zero channels clamps to one; tiny n stays in bounds
        assert_eq!(correlated_channels(400, 0, 50, 1).dims(), 1);
    }

    #[test]
    fn correlated_channels_share_background_but_differ_in_noise() {
        let ms = correlated_channels(3_000, 2, 100, 3);
        let x = &ms.channel(0).points;
        let y = &ms.channel(1).points;
        // channels correlate strongly (shared walk + carrier) …
        let mx = x.iter().sum::<f64>() / x.len() as f64;
        let my = y.iter().sum::<f64>() / y.len() as f64;
        let mut cov = 0.0;
        let mut vx = 0.0;
        let mut vy = 0.0;
        for (a, b) in x.iter().zip(y) {
            cov += (a - mx) * (b - my);
            vx += (a - mx) * (a - mx);
            vy += (b - my) * (b - my);
        }
        let corr = cov / (vx.sqrt() * vy.sqrt());
        assert!(corr > 0.8, "channels should be correlated, corr={corr}");
        // … but are not identical (per-channel noise stream)
        assert!(x.iter().zip(y).any(|(a, b)| (a - b).abs() > 0.01));
    }

    #[test]
    fn correlated_anomaly_span_is_deterministic_and_in_bounds() {
        let (q, l) = correlated_anomaly_span(4_000, 120);
        assert_eq!((q, l), (2_500, 120));
        assert!(q + 2 * l <= 4_000);
        // the joint wobble actually lands there: after removing the
        // window means (the shared walk's offset), the anomaly window
        // differs from the same-phase window one period earlier by far
        // more than noise alone explains
        let ms = correlated_channels(4_000, 2, 120, 5);
        let ch = &ms.channel(0).points;
        let period = 120;
        let centered_diff = |a: usize, b: usize| -> f64 {
            let ma = ch[a..a + 120].iter().sum::<f64>() / 120.0;
            let mb = ch[b..b + 120].iter().sum::<f64>() / 120.0;
            (0..120)
                .map(|t| ((ch[a + t] - ma) - (ch[b + t] - mb)).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let dev = centered_diff(q, q - period);
        let base = centered_diff(1_000, 1_000 - period);
        assert!(
            dev > 2.0 * base,
            "wobble must deform the carrier: {dev} vs {base}"
        );
    }

    #[test]
    fn power_has_weekly_structure() {
        let day = 96;
        let pts = power_like(day * 7 * 4, day, 0, 3);
        // workday mean exceeds weekend mean
        let mut work = 0.0;
        let mut wend = 0.0;
        for (i, p) in pts.iter().enumerate() {
            if (i / day) % 7 < 5 {
                work += p;
            } else {
                wend += p;
            }
        }
        assert!(work / (5.0 * 4.0) > wend / (2.0 * 4.0));
    }
}
