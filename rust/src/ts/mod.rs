//! Time-series substrate: containers (univariate and multivariate),
//! rolling statistics, I/O, synthetic generators, and the paper-dataset
//! registry.

pub mod datasets;
pub mod generators;
pub mod io;
pub mod multi;
pub mod plot;
pub mod series;
pub mod stats;

pub use multi::MultiSeries;
pub use series::TimeSeries;
pub use stats::{window_stats, SeqStats};
