//! `hst` — the command-line launcher for the hstime framework.
//!
//! Subcommands:
//!   discover <dataset>   run a discord search and print the result
//!   table <id|all>       regenerate a paper table/figure (see DESIGN.md)
//!   bench                sweep all engines, emit a BENCH_*.json trajectory
//!   generate <dataset>   write a synthetic dataset to a text file
//!   serve                start the batch-search TCP service
//!   submit               submit a job to a running service and wait
//!   info                 registry, artifact, and build information
//!
//! Common flags: --scale-div N (dataset length divisor, default 8),
//! --full (paper scale), --runs N, --seed N, --json, --algo NAME,
//! --threads N (parallel engines; 0 = HST_THREADS env, then all cores).

use anyhow::{bail, Context, Result};

use hstime::algo::{self, Algorithm as _};
use hstime::config::SearchParams;
use hstime::service;
use hstime::tables::{self, BenchConfig};
use hstime::ts::{datasets, io as ts_io};
use hstime::util::cli::Args;
use hstime::util::json::Json;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("discover") => discover(args),
        Some("table") => table(args),
        Some("bench") => bench(args),
        Some("report") => report(args),
        Some("plot") => plot(args),
        Some("merlin") => merlin(args),
        Some("vl") => vl(args),
        Some("monitor") => monitor(args),
        Some("stream") => stream(args),
        Some("mdim") => mdim(args),
        Some("generate") => generate(args),
        Some("serve") => serve(args),
        Some("submit") => submit(args),
        Some("snapshot") => snapshot(args),
        Some("trace") => trace(args),
        Some("info") => info(args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage: hst <discover|table|bench|report|plot|merlin|vl|monitor|stream|mdim|generate|serve|submit|snapshot|trace|info> [flags]
  hst discover 'ECG 108' --algo hst --k 3 --scale-div 8
  hst discover 'ECG 108' --algo hst-par --threads 4
  hst discover 'ECG 108' --trace run.jsonl   (write an hst-trace/1 JSONL span trace)
  hst discover synthetic --noise 0.001 --n 20000 --s 120
  hst table all --scale-div 8 --runs 3
  hst table 4 --full
  hst table parallel --threads 4
  hst bench --json BENCH_6.json            (all engines x registry fixtures)
  hst bench --quick --json smoke.json      (CI tier: 3 small fixtures, 1 run)
  hst bench --check BENCH_6.json           (schema-validate a trajectory file)
  hst bench --diff OLD.json NEW.json       (per-cell calls/wall-clock ratios)
  hst bench --kernel scalar                (pin the distance kernel; default HST_KERNEL/simd)
  hst report --out report.md --scale-div 8
  hst plot 'Shuttle TEK 14' --k 2
  hst merlin 'ECG 108' --min-len 80 --max-len 120 --step 8
  hst vl 'ECG 108' --min-len 80 --max-len 120 --step 8    (work-sharing hst-vl scan)
  hst monitor 'ECG 15' --window 4000 --batch 1000
  hst stream 'ECG 15' --window 4000 --refresh-every 500   (incremental hst-stream)
  hst stream --file points.txt --s 64    (or pipe points, one per line, on stdin)
  hst stream 'ECG 15' --addr 127.0.0.1:7878 --frame-points 512  (binary frames to a server)
  hst mdim --channels c0,c2 --s 96 --algo hst-md          (multivariate k-of-d search)
  hst mdim --file multi.csv --channels temp,flow --s 128  (columns = channels)
  hst mdim --d 4 --n 12000 --gen-seed 7 --algo brute-md   (synthetic correlated channels)
  hst generate 'Shuttle TEK 14' --out tek14.txt
  hst serve --addr 127.0.0.1:7878 --workers 4   (0 = HST_THREADS/all cores)
  hst serve --max-streams 1024 --ctx-cache 16 --stream-workers 2
  hst serve --snapshot-dir snapshots   (restore warm state on boot, save on shutdown)
  hst submit --addr 127.0.0.1:7878 --dataset 'ECG 15' --algo hst-par --threads 2
  hst snapshot save --addr 127.0.0.1:7878 --dir snapshots   (persist warm state now)
  hst snapshot restore --addr 127.0.0.1:7878                (seed from --snapshot-dir)
  hst snapshot inspect snapshots/ctx_ecg-15_0123456789abcdef.hsts
  hst trace run.jsonl                        (validate + summarize a trace file)
  hst info
thread control: --threads N on discover/submit/table, or HST_THREADS env";

fn bench_config(args: &Args) -> BenchConfig {
    let mut cfg = if args.has("full") {
        BenchConfig::full()
    } else {
        BenchConfig::default()
    };
    cfg.scale_div = args.get_usize("scale-div", cfg.scale_div);
    cfg.runs = args.get_usize("runs", cfg.runs);
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.threads = args.get_usize("threads", cfg.threads);
    cfg
}

fn discover(args: &Args) -> Result<()> {
    let name = args
        .positionals
        .first()
        .context("discover needs a dataset name (see `hst info`)")?;
    let algo_name = args.get_or("algo", "hst");
    let engine = algo::by_name(algo_name)
        .with_context(|| format!("unknown algorithm {algo_name:?}"))?;

    let (ts, default_params) = if name == "synthetic" {
        let n = args.get_usize("n", 20_000);
        let e = args.get_f64("noise", 0.1);
        let seed = args.get_u64("gen-seed", 0);
        let pts = hstime::ts::generators::sine_with_noise(n, e, seed);
        (
            hstime::ts::TimeSeries::new(format!("synthetic(E={e})"), pts),
            SearchParams::new(120, 4, 4),
        )
    } else {
        let d = datasets::by_name(name)
            .with_context(|| format!("unknown dataset {name:?}"))?;
        let ts = d.generate_scaled(args.get_usize("scale-div", 8));
        (ts, SearchParams::new(d.s, d.p, d.alphabet))
    };

    let s = args.get_usize("s", default_params.sax.s);
    let p = args.get_usize("p", if s % default_params.sax.p == 0 { default_params.sax.p } else { 4 });
    let alpha = args.get_usize("alphabet", default_params.sax.alphabet);
    let params = SearchParams::new(s, p, alpha)
        .with_discords(args.get_usize("k", 1))
        .with_seed(args.get_u64("seed", 0))
        .with_threads(args.get_usize("threads", 0));

    let report = match args.get("trace") {
        Some(path) => {
            // span-shaped JSONL trace of this one search (schema
            // hst-trace/1; `hst trace FILE` validates it back)
            let sink = std::sync::Arc::new(
                hstime::obs::JsonlTraceWriter::create(std::path::Path::new(
                    path,
                ))?,
            );
            let dyn_sink: std::sync::Arc<dyn hstime::obs::TraceSink> =
                std::sync::Arc::clone(&sink);
            let ctx = hstime::context::SearchContext::builder(&ts)
                .trace_sink(dyn_sink)
                .build();
            let report = engine.run_ctx(&ctx, &params)?;
            let errors = sink.finish()?;
            anyhow::ensure!(
                errors == 0,
                "{errors} trace events failed to write to {path}"
            );
            report
        }
        None => engine.run(&ts, &params)?,
    };
    if args.has("json") {
        println!("{}", report.to_json().set("dataset", ts.name.as_str()));
    } else {
        println!(
            "dataset {} ({} points, N={} sequences, s={})",
            ts.name,
            ts.n_total(),
            report.n_sequences,
            s
        );
        println!(
            "algo {}  distance calls {}  cps {:.1}  elapsed {:.3}s",
            report.algo,
            report.distance_calls,
            report.cps(),
            report.elapsed.as_secs_f64()
        );
        for (rank, d) in report.discords.iter().enumerate() {
            println!(
                "  #{:<2} discord @ {:<8} nnd {:<10.4} neighbor @ {}",
                rank + 1,
                d.position,
                d.nnd,
                d.neighbor
            );
        }
    }
    Ok(())
}

fn table(args: &Args) -> Result<()> {
    let id = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let cfg = bench_config(args);
    let ids: Vec<&str> = if id == "all" {
        tables::ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let gen = tables::by_id(id).with_context(|| format!("unknown table {id:?}"))?;
        let t = gen(&cfg);
        if args.has("json") {
            println!("{}", t.to_json());
        } else {
            println!("{}", t.render());
        }
    }
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    use hstime::bench::trajectory as traj;

    let load = |path: &str| -> Result<Vec<traj::BenchRecord>> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        let doc = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
        traj::validate(&doc).with_context(|| format!("{path} failed schema validation"))
    };

    // hst bench --check FILE — schema-validate an existing trajectory
    if let Some(path) = args.get("check") {
        let records = load(path)?;
        println!("{path}: ok ({} records, schema {})", records.len(), traj::TRAJECTORY_SCHEMA);
        return Ok(());
    }
    // hst bench --diff OLD NEW — per-cell ratios between two trajectories
    if let Some(old_path) = args.get("diff") {
        let new_path = args
            .positionals
            .first()
            .context("--diff needs two files: hst bench --diff OLD.json NEW.json")?;
        for line in traj::diff(&load(old_path)?, &load(new_path)?)? {
            println!("{line}");
        }
        return Ok(());
    }

    // run a sweep: tier picks fixtures + BenchConfig defaults, flags override
    let quick = args.has("quick");
    let (tier, mut cfg) = if args.has("full") {
        ("full", BenchConfig::full())
    } else if quick {
        ("quick", BenchConfig::smoke())
    } else {
        ("standard", BenchConfig::default())
    };
    cfg.scale_div = args.get_usize("scale-div", cfg.scale_div);
    cfg.runs = args.get_usize("runs", cfg.runs);
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.threads = args.get_usize("threads", cfg.threads);
    let kernel = match args.get("kernel") {
        Some(name) => hstime::dist::Kernel::from_name(name)
            .with_context(|| format!("unknown kernel {name:?} (scalar|simd)"))?,
        None => hstime::dist::Kernel::active(),
    };

    let records = traj::run_trajectory(&cfg, quick, kernel)?;
    let meta = traj::TrajectoryMeta::measured(&cfg, tier, kernel);
    let doc = traj::trajectory_json(&meta, &records);
    match args.get("json") {
        // bare --json (no path) prints the document instead
        Some(path) if path != hstime::util::cli::FLAG_SET => {
            std::fs::write(path, format!("{doc}\n"))
                .with_context(|| format!("writing {path}"))?;
            println!("wrote {} records ({tier} tier) to {path}", records.len());
        }
        Some(_) => println!("{doc}"),
        None => {
            for r in &records {
                println!(
                    "{:<12} {:<16} n={:<6} s={:<4} calls={:<10} cps={:<10.2} \
                     prep={:<8} wall={:.2}ms",
                    r.engine, r.table, r.n, r.s, r.calls, r.cps, r.prep_calls, r.wall_ms
                );
            }
        }
    }
    Ok(())
}

fn report(args: &Args) -> Result<()> {
    let cfg = bench_config(args);
    let ids: Vec<&str> = match args.positionals.first() {
        Some(one) => vec![one.as_str()],
        None => hstime::tables::ALL_IDS.to_vec(),
    };
    let text = hstime::tables::report::generate(&cfg, &ids);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("wrote report to {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn plot(args: &Args) -> Result<()> {
    let name = args.positionals.first().context("plot needs a dataset")?;
    let d = datasets::by_name(name)
        .with_context(|| format!("unknown dataset {name:?}"))?;
    let ts = d.generate_scaled(args.get_usize("scale-div", 8));
    let width = args.get_usize("width", 100);
    println!("{}", hstime::ts::plot::plot_series(&ts, width, 10));
    // discords + profile
    let k = args.get_usize("k", 3);
    let params = SearchParams::new(d.s, d.p, d.alphabet).with_discords(k);
    let rep = algo::hst::HstSearch::default().run(&ts, &params)?;
    let stats = hstime::ts::SeqStats::compute(&ts, d.s);
    let (profile, _) = algo::scamp::Scamp::matrix_profile(&ts, &stats);
    println!(
        "{}",
        hstime::ts::plot::plot_profile_with_discords(&profile.nnd, &rep.discords, width, 8)
    );
    for (rank, disc) in rep.discords.iter().enumerate() {
        println!("#{} discord @ {} nnd {:.4}", rank + 1, disc.position, disc.nnd);
    }
    Ok(())
}

fn merlin(args: &Args) -> Result<()> {
    let name = args.positionals.first().context("merlin needs a dataset")?;
    let d = datasets::by_name(name)
        .with_context(|| format!("unknown dataset {name:?}"))?;
    let ts = d.generate_scaled(args.get_usize("scale-div", 8));
    let scan = algo::merlin::Merlin::new(
        args.get_usize("min-len", d.s / 2),
        args.get_usize("max-len", d.s),
    )
    .with_step(args.get_usize("step", (d.s / 8).max(1)));
    let (found, calls) = scan.scan_series(&ts)?;
    println!(
        "MERLIN over L in [{}, {}] step {} — {} lengths, {} distance calls",
        scan.min_len,
        scan.max_len,
        scan.step,
        found.len(),
        calls
    );
    for ld in &found {
        println!(
            "  L={:<5} discord @ {:<8} nnd {:<10.4} (r={:.4}, {} attempts)",
            ld.s, ld.discord.position, ld.discord.nnd, ld.r_used, ld.attempts
        );
    }
    Ok(())
}

fn vl(args: &Args) -> Result<()> {
    let name = args.positionals.first().context("vl needs a dataset")?;
    let d = datasets::by_name(name)
        .with_context(|| format!("unknown dataset {name:?}"))?;
    let ts = d.generate_scaled(args.get_usize("scale-div", 8));
    // same defaults as `hst merlin`, so the two scans cover one range
    let range = hstime::config::LengthRange {
        min: args.get_usize("min-len", (d.s / 2).max(4)),
        max: args.get_usize("max-len", d.s),
        step: args.get_usize("step", (d.s / 8).max(1)),
    };
    let base = SearchParams::new(d.s, d.p, d.alphabet)
        .with_discords(args.get_usize("k", 1))
        .with_seed(args.get_u64("seed", 0));
    let ctx = hstime::context::SearchContext::builder(&ts).build();
    // scan() validates the range with named errors (no panicking ctor)
    let report = hstime::vl::HstVl { range }.scan(&ctx, &base)?;
    if args.has("json") {
        println!("{}", report.to_json().set("dataset", ts.name.as_str()));
        return Ok(());
    }
    println!(
        "hst-vl over s in [{}, {}] step {} — {} lengths, {} distance calls, {:.3}s",
        range.min,
        range.max,
        range.step,
        report.lengths.len(),
        report.total_calls,
        report.elapsed.as_secs_f64()
    );
    for vl in &report.lengths {
        let top = &vl.report.discords[0];
        println!(
            "  s={:<5} discord @ {:<8} nnd {:<10.4} ({} calls, transfer {}, {})",
            vl.s,
            top.position,
            top.nnd,
            vl.report.distance_calls,
            vl.transfer_calls,
            if vl.warm { "warm" } else { "cold" }
        );
    }
    println!("ranked by nnd/\u{221a}s:");
    for (rank, r) in report.ranked.iter().take(base.k.max(3)).enumerate() {
        println!(
            "  #{:<2} s={:<5} discord @ {:<8} score {:<10.4} (raw nnd {:.4})",
            rank + 1,
            r.s,
            r.discord.position,
            r.score,
            r.discord.nnd
        );
    }
    Ok(())
}

fn monitor(args: &Args) -> Result<()> {
    let name = args.positionals.first().context("monitor needs a dataset")?;
    let d = datasets::by_name(name)
        .with_context(|| format!("unknown dataset {name:?}"))?;
    let ts = d.generate_scaled(args.get_usize("scale-div", 8));
    let window = args.get_usize("window", (8 * d.s).max(2_000));
    let batch = args.get_usize("batch", window / 4);
    let params = SearchParams::new(d.s, d.p, d.alphabet)
        .with_discords(args.get_usize("k", 1));
    let mut mon = hstime::service::online::OnlineMonitor::new(params, window, batch);
    println!(
        "streaming {} ({} pts) through a {window}-pt window, batch {batch}",
        ts.name,
        ts.n_total()
    );
    let mut total_alerts = 0;
    for chunk in ts.points.chunks(batch) {
        for alert in mon.push(chunk)? {
            total_alerts += 1;
            println!(
                "  t={:<8} nnd {:<9.4} {}",
                alert.global_position,
                alert.nnd,
                if alert.significant { "SIGNIFICANT" } else { "" }
            );
        }
    }
    println!("{total_alerts} alerts emitted");
    Ok(())
}

fn print_stream_update(u: &hstime::stream::StreamUpdate, json: bool) {
    if json {
        println!("{}", u.to_json());
        return;
    }
    println!(
        "refresh #{:<4} window [{}, {})  calls {:<8} cps {:<7.2} {}",
        u.refresh,
        u.window_start,
        u.window_start + u.window_len as u64,
        u.distance_calls,
        u.cps(),
        if u.warm { "warm" } else { "cold" },
    );
    for (rank, d) in u.discords.iter().enumerate() {
        println!(
            "    #{:<2} discord @ {:<10} nnd {:<10.4} neighbor @ {}",
            rank + 1,
            d.position,
            d.nnd,
            d.neighbor
        );
    }
}

fn stream(args: &Args) -> Result<()> {
    use std::io::BufRead as _;

    // point source: a registry dataset, --file, or stdin (one f64/line)
    let (points, default_s, default_p): (Vec<f64>, usize, usize) =
        if let Some(name) = args.positionals.first() {
            let d = datasets::by_name(name)
                .with_context(|| format!("unknown dataset {name:?}"))?;
            let ts = d.generate_scaled(args.get_usize("scale-div", 8));
            (ts.points, d.s, d.p)
        } else if let Some(path) = args.get("file") {
            let ts = ts_io::load_text(std::path::Path::new(path), 0)?;
            (ts.points, 128, 4)
        } else {
            let mut pts = Vec::new();
            for line in std::io::stdin().lock().lines() {
                let line = line?;
                let t = line.trim();
                if t.is_empty() || t.starts_with('#') {
                    continue;
                }
                pts.push(t.parse::<f64>().with_context(|| {
                    format!("stdin: bad number {t:?}")
                })?);
            }
            (pts, 128, 4)
        };

    let s = args.get_usize("s", default_s);
    // prefer the dataset's registry P; otherwise the shared default rule
    let p = args.get_usize(
        "p",
        if s % default_p == 0 {
            default_p
        } else {
            hstime::config::SaxParams::default_p(s)
        },
    );
    let alpha = args.get_usize("alphabet", 4);
    let params = SearchParams::new(s, p, alpha)
        .with_discords(args.get_usize("k", 1))
        .with_seed(args.get_u64("seed", 0));
    let window = args.get_usize("window", (8 * s).max(2_000));
    let refresh_every = args.get_usize("refresh-every", window / 4);
    let json = args.has("json");

    // --addr switches to remote mode: ship the points to a running
    // `hst serve` as binary data frames instead of monitoring locally
    if let Some(addr) = args.get("addr") {
        let addr = addr.to_string();
        return stream_remote(args, &addr, &points, &params, window, refresh_every, json);
    }

    let mut mon = hstime::stream::StreamingMonitor::new(params, window)?
        .with_name("cli-stream")
        .with_refresh_every(refresh_every);
    if !json {
        println!(
            "streaming {} points through a {window}-pt window \
             (s={s}, refresh every {refresh_every})",
            points.len()
        );
    }
    for &x in &points {
        if let Some(u) = mon.append(x)? {
            print_stream_update(&u, json);
        }
    }
    // flush a final refresh so trailing points are searched too — but
    // only if any arrived since the last auto-refresh (a duplicate
    // search over an unchanged window would just repeat the last update)
    if mon.pending_points() > 0 && mon.num_sequences() >= 2 {
        print_stream_update(&mon.refresh()?, json);
    }
    if !json {
        println!(
            "{} refreshes, {} distance calls total",
            mon.refreshes(),
            mon.distance_calls()
        );
    }
    Ok(())
}

/// `hst stream --addr`: feed the points to a remote service over the
/// binary frame protocol (hello → stream_open → data frames → subscribe
/// for updates → stream_close). Refreshes printed here are bit-identical
/// to what the local monitor path would print for the same points.
fn stream_remote(
    args: &Args,
    addr: &str,
    points: &[f64],
    params: &SearchParams,
    window: usize,
    refresh_every: usize,
    json: bool,
) -> Result<()> {
    let mut client = service::Client::connect(addr)?;
    client.hello()?;
    let name = args.get_or("stream", "cli-stream").to_string();
    let params_json = Json::obj()
        .set("s", params.sax.s)
        .set("p", params.sax.p)
        .set("alphabet", params.sax.alphabet)
        .set("k", params.k)
        .set("seed", params.seed);
    let sid = client.open_stream(&name, params_json, window, refresh_every)?;
    if !json {
        println!(
            "streaming {} points to {addr} as binary frames \
             (stream {name:?} id {sid}, window {window}, refresh every \
             {refresh_every})",
            points.len()
        );
    }
    let frame_points = args.get_usize("frame-points", 512).max(1);
    for chunk in points.chunks(frame_points) {
        client.send_points(sid, chunk)?;
    }
    // drain updates until the server has nothing new for two seconds
    let mut seq = 0u64;
    loop {
        let reply = client.subscribe(&name, seq, 2_000)?;
        if reply.get("timed_out").is_some()
            || reply.get("ok").and_then(|b| b.as_bool()) != Some(true)
        {
            break;
        }
        let Some(next) = reply.get("seq").and_then(|s| s.as_u64()) else {
            break;
        };
        seq = next;
        if let Some(update) = reply.get("update") {
            if json {
                println!("{update}");
            } else {
                let calls = update
                    .get("distance_calls")
                    .and_then(|c| c.as_u64())
                    .unwrap_or(0);
                let n_disc = update
                    .get("discords")
                    .and_then(|d| d.as_arr())
                    .map(|d| d.len())
                    .unwrap_or(0);
                println!(
                    "refresh {seq}: {n_disc} discords, {calls} distance calls"
                );
            }
        }
    }
    let sheds = client.take_sheds();
    if !sheds.is_empty() {
        let dropped: u64 = sheds.iter().map(|s| s.dropped as u64).sum();
        eprintln!(
            "warning: {} frames ({dropped} points) shed by the server \
             (first reason: {})",
            sheds.len(),
            sheds[0].reason.name()
        );
    }
    client.call(
        &Json::obj().set("cmd", "stream_close").set("stream", name.as_str()),
    )?;
    if !json {
        println!("{seq} refreshes observed");
    }
    Ok(())
}

fn mdim(args: &Args) -> Result<()> {
    use hstime::mdim::{self, MdimAlgorithm as _, MdimParams};

    // channel source: a multi-column file, or the correlated synthetic
    // generator (shared walk + per-channel noise + a joint anomaly)
    let (ms, default_s) = if let Some(path) = args.get("file") {
        (ts_io::load_multi_csv(std::path::Path::new(path))?, 128)
    } else {
        let s_hint = args.get_usize("s", 96);
        let ms = hstime::ts::generators::correlated_channels(
            args.get_usize("n", 8_000),
            args.get_usize("d", 3),
            args.get_usize("anomaly-len", s_hint),
            args.get_u64("gen-seed", 0),
        );
        (ms, 96)
    };

    let s = args.get_usize("s", default_s);
    let p = args.get_usize("p", hstime::config::SaxParams::default_p(s));
    let alpha = args.get_usize("alphabet", 4);
    let base = SearchParams::new(s, p, alpha)
        .with_discords(args.get_usize("k", 1))
        .with_seed(args.get_u64("seed", 0))
        .with_threads(args.get_usize("threads", 0));
    let channels: Vec<String> = args
        .get("channels")
        .map(|list| {
            list.split(',')
                .map(|c| c.trim().to_string())
                .filter(|c| !c.is_empty())
                .collect()
        })
        .unwrap_or_default();
    let params = MdimParams { base, channels };

    let algo_name = args.get_or("algo", "hst-md");
    let engine = mdim::by_name(algo_name)
        .with_context(|| format!("unknown multivariate algorithm {algo_name:?}"))?;
    let report = engine.run_multi(&ms, &params)?;
    if args.has("json") {
        println!("{}", report.to_json().set("dataset", ms.name.as_str()));
    } else {
        println!(
            "dataset {} ({} channels x {} points, N={} sequences, s={})",
            ms.name,
            ms.dims(),
            ms.n_total(),
            report.n_sequences,
            s
        );
        println!(
            "algo {}  channels [{}]  distance calls {}  cps/channel {:.2}  elapsed {:.3}s",
            report.algo,
            report.channels.join(", "),
            report.distance_calls,
            report.cps_per_channel(),
            report.elapsed.as_secs_f64()
        );
        for (rank, d) in report.discords.iter().enumerate() {
            println!(
                "  #{:<2} discord @ {:<8} aggregate nnd {:<10.4} neighbor @ {}",
                rank + 1,
                d.position,
                d.nnd,
                d.neighbor
            );
        }
    }
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let name = args
        .positionals
        .first()
        .context("generate needs a dataset name")?;
    let d = datasets::by_name(name)
        .with_context(|| format!("unknown dataset {name:?}"))?;
    let ts = d.generate_scaled(args.get_usize("scale-div", 1));
    let out = args.get("out").context("--out <file> required")?;
    ts_io::save_text(&ts, std::path::Path::new(out))?;
    println!("wrote {} points to {}", ts.n_total(), out);
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
    let defaults = service::ServeConfig::default();
    // 0 = size the pool via ExecPolicy (HST_THREADS, then all cores)
    let workers = hstime::exec::ExecPolicy::new(args.get_usize("workers", 0))
        .resolve();
    let cfg = service::ServeConfig {
        workers,
        capacity: args.get_usize("capacity", defaults.capacity),
        max_streams: args.get_usize("max-streams", defaults.max_streams),
        ctx_cache: args.get_usize("ctx-cache", defaults.ctx_cache),
        stream_workers: args
            .get_usize("stream-workers", defaults.stream_workers),
        snapshot_dir: args
            .get("snapshot-dir")
            .map(std::path::PathBuf::from),
    };
    anyhow::ensure!(
        cfg.max_streams > 0,
        "flag `--max-streams` must be >= 1 (0 would reject every \
         stream_open)"
    );
    anyhow::ensure!(
        cfg.ctx_cache > 0,
        "flag `--ctx-cache` must be >= 1 (0 would disable context reuse \
         entirely)"
    );
    println!(
        "hstime service: workers={} capacity={} max_streams={} ctx_cache={} \
         stream_workers={} snapshot_dir={}",
        cfg.workers,
        cfg.capacity,
        cfg.max_streams,
        cfg.ctx_cache,
        cfg.stream_workers,
        cfg.snapshot_dir
            .as_ref()
            .map(|d| d.display().to_string())
            .unwrap_or_else(|| "-".to_string())
    );
    service::serve_config(addr.as_str(), cfg, |bound| {
        println!("listening on {bound}");
    })
}

fn submit(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
    let dataset = args.get_or("dataset", "ECG 15").to_string();
    let s = args.get_usize("s", datasets::by_name(&dataset).map(|d| d.s).unwrap_or(128));
    let req = Json::obj()
        .set("cmd", "submit")
        .set("dataset", dataset.as_str())
        .set("algo", args.get_or("algo", "hst"))
        .set("scale_div", args.get_usize("scale-div", 8))
        .set(
            "params",
            Json::obj()
                .set("s", s)
                .set("p", args.get_usize("p", 4))
                .set("alphabet", args.get_usize("alphabet", 4))
                .set("k", args.get_usize("k", 1))
                .set("seed", args.get_u64("seed", 0))
                .set("threads", args.get_usize("threads", 0)),
        );
    let mut client = service::Client::connect(addr.as_str())?;
    let job = client.submit(req)?;
    println!("job {job} submitted; waiting…");
    let reply = client.wait(job)?;
    println!("{reply}");
    Ok(())
}

fn snapshot(args: &Args) -> Result<()> {
    let action = args
        .positionals
        .first()
        .map(String::as_str)
        .context("snapshot needs an action: save | restore | inspect")?;
    match action {
        "inspect" => {
            let path = args
                .positionals
                .get(1)
                .context("snapshot inspect needs a .hsts file path")?;
            let bytes = std::fs::read(path)
                .with_context(|| format!("reading {path}"))?;
            let summary = hstime::snapshot::inspect(&bytes)
                .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            if args.has("json") {
                let sections: Vec<Json> = summary
                    .sections
                    .iter()
                    .map(|s| {
                        Json::obj()
                            .set("tag", s.tag as u64)
                            .set("name", s.name)
                            .set("len", s.len)
                            .set("offset", s.offset)
                    })
                    .collect();
                println!(
                    "{}",
                    Json::obj()
                        .set("ok", true)
                        .set("kind", summary.kind.name())
                        .set("bytes", summary.bytes)
                        .set("sections", sections)
                        .set(
                            "detail",
                            summary
                                .detail
                                .iter()
                                .map(|d| Json::from(d.as_str()))
                                .collect::<Vec<_>>(),
                        )
                );
            } else {
                println!(
                    "{path}: {} snapshot, {} bytes, {} sections",
                    summary.kind.name(),
                    summary.bytes,
                    summary.sections.len()
                );
                for s in &summary.sections {
                    println!(
                        "  section {:#06x} {:<14} {:>8} bytes @ {}",
                        s.tag, s.name, s.len, s.offset
                    );
                }
                for line in &summary.detail {
                    println!("  {line}");
                }
            }
            Ok(())
        }
        "save" | "restore" => {
            let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
            let mut req = Json::obj().set(
                "cmd",
                if action == "save" { "snapshot_save" } else { "snapshot_restore" },
            );
            if let Some(dir) = args.get("dir") {
                req = req.set("dir", dir);
            }
            let mut client = service::Client::connect(addr.as_str())?;
            let reply = client.call(&req)?;
            println!("{reply}");
            anyhow::ensure!(
                reply.get("ok").and_then(|b| b.as_bool()) == Some(true),
                "snapshot {action} rejected by the server"
            );
            Ok(())
        }
        other => bail!(
            "unknown snapshot action {other:?} (expected save, restore, \
             or inspect)"
        ),
    }
}

fn trace(args: &Args) -> Result<()> {
    let path = args
        .positionals
        .first()
        .context("trace needs a file: hst trace run.jsonl")?;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    let summary = hstime::obs::validate_trace(&text)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    println!("{}", summary.to_json().set("file", path.as_str()));
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    println!("hstime {} — HOT SAX Time reproduction", env!("CARGO_PKG_VERSION"));
    println!("\ndatasets (paper Tables 1/6):");
    for d in datasets::registry() {
        println!(
            "  {:<16} len {:>7}  s={:<5} P={:<3} alphabet={} family {:?}",
            d.name, d.paper_len, d.s, d.p, d.alphabet, d.family
        );
    }
    println!("\nalgorithms: {}", algo::ALL_ENGINES.join(", "));
    println!(
        "threads: --threads N on discover/submit/table, HST_THREADS env, \
         default all cores (currently resolves to {})",
        hstime::exec::ExecPolicy::auto().resolve()
    );
    println!(
        "distance backend: {:?}{}",
        hstime::dist::active_backend(),
        if cfg!(feature = "pjrt") {
            ""
        } else {
            " (build with --features pjrt for the XLA/PJRT runtime)"
        }
    );
    let dir = hstime::runtime::default_artifact_dir();
    match hstime::runtime::Manifest::load(&dir) {
        Ok(m) => println!(
            "\nartifacts: {} entries in {} (s_pad={}, query_b={}, tile={})",
            m.entries.len(),
            dir.display(),
            m.s_pad,
            m.query_b,
            m.tile
        ),
        Err(e) => println!(
            "\nartifacts: not available ({e:#}) — run `make artifacts`"
        ),
    }
    if args.has("verbose") {
        println!("\ntables: {}", tables::ALL_IDS.join(", "));
    }
    Ok(())
}
