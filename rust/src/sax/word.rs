//! SAX words: fixed-length symbol strings.
//!
//! Words are at most 32 symbols (the paper's largest P is 15; Sec. 4.6 uses
//! P = 128, for which we fall back to a hashed 32-symbol digest of the
//! word — cluster identity only needs equality, and digest collisions
//! merely merge clusters, which is a performance (not correctness) effect
//! for HOT SAX/HST since SAX only *orders* the search).

use std::fmt;

/// Maximum symbols stored inline.
pub const MAX_INLINE: usize = 32;

/// A SAX word (cluster key).
#[derive(Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SaxWord {
    len: u8,
    sym: [u8; MAX_INLINE],
}

impl SaxWord {
    /// Build from raw symbols. Words longer than [`MAX_INLINE`] are folded
    /// (xor-rotate) into 32 bytes.
    pub fn new(symbols: &[u8]) -> SaxWord {
        let mut sym = [0u8; MAX_INLINE];
        if symbols.len() <= MAX_INLINE {
            sym[..symbols.len()].copy_from_slice(symbols);
            SaxWord {
                len: symbols.len() as u8,
                sym,
            }
        } else {
            for (i, &s) in symbols.iter().enumerate() {
                let slot = i % MAX_INLINE;
                sym[slot] = sym[slot].rotate_left(3) ^ s.wrapping_add(i as u8);
            }
            SaxWord {
                len: MAX_INLINE as u8,
                sym,
            }
        }
    }

    /// Symbols as a slice (digest bytes if the word was folded).
    pub fn symbols(&self) -> &[u8] {
        &self.sym[..self.len as usize]
    }

    /// Number of stored symbols (digest length if folded).
    pub fn len(&self) -> usize {
        self.len as usize
    }

    /// Whether the word holds no symbols.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn write_letters(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // letters a, b, c… like the SAX literature
        for &s in self.symbols() {
            let c = if s < 26 { (b'a' + s) as char } else { '#' };
            write!(f, "{c}")?;
        }
        Ok(())
    }
}

impl fmt::Debug for SaxWord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_letters(f)
    }
}

impl fmt::Display for SaxWord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_letters(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equality_and_hash_on_symbols() {
        use std::collections::HashMap;
        let a = SaxWord::new(&[0, 1, 2, 3]);
        let b = SaxWord::new(&[0, 1, 2, 3]);
        let c = SaxWord::new(&[0, 1, 2, 2]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        let mut m = HashMap::new();
        m.insert(a.clone(), 1);
        assert_eq!(m.get(&b), Some(&1));
        assert_eq!(m.get(&c), None);
    }

    #[test]
    fn display_as_letters() {
        let w = SaxWord::new(&[0, 1, 3, 2]);
        assert_eq!(w.to_string(), "abdc");
    }

    #[test]
    fn long_words_fold_deterministically() {
        let long: Vec<u8> = (0..128).map(|i| (i % 4) as u8).collect();
        let a = SaxWord::new(&long);
        let b = SaxWord::new(&long);
        assert_eq!(a, b);
        assert_eq!(a.len(), MAX_INLINE);
        // a different long word should (almost surely) differ
        let mut other = long.clone();
        other[50] = 3 - other[50];
        assert_ne!(a, SaxWord::new(&other));
    }

    #[test]
    fn length_prefix_distinguishes() {
        // "ab" != "ab\0" even though padding bytes match
        let a = SaxWord::new(&[0, 1]);
        let b = SaxWord::new(&[0, 1, 0]);
        assert_ne!(a, b);
    }
}
