//! Symbolic Aggregate approXimation (SAX) substrate (Lin et al., 2003).
//!
//! SAX is the dimensionality-reduction device both HOT SAX and HST use to
//! organize their search: each z-normalized sequence is reduced by PAA to
//! `P` segment means, each mean is quantized against Gaussian breakpoints
//! into one of `alphabet` symbols, and sequences sharing a symbolic word
//! form a *cluster*. Small clusters hint at isolated sequences (discord
//! candidates); same-cluster members are likely Euclidean neighbors.

pub mod breakpoints;
pub mod index;
pub mod mindist;
pub mod paa;
pub mod word;

pub use index::{SaxIndex, WordBuilder};
pub use word::SaxWord;
