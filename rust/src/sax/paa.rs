//! Piecewise Aggregate Approximation (PAA).
//!
//! Reduces a z-normalized sequence of length `s` to `p` segment means.
//! Like the paper's implementation, `p` must divide `s` exactly ("our code
//! requires that the number of parts of the PAA is an exact divisor of the
//! length of the sequences", Sec. 4.3).

/// PAA of `seq` (length s) into `out` (length p). `s % p == 0`.
pub fn paa_into(seq: &[f64], out: &mut [f64]) {
    let s = seq.len();
    let p = out.len();
    assert!(p > 0 && s % p == 0, "P={p} must divide s={s}");
    let w = s / p;
    let inv_w = 1.0 / w as f64;
    for (i, o) in out.iter_mut().enumerate() {
        let seg = &seq[i * w..(i + 1) * w];
        *o = seg.iter().sum::<f64>() * inv_w;
    }
}

/// Allocating variant of [`paa_into`].
pub fn paa(seq: &[f64], p: usize) -> Vec<f64> {
    let mut out = vec![0.0; p];
    paa_into(seq, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages_segments() {
        let seq = [1.0, 3.0, 2.0, 4.0, 10.0, 20.0];
        assert_eq!(paa(&seq, 3), vec![2.0, 3.0, 15.0]);
        let p2 = paa(&seq, 2);
        assert!((p2[0] - 2.0).abs() < 1e-12);
        assert!((p2[1] - 34.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn identity_when_p_equals_s() {
        let seq = [1.5, -2.0, 0.25];
        assert_eq!(paa(&seq, 3), seq.to_vec());
    }

    #[test]
    fn p_one_is_global_mean() {
        let seq = [2.0, 4.0, 6.0, 8.0];
        assert_eq!(paa(&seq, 1), vec![5.0]);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn rejects_non_divisor() {
        paa(&[0.0; 10], 3);
    }

    #[test]
    fn preserves_mean() {
        // PAA of a z-normalized (zero-mean) sequence stays zero-mean.
        let mut rng = crate::util::rng::Rng64::new(1);
        let mut seq: Vec<f64> = (0..120).map(|_| rng.normal()).collect();
        let m = seq.iter().sum::<f64>() / 120.0;
        for v in &mut seq {
            *v -= m;
        }
        let red = paa(&seq, 4);
        assert!(red.iter().sum::<f64>().abs() < 1e-10);
    }
}
