//! The SAX index: word per sequence + clusters grouped by word.
//!
//! This is the `SAX()` step of both HOT SAX and HST (Listing 2, line 3):
//! every sequence start is mapped to its SAX word; sequences sharing a word
//! form a cluster. Clusters are exposed sorted by size (ascending) because
//! both algorithms scan "from the smallest to the biggest" cluster.

use std::collections::HashMap;

use crate::config::SaxParams;
use crate::ts::{SeqStats, TimeSeries};

use super::breakpoints::{breakpoints, symbolize};
use super::paa::paa_into;
use super::word::SaxWord;

/// SAX index over all sequences of one series for fixed (s, P, alphabet).
#[derive(Debug, Clone)]
pub struct SaxIndex {
    /// Word of each sequence start (len = N).
    pub words: Vec<SaxWord>,
    /// Cluster id of each sequence start (len = N); ids index `clusters`.
    pub cluster_of: Vec<usize>,
    /// Members of each cluster, in time order.
    pub clusters: Vec<Vec<usize>>,
    /// Cluster ids sorted by ascending size (ties by id for determinism).
    pub by_size: Vec<usize>,
}

impl SaxIndex {
    /// Build the index. `stats` must have been computed with `params.s`.
    pub fn build(ts: &TimeSeries, stats: &SeqStats, params: &SaxParams) -> SaxIndex {
        assert_eq!(stats.s, params.s, "stats were computed for a different s");
        let n = stats.len();
        let beta = breakpoints(params.alphabet);
        let mut znorm_buf = vec![0.0; params.s];
        let mut paa_buf = vec![0.0; params.p];
        let mut sym_buf = vec![0u8; params.p];

        let mut words = Vec::with_capacity(n);
        let mut map: HashMap<SaxWord, usize> = HashMap::new();
        let mut clusters: Vec<Vec<usize>> = Vec::new();
        let mut cluster_of = Vec::with_capacity(n);

        for k in 0..n {
            stats.znorm_into(ts, k, &mut znorm_buf);
            paa_into(&znorm_buf, &mut paa_buf);
            for (sy, &v) in sym_buf.iter_mut().zip(&paa_buf) {
                *sy = symbolize(v, &beta);
            }
            let w = SaxWord::new(&sym_buf);
            let id = *map.entry(w.clone()).or_insert_with(|| {
                clusters.push(Vec::new());
                clusters.len() - 1
            });
            clusters[id].push(k);
            cluster_of.push(id);
            words.push(w);
        }

        let mut by_size: Vec<usize> = (0..clusters.len()).collect();
        by_size.sort_by_key(|&id| (clusters[id].len(), id));

        SaxIndex {
            words,
            cluster_of,
            clusters,
            by_size,
        }
    }

    /// Number of sequences indexed.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// Whether no sequence is indexed.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Members of the cluster containing sequence `i`.
    pub fn cluster_members(&self, i: usize) -> &[usize] {
        &self.clusters[self.cluster_of[i]]
    }

    /// Size of the cluster containing sequence `i`.
    pub fn cluster_size(&self, i: usize) -> usize {
        self.cluster_members(i).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SaxParams;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    fn small_index() -> (TimeSeries, SeqStats, SaxIndex) {
        let ts = generators::sine_with_noise(2_000, 0.1, 42).into_series("sine");
        let params = SaxParams {
            s: 120,
            p: 4,
            alphabet: 4,
        };
        let stats = SeqStats::compute(&ts, params.s);
        let idx = SaxIndex::build(&ts, &stats, &params);
        (ts, stats, idx)
    }

    #[test]
    fn partitions_all_sequences() {
        let (ts, _, idx) = small_index();
        let n = ts.num_sequences(120);
        assert_eq!(idx.len(), n);
        let total: usize = idx.clusters.iter().map(|c| c.len()).sum();
        assert_eq!(total, n, "clusters partition the sequence set");
        // membership is consistent
        for (k, &cid) in idx.cluster_of.iter().enumerate() {
            assert!(idx.clusters[cid].contains(&k));
        }
    }

    #[test]
    fn same_cluster_means_same_word() {
        let (_, _, idx) = small_index();
        for members in &idx.clusters {
            let w0 = &idx.words[members[0]];
            for &m in members {
                assert_eq!(&idx.words[m], w0);
            }
        }
    }

    #[test]
    fn by_size_is_ascending() {
        let (_, _, idx) = small_index();
        let sizes: Vec<usize> = idx.by_size.iter().map(|&id| idx.clusters[id].len()).collect();
        for w in sizes.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn periodic_series_clusters_tightly() {
        // near-noiseless sine: few clusters, all fairly large
        let ts = generators::sine_with_noise(3_000, 0.0001, 1).into_series("s");
        let params = SaxParams { s: 120, p: 4, alphabet: 4 };
        let stats = SeqStats::compute(&ts, 120);
        let idx = SaxIndex::build(&ts, &stats, &params);
        assert!(
            idx.clusters.len() < 64,
            "expected few clusters, got {}",
            idx.clusters.len()
        );
    }

    #[test]
    fn members_in_time_order() {
        let (_, _, idx) = small_index();
        for members in &idx.clusters {
            for w in members.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }
}
