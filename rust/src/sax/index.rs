//! The SAX index: word per sequence + clusters grouped by word.
//!
//! This is the `SAX()` step of both HOT SAX and HST (Listing 2, line 3):
//! every sequence start is mapped to its SAX word; sequences sharing a word
//! form a cluster. Clusters are exposed sorted by size (ascending) because
//! both algorithms scan "from the smallest to the biggest" cluster.

use std::collections::HashMap;

use crate::config::SaxParams;
use crate::ts::{SeqStats, TimeSeries};

use super::breakpoints::{breakpoints, symbolize};
use super::paa::paa_into;
use super::word::SaxWord;

/// Computes SAX words one sequence at a time (z-norm → PAA → symbols),
/// reusing its scratch buffers across calls.
///
/// The shared word kernel of the batch [`SaxIndex::build`] and the
/// [`stream`](crate::stream) monitor's incremental per-point updates: a
/// word depends only on the sequence's points and its rolling (μ, σ)
/// (themselves pure per-window — see
/// [`ts::window_stats`](crate::ts::window_stats)), so both paths produce
/// bit-identical words for the same window.
#[derive(Debug, Clone)]
pub struct WordBuilder {
    beta: Vec<f64>,
    znorm_buf: Vec<f64>,
    paa_buf: Vec<f64>,
    sym_buf: Vec<u8>,
}

impl WordBuilder {
    /// Scratch state for words under `params`.
    pub fn new(params: &SaxParams) -> WordBuilder {
        WordBuilder {
            beta: breakpoints(params.alphabet),
            znorm_buf: vec![0.0; params.s],
            paa_buf: vec![0.0; params.p],
            sym_buf: vec![0u8; params.p],
        }
    }

    /// The SAX word of one sequence, given its points (length `s`) and its
    /// rolling mean/std.
    pub fn word(&mut self, window: &[f64], mean: f64, std: f64) -> SaxWord {
        debug_assert_eq!(window.len(), self.znorm_buf.len());
        let inv_sd = 1.0 / std;
        for (o, &p) in self.znorm_buf.iter_mut().zip(window) {
            *o = (p - mean) * inv_sd;
        }
        paa_into(&self.znorm_buf, &mut self.paa_buf);
        for (sy, &v) in self.sym_buf.iter_mut().zip(&self.paa_buf) {
            *sy = symbolize(v, &self.beta);
        }
        SaxWord::new(&self.sym_buf)
    }
}

/// SAX index over all sequences of one series for fixed (s, P, alphabet).
#[derive(Debug, Clone)]
pub struct SaxIndex {
    /// Word of each sequence start (len = N).
    pub words: Vec<SaxWord>,
    /// Cluster id of each sequence start (len = N); ids index `clusters`.
    pub cluster_of: Vec<usize>,
    /// Members of each cluster, in time order.
    pub clusters: Vec<Vec<usize>>,
    /// Cluster ids sorted by ascending size (ties by id for determinism).
    pub by_size: Vec<usize>,
}

impl SaxIndex {
    /// Build the index. `stats` must have been computed with `params.s`.
    pub fn build(ts: &TimeSeries, stats: &SeqStats, params: &SaxParams) -> SaxIndex {
        assert_eq!(stats.s, params.s, "stats were computed for a different s");
        let n = stats.len();
        let mut wb = WordBuilder::new(params);
        let words: Vec<SaxWord> = (0..n)
            .map(|k| wb.word(ts.seq(k, params.s), stats.mean[k], stats.std[k]))
            .collect();
        SaxIndex::from_words(words)
    }

    /// Assemble the index from already-computed words (one per sequence
    /// start, in time order). Cluster ids are assigned in order of first
    /// appearance — exactly as [`build`](Self::build) assigns them — so an
    /// index materialized from a streaming monitor's incrementally
    /// maintained word deque is identical to a cold `build` over the same
    /// window.
    pub fn from_words(words: Vec<SaxWord>) -> SaxIndex {
        let n = words.len();
        let mut map: HashMap<SaxWord, usize> = HashMap::new();
        let mut clusters: Vec<Vec<usize>> = Vec::new();
        let mut cluster_of = Vec::with_capacity(n);

        for (k, w) in words.iter().enumerate() {
            let id = *map.entry(w.clone()).or_insert_with(|| {
                clusters.push(Vec::new());
                clusters.len() - 1
            });
            clusters[id].push(k);
            cluster_of.push(id);
        }

        let mut by_size: Vec<usize> = (0..clusters.len()).collect();
        by_size.sort_by_key(|&id| (clusters[id].len(), id));

        SaxIndex {
            words,
            cluster_of,
            clusters,
            by_size,
        }
    }

    /// Number of sequences indexed.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// Whether no sequence is indexed.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Members of the cluster containing sequence `i`.
    pub fn cluster_members(&self, i: usize) -> &[usize] {
        &self.clusters[self.cluster_of[i]]
    }

    /// Size of the cluster containing sequence `i`.
    pub fn cluster_size(&self, i: usize) -> usize {
        self.cluster_members(i).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SaxParams;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    fn small_index() -> (TimeSeries, SeqStats, SaxIndex) {
        let ts = generators::sine_with_noise(2_000, 0.1, 42).into_series("sine");
        let params = SaxParams {
            s: 120,
            p: 4,
            alphabet: 4,
        };
        let stats = SeqStats::compute(&ts, params.s);
        let idx = SaxIndex::build(&ts, &stats, &params);
        (ts, stats, idx)
    }

    #[test]
    fn partitions_all_sequences() {
        let (ts, _, idx) = small_index();
        let n = ts.num_sequences(120);
        assert_eq!(idx.len(), n);
        let total: usize = idx.clusters.iter().map(|c| c.len()).sum();
        assert_eq!(total, n, "clusters partition the sequence set");
        // membership is consistent
        for (k, &cid) in idx.cluster_of.iter().enumerate() {
            assert!(idx.clusters[cid].contains(&k));
        }
    }

    #[test]
    fn same_cluster_means_same_word() {
        let (_, _, idx) = small_index();
        for members in &idx.clusters {
            let w0 = &idx.words[members[0]];
            for &m in members {
                assert_eq!(&idx.words[m], w0);
            }
        }
    }

    #[test]
    fn by_size_is_ascending() {
        let (_, _, idx) = small_index();
        let sizes: Vec<usize> = idx.by_size.iter().map(|&id| idx.clusters[id].len()).collect();
        for w in sizes.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn periodic_series_clusters_tightly() {
        // near-noiseless sine: few clusters, all fairly large
        let ts = generators::sine_with_noise(3_000, 0.0001, 1).into_series("s");
        let params = SaxParams { s: 120, p: 4, alphabet: 4 };
        let stats = SeqStats::compute(&ts, 120);
        let idx = SaxIndex::build(&ts, &stats, &params);
        assert!(
            idx.clusters.len() < 64,
            "expected few clusters, got {}",
            idx.clusters.len()
        );
    }

    #[test]
    fn from_words_matches_build_exactly() {
        // the streaming monitor materializes its index through from_words;
        // cluster ids, members, and by_size order must match build()
        let (ts, stats, idx) = small_index();
        let params = SaxParams { s: 120, p: 4, alphabet: 4 };
        let mut wb = WordBuilder::new(&params);
        let words: Vec<SaxWord> = (0..stats.len())
            .map(|k| wb.word(ts.seq(k, 120), stats.mean[k], stats.std[k]))
            .collect();
        let rebuilt = SaxIndex::from_words(words);
        assert_eq!(rebuilt.words, idx.words);
        assert_eq!(rebuilt.cluster_of, idx.cluster_of);
        assert_eq!(rebuilt.clusters, idx.clusters);
        assert_eq!(rebuilt.by_size, idx.by_size);
    }

    #[test]
    fn members_in_time_order() {
        let (_, _, idx) = small_index();
        for members in &idx.clusters {
            for w in members.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }
}
