//! Gaussian breakpoints for SAX quantization.
//!
//! For an alphabet of size `a`, the breakpoints are the a−1 quantiles of the
//! standard normal that split it into `a` equiprobable regions — computed
//! here with the inverse normal CDF instead of a hard-coded table, so any
//! alphabet in 2..=20 works (the paper uses 3 and 4).

use crate::util::stats::inv_norm_cdf;

/// Breakpoints β_1 < … < β_{a−1} for alphabet size `a`.
pub fn breakpoints(alphabet: usize) -> Vec<f64> {
    assert!(
        (2..=20).contains(&alphabet),
        "alphabet must be in 2..=20, got {alphabet}"
    );
    (1..alphabet)
        .map(|i| inv_norm_cdf(i as f64 / alphabet as f64))
        .collect()
}

/// Quantize one PAA value into a symbol 0..alphabet-1.
#[inline]
pub fn symbolize(value: f64, beta: &[f64]) -> u8 {
    // binary search: first breakpoint > value
    match beta.binary_search_by(|b| b.partial_cmp(&value).unwrap()) {
        Ok(i) => (i + 1) as u8, // value == breakpoint goes to upper cell
        Err(i) => i as u8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alphabet3_matches_sax_table() {
        let b = breakpoints(3);
        assert_eq!(b.len(), 2);
        assert!((b[0] + 0.4307).abs() < 1e-3, "{}", b[0]);
        assert!((b[1] - 0.4307).abs() < 1e-3, "{}", b[1]);
    }

    #[test]
    fn alphabet4_matches_sax_table() {
        let b = breakpoints(4);
        // classic table: -0.67, 0, 0.67
        assert!((b[0] + 0.6745).abs() < 1e-3);
        assert!(b[1].abs() < 1e-9);
        assert!((b[2] - 0.6745).abs() < 1e-3);
    }

    #[test]
    fn breakpoints_monotone_for_all_alphabets() {
        for a in 2..=20 {
            let b = breakpoints(a);
            assert_eq!(b.len(), a - 1);
            for w in b.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn symbolize_cells() {
        let b = breakpoints(4);
        assert_eq!(symbolize(-2.0, &b), 0);
        assert_eq!(symbolize(-0.3, &b), 1);
        assert_eq!(symbolize(0.3, &b), 2);
        assert_eq!(symbolize(2.0, &b), 3);
        // boundary goes up
        assert_eq!(symbolize(b[1], &b), 2);
    }

    #[test]
    #[should_panic(expected = "alphabet")]
    fn rejects_tiny_alphabet() {
        breakpoints(1);
    }
}
