//! SAX MINDIST: the classic lower bound on z-normalized Euclidean distance.
//!
//! Not used by the search algorithms themselves (HOT SAX/HST only use SAX
//! to *order* the search), but it is the contract that makes SAX clusters
//! meaningful — "sequences belonging to the same SAX cluster can also be
//! Euclidean neighbors". The property tests verify
//! `MINDIST(ŵ_a, ŵ_b) <= d(a, b)` on random data, which pins down the
//! breakpoint table and PAA implementation.

use super::breakpoints::breakpoints;
use super::word::SaxWord;

/// Pairwise symbol distance table: dist(r, c) = 0 if |r−c| <= 1 else
/// β_{max(r,c)−1} − β_{min(r,c)} (Lin et al. 2003, Table 3).
pub fn cell_table(alphabet: usize) -> Vec<Vec<f64>> {
    let beta = breakpoints(alphabet);
    let mut t = vec![vec![0.0; alphabet]; alphabet];
    for (r, row) in t.iter_mut().enumerate() {
        for (c, v) in row.iter_mut().enumerate() {
            if r.abs_diff(c) > 1 {
                let hi = r.max(c);
                let lo = r.min(c);
                *v = beta[hi - 1] - beta[lo];
            }
        }
    }
    t
}

/// MINDIST between two SAX words of sequences of original length `s`.
pub fn mindist(a: &SaxWord, b: &SaxWord, s: usize, table: &[Vec<f64>]) -> f64 {
    assert_eq!(a.len(), b.len(), "words must share P");
    let p = a.len();
    let mut acc = 0.0;
    for (&sa, &sb) in a.symbols().iter().zip(b.symbols()) {
        let d = table[sa as usize][sb as usize];
        acc += d * d;
    }
    ((s as f64 / p as f64) * acc).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_words_have_zero_mindist() {
        let t = cell_table(4);
        let w = SaxWord::new(&[0, 1, 2, 3]);
        assert_eq!(mindist(&w, &w, 128, &t), 0.0);
    }

    #[test]
    fn adjacent_symbols_cost_zero() {
        let t = cell_table(4);
        let a = SaxWord::new(&[0, 1, 2, 3]);
        let b = SaxWord::new(&[1, 2, 3, 2]);
        assert_eq!(mindist(&a, &b, 128, &t), 0.0);
    }

    #[test]
    fn far_symbols_cost_positive_and_symmetric() {
        let t = cell_table(4);
        let a = SaxWord::new(&[0, 0, 0, 0]);
        let b = SaxWord::new(&[3, 3, 3, 3]);
        let d_ab = mindist(&a, &b, 128, &t);
        let d_ba = mindist(&b, &a, 128, &t);
        assert!(d_ab > 0.0);
        assert_eq!(d_ab, d_ba);
    }

    #[test]
    fn table_values_match_literature_alphabet4() {
        let t = cell_table(4);
        // dist(a, c) = beta_2 - beta_1 = 0 - (-0.6745) = 0.6745
        assert!((t[0][2] - 0.6745).abs() < 1e-3);
        // dist(a, d) = beta_3 - beta_1 = 0.6745 + 0.6745
        assert!((t[0][3] - 1.349).abs() < 2e-3);
        assert_eq!(t[1][2], 0.0);
    }

    #[test]
    fn grows_with_s() {
        let t = cell_table(4);
        let a = SaxWord::new(&[0, 0, 0, 0]);
        let b = SaxWord::new(&[3, 0, 0, 0]);
        assert!(mindist(&a, &b, 256, &t) > mindist(&a, &b, 64, &t));
    }
}
