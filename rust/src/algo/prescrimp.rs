//! preSCRIMP (Zhu et al., ICDM 2018): the approximate matrix-profile pass
//! the paper's Sec. 4.5 discusses as the anytime alternative to SCAMP.
//!
//! Instead of every diagonal, preSCRIMP evaluates anchor pairs on a
//! `stride`-spaced sample of positions and then *extends* each anchor
//! match forward/backward while it keeps improving the profile (the same
//! CNP property HST's time topology exploits). The result is an
//! approximate profile whose maxima usually coincide with the true
//! discords — but, as the paper notes for all approximate methods, with
//! no exactness guarantee; it serves as a baseline and as an ablation
//! reference for HST's warm-up quality.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::NndProfile;
use crate::dist::{Backend, DistanceKind};
use crate::ts::SeqStats;

use super::{brute::BruteForce, Algorithm, SearchReport};

/// The preSCRIMP engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct PreScrimp {
    /// Sampling stride (in sequences); the original uses s/4.
    /// 0 (the default) = auto (s/4).
    pub stride: usize,
}

impl PreScrimp {
    /// Approximate profile + pair-evaluation count, through the context's
    /// distance backend. Checks the context's run controls once per
    /// anchor.
    pub fn approx_profile(
        &self,
        ctx: &SearchContext,
        stats: &SeqStats,
        seed: u64,
    ) -> Result<(NndProfile, u64)> {
        let s = stats.s;
        let n = stats.len();
        let stride = if self.stride == 0 {
            (s / 4).max(1)
        } else {
            self.stride
        };
        let _ = seed; // sampling is deterministic; seed kept for API parity
        let dist = ctx.distance(stats, DistanceKind::Znorm);
        let mut profile = NndProfile::new(n);

        // anchor pass: each sampled i gets its nn among sampled js
        let samples: Vec<usize> = (0..n).step_by(stride).collect();
        for &i in &samples {
            ctx.check(dist.calls())?;
            // random subset of partners (anytime flavour): all samples here
            for &j in &samples {
                if i < j && j - i >= s {
                    let cutoff = profile.nnd[i].max(profile.nnd[j]);
                    let d = dist.dist_early(i, j, cutoff);
                    if d < cutoff {
                        profile.observe(i, j, d);
                    }
                }
            }
        }

        // extension pass: walk each anchor match diagonally while improving
        for &i in &samples {
            ctx.check(dist.calls())?;
            let g = profile.ngh[i];
            if g == crate::discord::NO_NEIGHBOR {
                continue;
            }
            for dir in [1isize, -1isize] {
                let mut step = 1isize;
                loop {
                    let t = i as isize + dir * step;
                    let c = g as isize + dir * step;
                    if t < 0 || c < 0 || t >= n as isize || c >= n as isize {
                        break;
                    }
                    let (t, c) = (t as usize, c as usize);
                    if t.abs_diff(c) < s {
                        break;
                    }
                    let old = profile.nnd[t];
                    let d = dist.dist_early(t, c, old);
                    if d < old {
                        profile.observe(t, c, d);
                    } else {
                        break; // diagonal stopped improving
                    }
                    step += 1;
                    if step as usize > stride {
                        break; // next anchor takes over
                    }
                }
            }
        }
        let calls = dist.calls();
        Ok((profile, calls))
    }
}

impl Algorithm for PreScrimp {
    fn name(&self) -> &'static str {
        "prescrimp"
    }

    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        let s = params.sax.s;
        let n = ctx.series().num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ensure!(params.znormalize, "preSCRIMP is z-normalized only");
        ctx.check(0)?;
        let start = Instant::now();
        ctx.notify_phase(self.name(), "prepare");
        let stats = ctx.stats(s);
        ctx.notify_phase(self.name(), "search");
        let (profile, calls) = self.approx_profile(ctx, &stats, params.seed)?;
        let discords = BruteForce::discords_from_profile(&profile, s, params.k);
        ctx.trace_pass(&crate::obs::PassEvent {
            engine: self.name(),
            phase: "search",
            index: 0,
            candidates: n as u64,
            abandons: 0,
            calls,
            best: discords.first().map(|d| d.nnd).unwrap_or(f64::NAN),
        });
        for (rank, d) in discords.iter().enumerate() {
            ctx.notify_discord(rank, d);
        }
        // the approximate profile is still a valid upper bound — merged
        // into the context cache (pointwise min) to warm later exact
        // searches. Scalar-backend contexts only, like every cache
        // feeder (a reduced-precision backend must not feed the cache).
        if ctx.backend() == Backend::Scalar {
            ctx.store_warm_profile(s, DistanceKind::Znorm, false, profile);
        }
        Ok(SearchReport {
            algo: self.name().to_string(),
            discords,
            distance_calls: calls,
            prep_calls: 0,
            elapsed: start.elapsed(),
            n_sequences: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::scamp::Scamp;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn profile_upper_bounds_exact_everywhere() {
        let ts = generators::ecg_like(1_500, 110, 1, 600).into_series("e");
        let s = 96;
        let stats = SeqStats::compute(&ts, s);
        let ctx = SearchContext::builder(&ts).build();
        let (approx, _) = PreScrimp::default()
            .approx_profile(&ctx, &stats, 1)
            .unwrap();
        let (exact, _) = Scamp::matrix_profile(&ts, &stats);
        for i in 0..exact.len() {
            assert!(
                approx.nnd[i] >= exact.nnd[i] - 5e-8,
                "i={i}: {} < exact {}",
                approx.nnd[i],
                exact.nnd[i]
            );
        }
    }

    #[test]
    fn far_cheaper_than_exact_profile() {
        let ts = generators::sine_with_noise(3_000, 0.1, 601).into_series("s");
        let s = 120;
        let stats = SeqStats::compute(&ts, s);
        let ctx = SearchContext::builder(&ts).build();
        let (_, approx_calls) = PreScrimp::default()
            .approx_profile(&ctx, &stats, 2)
            .unwrap();
        let (_, exact_pairs) = Scamp::matrix_profile(&ts, &stats);
        assert!(
            approx_calls * 10 < exact_pairs,
            "prescrimp {} vs scamp {}",
            approx_calls,
            exact_pairs
        );
    }

    #[test]
    fn usually_finds_a_strong_injected_discord() {
        let mut pts = generators::sine_with_noise(2_400, 0.05, 602);
        let mut rng = crate::util::rng::Rng64::new(3);
        generators::inject(&mut pts, 1_200, 96, generators::Anomaly::Bump, &mut rng);
        let ts = pts.into_series("bump");
        let params = SearchParams::new(96, 4, 4);
        let rep = PreScrimp::default().run(&ts, &params).unwrap();
        let d = &rep.discords[0];
        assert!(
            d.position.abs_diff(1_200 + 48) <= 144,
            "approx discord at {} should be near the bump",
            d.position
        );
    }
}
