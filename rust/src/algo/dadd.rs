//! DADD / DRAG — Disk-Aware Discord Discovery (Yankov, Keogh &
//! Rebbapragada, 2008), the Table 7 baseline.
//!
//! A two-phase range-threshold algorithm:
//!
//! * **Phase 1 (candidate selection)**: stream the sequences once keeping a
//!   candidate set C. Each incoming sequence x evicts every candidate
//!   closer than the *discord defining range* r; x joins C only if nothing
//!   in C was within r of it.
//! * **Phase 2 (refinement)**: stream again, tightening each surviving
//!   candidate's nnd (early-abandoning at r); candidates whose nnd drops
//!   below r are discarded. Survivors hold exact nnds ≥ r — the discords.
//!
//! The outcome (and cost) depends on r: too small floods phase 2, too
//! large loses discords (they simply cannot be found and the caller must
//! retry with smaller r — surfaced via [`DaddOutcome::missing`]).
//!
//! Protocol notes (paper Sec. 4.4): the reference DADD processes page-wise
//! raw (non-z-normalized) sequences with self-matches allowed; our
//! [`Dadd`] defaults to the standard discord protocol but honours
//! `SearchParams::dadd_protocol()` for the Table 7 reproduction. Pages are
//! emulated by streaming candidate evaluation in `page_size` chunks.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::Discord;
use crate::dist::Distance;

use super::{non_self_match, Algorithm, SearchReport};

/// The DADD engine. `r` must be supplied (the paper obtains it by sampling
/// or, for Table 7, from the exact nnd of the k-th discord).
#[derive(Debug, Clone)]
pub struct Dadd {
    /// Discord defining range.
    pub r: f64,
    /// Page size (sequences per streamed chunk).
    pub page_size: usize,
}

impl Default for Dadd {
    fn default() -> Dadd {
        Dadd {
            r: 0.0,
            page_size: 10_000,
        }
    }
}

/// Detailed outcome of a DADD run (beyond the generic report).
#[derive(Debug, Clone)]
pub struct DaddOutcome {
    /// Discords found (nnd >= r), best first.
    pub discords: Vec<Discord>,
    /// Number of candidates that survived phase 1.
    pub phase1_survivors: usize,
    /// True when fewer than k discords met the range (r was too big).
    pub missing: bool,
    /// Distance calls spent in phase 1 / phase 2 (deltas of the passed-in
    /// session, so callers sharing one session across runs still get
    /// per-run numbers). The trace layer reports these; they are not part
    /// of the search result.
    pub phase_calls: [u64; 2],
    /// Early-abandoned calls per phase (same delta accounting).
    pub phase_abandons: [u64; 2],
}

impl Dadd {
    /// Run both phases and return the detailed outcome. Checks the
    /// context's run controls once per streamed sequence (phase 1) and
    /// once per surviving candidate per page (phase 2).
    pub fn run_detailed(
        &self,
        ctx: &SearchContext,
        params: &SearchParams,
        dist: &dyn Distance,
    ) -> Result<DaddOutcome> {
        let s = params.sax.s;
        let n = ctx.series().num_sequences(s);
        let allow = params.allow_self_match;
        let r = self.r;

        // --- Phase 1: streaming candidate selection -------------------
        // `alive[c]` = candidate c not yet evicted.
        let calls_before = dist.calls();
        let abandons_before = dist.abandons();
        let mut cands: Vec<usize> = Vec::new();
        for x in 0..n {
            ctx.check(dist.calls())?;
            let mut is_cand = true;
            let mut w = 0;
            for ci in 0..cands.len() {
                let c = cands[ci];
                if c == x || !non_self_match(x, c, s, allow) {
                    cands[w] = c;
                    w += 1;
                    continue;
                }
                let d = dist.dist_early(x, c, r);
                if d < r {
                    // x and c are within r of each other: c is evicted and
                    // x cannot join (it has a neighbor within r).
                    is_cand = false;
                    // c dropped (not copied to the write cursor)
                } else {
                    cands[w] = c;
                    w += 1;
                }
            }
            cands.truncate(w);
            if is_cand {
                cands.push(x);
            }
        }
        let phase1_survivors = cands.len();
        let phase1_calls = dist.calls() - calls_before;
        let phase1_abandons = dist.abandons() - abandons_before;
        let calls_before = dist.calls();
        let abandons_before = dist.abandons();

        // --- Phase 2: refinement over page-sized chunks ----------------
        let mut nnd: Vec<f64> = vec![f64::INFINITY; cands.len()];
        let mut ngh: Vec<usize> = vec![usize::MAX; cands.len()];
        let mut alive: Vec<bool> = vec![true; cands.len()];
        let mut page_start = 0;
        while page_start < n {
            let page_end = (page_start + self.page_size).min(n);
            for (ci, &c) in cands.iter().enumerate() {
                if !alive[ci] {
                    continue;
                }
                ctx.check(dist.calls())?;
                for x in page_start..page_end {
                    if x == c || !non_self_match(x, c, s, allow) {
                        continue;
                    }
                    // abandon at min(current nnd, nothing below r matters
                    // except to prove c dead, so r also caps the work)
                    let cutoff = nnd[ci];
                    let d = dist.dist_early(c, x, cutoff);
                    if d < cutoff {
                        nnd[ci] = d;
                        ngh[ci] = x;
                        if d < r {
                            alive[ci] = false;
                            break;
                        }
                    }
                }
            }
            page_start = page_end;
        }

        // --- Extract top-k non-overlapping discords --------------------
        let mut pool: Vec<(usize, f64, usize)> = cands
            .iter()
            .enumerate()
            .filter(|&(ci, _)| alive[ci] && nnd[ci].is_finite() && nnd[ci] >= r)
            .map(|(ci, &c)| (c, nnd[ci], ngh[ci]))
            .collect();
        pool.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut discords: Vec<Discord> = Vec::new();
        for (pos, d_nnd, d_ngh) in pool {
            if discords
                .iter()
                .all(|d| d.position.abs_diff(pos) >= s)
            {
                discords.push(Discord {
                    position: pos,
                    nnd: d_nnd,
                    neighbor: d_ngh,
                });
                if discords.len() == params.k {
                    break;
                }
            }
        }
        let missing = discords.len() < params.k;
        Ok(DaddOutcome {
            discords,
            phase1_survivors,
            missing,
            phase_calls: [phase1_calls, dist.calls() - calls_before],
            phase_abandons: [phase1_abandons, dist.abandons() - abandons_before],
        })
    }
}

impl Algorithm for Dadd {
    fn name(&self) -> &'static str {
        "dadd"
    }

    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        let s = params.sax.s;
        let n = ctx.series().num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ensure!(self.r > 0.0, "DADD requires a positive range r");
        ctx.check(0)?;
        let start = Instant::now();
        ctx.notify_phase(self.name(), "prepare");
        let stats = ctx.stats(s);
        let dist = ctx.distance(&stats, params.distance_kind());
        ctx.notify_phase(self.name(), "search");
        let outcome = self.run_detailed(ctx, params, dist.as_ref())?;
        let best = outcome.discords.first().map(|d| d.nnd).unwrap_or(f64::NAN);
        let phase_candidates = [n as u64, outcome.phase1_survivors as u64];
        for phase in 0..2 {
            ctx.trace_pass(&crate::obs::PassEvent {
                engine: self.name(),
                phase: "search",
                index: phase,
                candidates: phase_candidates[phase],
                abandons: outcome.phase_abandons[phase],
                calls: outcome.phase_calls[phase],
                best: if phase == 1 { best } else { f64::NAN },
            });
        }
        for (rank, d) in outcome.discords.iter().enumerate() {
            ctx.notify_discord(rank, d);
        }
        Ok(SearchReport {
            algo: self.name().to_string(),
            discords: outcome.discords,
            distance_calls: dist.calls(),
            prep_calls: 0,
            elapsed: start.elapsed(),
            n_sequences: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::brute::BruteForce;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn finds_the_discord_given_exact_r() {
        let ts = generators::ecg_like(2_000, 110, 1, 70).into_series("e");
        let params = SearchParams::new(96, 4, 4);
        let truth = BruteForce.run(&ts, &params).unwrap();
        let r = truth.discords[0].nnd;
        let dadd = Dadd {
            r: r * 0.999,
            page_size: 500,
        };
        let rep = dadd.run(&ts, &params).unwrap();
        assert!(!rep.discords.is_empty());
        assert!(
            (rep.discords[0].nnd - truth.discords[0].nnd).abs() < 5e-8,
            "dadd {} vs brute {}",
            rep.discords[0].nnd,
            truth.discords[0].nnd
        );
    }

    #[test]
    fn too_large_r_reports_missing() {
        let ts = generators::valve_like(1_500, 150, 1, 71).into_series("v");
        let params = SearchParams::new(128, 4, 4);
        let truth = BruteForce.run(&ts, &params).unwrap();
        let dadd = Dadd {
            r: truth.discords[0].nnd * 2.0,
            page_size: 500,
        };
        let s = params.sax.s;
        let ctx = SearchContext::builder(&ts).build();
        let stats = ctx.stats(s);
        let dist = ctx.distance(&stats, crate::dist::DistanceKind::Znorm);
        let out = dadd.run_detailed(&ctx, &params, dist.as_ref()).unwrap();
        assert!(out.missing, "r above the discord nnd cannot find it");
    }

    #[test]
    fn smaller_r_costs_more_calls() {
        let ts = generators::respiration_like(2_500, 140, 1, 72).into_series("r");
        let params = SearchParams::new(128, 4, 4);
        let truth = BruteForce.run(&ts, &params).unwrap();
        let r = truth.discords[0].nnd;
        let tight = Dadd { r: r * 0.999, page_size: 1_000 }
            .run(&ts, &params)
            .unwrap();
        let loose = Dadd { r: r * 0.60, page_size: 1_000 }
            .run(&ts, &params)
            .unwrap();
        assert!(
            loose.distance_calls > tight.distance_calls,
            "r=0.6·nnd {} should cost more than r≈nnd {}",
            loose.distance_calls,
            tight.distance_calls
        );
    }

    #[test]
    fn table7_protocol_runs_raw_with_self_matches() {
        let ts = generators::ecg_like(1_200, 100, 1, 73).into_series("e");
        let params = SearchParams::new(64, 4, 4).dadd_protocol();
        let truth = BruteForce.run(&ts, &params).unwrap();
        let dadd = Dadd {
            r: truth.discords[0].nnd * 0.99,
            page_size: 300,
        };
        let rep = dadd.run(&ts, &params).unwrap();
        assert!(!rep.discords.is_empty());
        assert!((rep.discords[0].nnd - truth.discords[0].nnd).abs() < 5e-8);
    }

    #[test]
    fn requires_positive_r() {
        let ts = generators::ecg_like(600, 90, 1, 74).into_series("e");
        let params = SearchParams::new(64, 4, 4);
        assert!(Dadd::default().run(&ts, &params).is_err());
    }
}
