//! HOT SAX Time (HST) — the paper's exact discord-search algorithm.
//!
//! HST keeps HOT SAX's SAX-guided minimization but adds four devices
//! (paper Sec. 3):
//!
//! 1. **Warm-up** ([`warmup`]): a chain of ~N distance calls through the
//!    shuffled, size-ordered SAX clusters gives every sequence an
//!    approximate nnd *before* the first discord search begins.
//! 2. **Short-range time topology** ([`topology::short_range`]): the CNP
//!    property (`ngh(i±1) ≈ ngh(i)±1`) upgrades the warm-up profile with
//!    ~N more targeted calls.
//! 3. **Re-ordered, dynamic external loop**: sequences are visited in
//!    descending order of (moving-averaged) approximate nnd, and the
//!    remaining order is re-sorted every time a good discord candidate is
//!    confirmed.
//! 4. **Long-range time topology** ([`topology::long_range_forw`] /
//!    [`topology::long_range_back`]): after a
//!    candidate's clarification, its ≤ s time-neighbors (the rest of the
//!    nnd-profile *peak*) get their nnds lowered with ≤ 2s targeted calls,
//!    levelling the peak without independent inner loops.
//!
//! The approximate-nnd profile persists across the k-discord loop
//! (Sec. 3.2), which is where most of the k > 1 speedup comes from — and,
//! through the [`SearchContext`] warm-profile cache, across *searches*:
//! a second search on a warm context starts from the previous search's
//! refined profile and skips the warm-up entirely.
//!
//! [`par::HstPar`] (`hst-par`) is the sharded-parallel variant the paper
//! names as future work (Sec. 5): the outer candidate loop is split over
//! chunks of the SAX-ordered candidate sequence, every worker pruning
//! against a shared lock-free best-so-far bound, with results identical
//! to the serial engine.

pub mod par;
pub mod topology;
pub mod warmup;

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::{Discord, ExclusionZones, NndProfile};
use crate::dist::{CountingDistance, Distance};
use crate::sax::SaxIndex;
use crate::util::rng::Rng64;

use super::{non_self_match, Algorithm, SearchReport};

/// Tuning knobs (defaults follow the paper).
#[derive(Debug, Clone)]
pub struct HstSearch {
    /// Smear the initial external-loop order with the Eq. 6 moving average.
    pub smear_initial_order: bool,
    /// Run the long-range topology peak-levelling functions.
    pub long_range: bool,
    /// Re-sort the remaining external loop after each good candidate.
    pub dynamic_reorder: bool,
    /// Run the warm-up chain (disable only for ablations).
    pub warmup: bool,
    /// Run the short-range topology pass (disable only for ablations).
    pub short_range: bool,
}

impl Default for HstSearch {
    fn default() -> HstSearch {
        HstSearch {
            smear_initial_order: true,
            long_range: true,
            dynamic_reorder: true,
            warmup: true,
            short_range: true,
        }
    }
}

/// Per-pass cluster scan order: members of each cluster pre-shuffled once
/// (the paper's "pseudo-random order" of the inner loop).
pub(crate) struct ScanOrder {
    clusters: Vec<Vec<usize>>,
}

impl ScanOrder {
    pub(crate) fn build(idx: &SaxIndex, rng: &mut Rng64) -> ScanOrder {
        let mut clusters = idx.clusters.clone();
        for c in &mut clusters {
            rng.shuffle(c);
        }
        ScanOrder { clusters }
    }

    #[inline]
    fn cluster(&self, cid: usize) -> &[usize] {
        &self.clusters[cid]
    }
}

/// Where the inner loop reads its best-so-far pruning bound from: a plain
/// `f64` on the serial path, the shared [`exec::AtomicF64`] on the
/// `hst-par` path. Monomorphized, so the serial loop pays nothing.
///
/// [`exec::AtomicF64`]: crate::exec::AtomicF64
pub(crate) trait BoundSrc {
    /// The current best-so-far discord distance.
    fn get(&self) -> f64;
}

impl BoundSrc for f64 {
    #[inline]
    fn get(&self) -> f64 {
        *self
    }
}

impl BoundSrc for crate::exec::AtomicF64 {
    #[inline]
    fn get(&self) -> f64 {
        self.load()
    }
}

/// The inner minimization for candidate `i` (the HOT SAX inner loop with
/// profile maintenance): same-cluster first, then remaining clusters from
/// smallest to biggest. Returns `true` if `i` survived — in which case
/// `profile.nnd[i]` is its *exact* nnd. `best` is re-read at every step,
/// so a shared bound raised by another worker aborts the loop as early as
/// a serial bound would.
#[allow(clippy::too_many_arguments)]
pub(crate) fn minimize<B: BoundSrc>(
    i: usize,
    dist: &dyn Distance,
    idx: &SaxIndex,
    scan: &ScanOrder,
    profile: &mut NndProfile,
    best: &B,
    s: usize,
    allow: bool,
) -> bool {
    let own = idx.cluster_of[i];

    // Current_cluster(): the candidate's own SAX cluster.
    for &j in scan.cluster(own) {
        if i == j || !non_self_match(i, j, s, allow) {
            continue;
        }
        let cutoff = profile.nnd[i].max(profile.nnd[j]);
        let d = dist.dist_early(i, j, cutoff);
        if d < cutoff {
            profile.observe(i, j, d); // exact evaluation
        }
        if profile.nnd[i] < best.get() {
            return false; // cannot be a discord
        }
    }

    // Other_clusters(): smallest clusters first.
    for &cid in &idx.by_size {
        if cid == own {
            continue;
        }
        for &j in scan.cluster(cid) {
            if !non_self_match(i, j, s, allow) {
                continue;
            }
            let cutoff = profile.nnd[i].max(profile.nnd[j]);
            let d = dist.dist_early(i, j, cutoff);
            if d < cutoff {
                profile.observe(i, j, d);
            }
            if profile.nnd[i] < best.get() {
                return false;
            }
        }
    }
    true
}

/// Sort `slice` by descending profile nnd (ties by index for
/// determinism). Shared with [`par::HstPar`] and the multivariate
/// [`mdim`](crate::mdim) engines.
pub(crate) fn sort_by_nnd_desc(slice: &mut [usize], key: &[f64]) {
    slice.sort_unstable_by(|&a, &b| {
        key[b]
            .partial_cmp(&key[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
}

impl HstSearch {
    /// Run one external-loop pass: find the best discord not excluded by
    /// `zones`, given (and refining) the shared approximate profile.
    #[allow(clippy::too_many_arguments)]
    fn pass(
        &self,
        ctx: &SearchContext,
        dist: &dyn Distance,
        idx: &SaxIndex,
        profile: &mut NndProfile,
        zones: &ExclusionZones,
        params: &SearchParams,
        rng: &mut Rng64,
        first_pass: bool,
    ) -> Result<Option<Discord>> {
        let s = params.sax.s;
        let n = idx.len();
        let allow = params.allow_self_match;
        let scan = ScanOrder::build(idx, rng);

        // Sort_External(): candidates in descending approximate-nnd order.
        // First pass smears with the Eq. 6 moving average to kill lone
        // spikes; later passes use the (now much better) raw profile.
        let mut order: Vec<usize> =
            (0..n).filter(|&i| zones.allowed(i, s)).collect();
        let initial_key: Vec<f64> = if first_pass && self.smear_initial_order {
            profile.smeared(s)
        } else {
            profile.nnd.clone()
        };
        sort_by_nnd_desc(&mut order, &initial_key);

        let mut best_dist = 0.0f64;
        let mut best: Option<Discord> = None;

        let mut pos = 0;
        while pos < order.len() {
            let i = order[pos];
            pos += 1;
            ctx.check(dist.calls())?;

            // Avoid_low_nnds(): the carried-over approximate nnd already
            // rules most sequences out.
            let mut can_be_discord = profile.nnd[i] >= best_dist;

            if can_be_discord {
                can_be_discord =
                    minimize(i, dist, idx, &scan, profile, &best_dist, s, allow);
            }

            // Long-range topology: level the peak around i (Listing 2 runs
            // these regardless of can_be_discord).
            if self.long_range {
                topology::long_range_forw(i, dist, profile, best_dist, n, s, allow);
                topology::long_range_back(i, dist, profile, best_dist, n, s, allow);
            }

            // A sequence with no admissible comparison partner keeps the ∞
            // sentinel; its nnd is undefined, so (like the other engines)
            // it cannot be reported as a discord.
            if can_be_discord && profile.nnd[i].is_finite() {
                // i is a good discord candidate: nnd[i] is exact and at
                // least ties the highest exact value so far. Exact ties
                // keep the lowest index — the same deterministic rule the
                // parallel merge applies, so `hst` and `hst-par` agree
                // even on tied candidates (e.g. a duplicated anomaly).
                let nnd_i = profile.nnd[i];
                let better = match &best {
                    None => true,
                    Some(b) => {
                        nnd_i > b.nnd || (nnd_i == b.nnd && i < b.position)
                    }
                };
                if better {
                    best_dist = nnd_i;
                    best = Some(Discord {
                        position: i,
                        nnd: nnd_i,
                        neighbor: profile.ngh[i],
                    });
                    // Sort_Remaining_Ext(): the inner loop just touched
                    // almost every sequence — re-aim the external loop.
                    if self.dynamic_reorder {
                        sort_by_nnd_desc(&mut order[pos..], &profile.nnd);
                    }
                }
            }
        }
        Ok(best)
    }
}

impl HstSearch {
    /// The full serial search, reporting under `algo_name`. Shared by the
    /// serial engine and by [`par::HstPar`] when it resolves to a single
    /// worker (one thread ⇒ the serial algorithm, bit-identical calls
    /// included). `scalar_only` forces the exact scalar distance backend
    /// regardless of the context's configured backend — `hst-par` sets it
    /// so its results do not depend on the resolved thread count even on
    /// an XLA-backed context (its ≥ 2-worker path is always scalar).
    pub(crate) fn run_serial(
        &self,
        ctx: &SearchContext,
        params: &SearchParams,
        algo_name: &'static str,
        scalar_only: bool,
    ) -> Result<SearchReport> {
        let s = params.sax.s;
        let n = ctx.series().num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ctx.check(0)?;
        let start = Instant::now();
        ctx.notify_phase(algo_name, "prepare");
        let kind = params.distance_kind();
        let (stats, idx) = ctx.prepared(&params.sax);
        let dist: Box<dyn Distance + '_> = if scalar_only {
            Box::new(CountingDistance::with_kernel(
                ctx.series(),
                &stats,
                kind,
                ctx.kernel(),
            ))
        } else {
            ctx.distance(&stats, kind)
        };
        let dist: &dyn Distance = dist.as_ref();
        let mut rng = Rng64::new(params.seed ^ 0x4853_5400); // "HST"

        // Warm start: any profile an earlier search on this context left
        // behind is a valid upper bound of every exact nnd, so the
        // warm-up chain + short-range topology (~2 calls per sequence)
        // are only paid on a cold context. The cache only serves exact
        // (scalar-compatible) sessions: reduced-precision backends must
        // neither trust nor feed it.
        let mut prep_calls = 0u64;
        let cached = if dist.is_exact() {
            ctx.warm_profile(s, kind, params.allow_self_match)
        } else {
            None
        };
        let mut profile = match cached {
            Some(p) if p.len() == n => p,
            _ => {
                let before = dist.calls();
                let mut p = NndProfile::new(n);
                if self.warmup {
                    warmup::warmup(dist, &idx, &mut p, s, params.allow_self_match, &mut rng);
                }
                if self.short_range {
                    topology::short_range(dist, &mut p, n, s, params.allow_self_match);
                }
                prep_calls = dist.calls() - before;
                p
            }
        };
        // The bounded (~2N-call) preparation runs to completion; budget
        // and cancellation take effect from this checkpoint on.
        ctx.check(dist.calls())?;
        ctx.trace_pass(&crate::obs::PassEvent {
            engine: algo_name,
            phase: "prepare",
            index: 0,
            candidates: n as u64,
            abandons: dist.abandons(),
            calls: prep_calls,
            best: f64::NAN,
        });

        ctx.notify_phase(algo_name, "search");
        let mut zones = ExclusionZones::new();
        let mut discords = Vec::new();
        for ki in 0..params.k {
            let calls_before = dist.calls();
            let abandons_before = dist.abandons();
            let found =
                self.pass(ctx, dist, &idx, &mut profile, &zones, params, &mut rng, ki == 0)?;
            ctx.trace_pass(&crate::obs::PassEvent {
                engine: algo_name,
                phase: "search",
                index: ki,
                candidates: n as u64,
                abandons: dist.abandons() - abandons_before,
                calls: dist.calls() - calls_before,
                best: found.as_ref().map(|d| d.nnd).unwrap_or(f64::NAN),
            });
            match found {
                Some(d) => {
                    zones.add(d.position, s);
                    ctx.notify_discord(ki, &d);
                    discords.push(d);
                }
                None => break,
            }
        }

        // Leave the refined profile behind for the next search on this
        // context (Sec. 3.2's carry-over, extended across searches).
        if dist.is_exact() {
            ctx.store_warm_profile(s, kind, params.allow_self_match, profile);
        }

        Ok(SearchReport {
            algo: algo_name.to_string(),
            discords,
            distance_calls: dist.calls(),
            prep_calls,
            elapsed: start.elapsed(),
            n_sequences: n,
        })
    }
}

impl Algorithm for HstSearch {
    fn name(&self) -> &'static str {
        "hst"
    }

    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        self.run_serial(ctx, params, self.name(), false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::brute::BruteForce;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;
    use crate::ts::TimeSeries;

    fn agree_with_brute(ts: &TimeSeries, params: &SearchParams) {
        let hst = HstSearch::default().run(ts, params).unwrap();
        let bf = BruteForce.run(ts, params).unwrap();
        assert_eq!(hst.discords.len(), bf.discords.len());
        for (h, b) in hst.discords.iter().zip(&bf.discords) {
            assert!(
                (h.nnd - b.nnd).abs() < 5e-8,
                "nnd mismatch: {} vs {} (pos {} vs {})",
                h.nnd,
                b.nnd,
                h.position,
                b.position
            );
        }
    }

    #[test]
    fn exact_on_ecg() {
        let ts = generators::ecg_like(1_500, 100, 1, 21).into_series("e");
        agree_with_brute(&ts, &SearchParams::new(80, 4, 4));
    }

    #[test]
    fn exact_on_low_noise_sine() {
        // the regime where HOT SAX struggles (Table 4)
        let ts = generators::sine_with_noise(1_200, 0.0001, 31).into_series("s");
        agree_with_brute(&ts, &SearchParams::new(64, 4, 4));
    }

    #[test]
    fn exact_on_high_noise_sine() {
        let ts = generators::sine_with_noise(1_200, 10.0, 32).into_series("s");
        agree_with_brute(&ts, &SearchParams::new(64, 4, 4));
    }

    #[test]
    fn exact_on_five_discords() {
        let ts = generators::valve_like(2_200, 150, 2, 33).into_series("v");
        agree_with_brute(&ts, &SearchParams::new(100, 4, 4).with_discords(5));
    }

    #[test]
    fn exact_with_every_feature_disabled() {
        // ablation sanity: each device is an optimization, not a
        // correctness requirement.
        let ts = generators::ecg_like(1_200, 90, 1, 34).into_series("e");
        let params = SearchParams::new(72, 4, 4).with_discords(2);
        let plain = HstSearch {
            smear_initial_order: false,
            long_range: false,
            dynamic_reorder: false,
            warmup: false,
            short_range: false,
        };
        let a = plain.run(&ts, &params).unwrap();
        let b = BruteForce.run(&ts, &params).unwrap();
        for (x, y) in a.discords.iter().zip(&b.discords) {
            assert!((x.nnd - y.nnd).abs() < 5e-8);
        }
    }

    #[test]
    fn beats_hotsax_on_low_noise() {
        use crate::algo::hotsax::HotSax;
        let ts = generators::sine_with_noise(4_000, 0.001, 35).into_series("s");
        let params = SearchParams::new(120, 4, 4);
        let hst = HstSearch::default().run(&ts, &params).unwrap();
        let hs = HotSax.run(&ts, &params).unwrap();
        assert!(
            hst.distance_calls < hs.distance_calls,
            "hst {} vs hotsax {}",
            hst.distance_calls,
            hs.distance_calls
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let ts = generators::respiration_like(2_500, 140, 1, 36).into_series("r");
        let params = SearchParams::new(128, 4, 4).with_seed(5).with_discords(3);
        let a = HstSearch::default().run(&ts, &params).unwrap();
        let b = HstSearch::default().run(&ts, &params).unwrap();
        assert_eq!(a.distance_calls, b.distance_calls);
        assert_eq!(
            a.discords.iter().map(|d| d.position).collect::<Vec<_>>(),
            b.discords.iter().map(|d| d.position).collect::<Vec<_>>()
        );
    }

    #[test]
    fn profile_stays_upper_bound_of_exact() {
        // after a full run, every profile value must be >= the exact nnd
        // (approximate nnds are upper bounds by construction)
        use crate::dist::{CountingDistance, DistanceKind};
        let ts = generators::ecg_like(900, 80, 1, 37).into_series("e");
        let params = SearchParams::new(64, 4, 4);
        let s = params.sax.s;
        let ctx = SearchContext::builder(&ts).build();
        let stats = crate::ts::SeqStats::compute(&ts, s);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let idx = SaxIndex::build(&ts, &stats, &params.sax);
        let mut rng = Rng64::new(1);
        let mut profile = NndProfile::new(idx.len());
        warmup::warmup(&dist, &idx, &mut profile, s, false, &mut rng);
        topology::short_range(&dist, &mut profile, idx.len(), s, false);
        let exact = BruteForce::exact_profile(&ctx, &params, &dist).unwrap();
        for i in 0..idx.len() {
            assert!(
                profile.nnd[i] >= exact.nnd[i] - 5e-8,
                "i={i}: approx {} < exact {}",
                profile.nnd[i],
                exact.nnd[i]
            );
        }
    }

    #[test]
    fn warm_context_reuses_the_refined_profile() {
        let ts = generators::ecg_like(1_400, 100, 1, 38).into_series("e");
        let params = SearchParams::new(80, 4, 4);
        let ctx = SearchContext::builder(&ts).build();
        let cold = HstSearch::default().run_ctx(&ctx, &params).unwrap();
        let warm = HstSearch::default().run_ctx(&ctx, &params).unwrap();
        assert!(cold.prep_calls > 0, "cold run pays the warm-up");
        assert_eq!(warm.prep_calls, 0, "warm run must not re-prepare");
        // both are exact: same discord, same nnd
        assert_eq!(cold.discords[0].position, warm.discords[0].position);
        assert!((cold.discords[0].nnd - warm.discords[0].nnd).abs() < 5e-8);
        // and the one-shot path agrees
        let oneshot = HstSearch::default().run(&ts, &params).unwrap();
        assert_eq!(oneshot.discords[0].position, cold.discords[0].position);
    }
}
