//! `hst-par` — sharded-parallel HST (the paper's Sec. 5 follow-up:
//! "Parallelizing HST is also a natural follow up of the present work").
//!
//! The decomposition follows the HOTSAX-family GPU work (Zymbler &
//! Kraeva's PD3 shards the candidate/pruning loops over segments; SCAMP
//! splits diagonals across thread blocks): the **outer candidate loop**
//! of each discord pass is split over chunks of the SAX-ordered candidate
//! sequence and executed by the [`exec`](crate::exec) worker pool.
//!
//! Per pass:
//!
//! 1. **Seed** — the first candidate (the highest approximate nnd) is
//!    minimized serially, exactly like serial HST's first outer step.
//!    Its exact nnd initializes the shared best-so-far bound, so no
//!    worker ever starts pruning against an empty bound (the cold-bound
//!    stampede would otherwise make every worker minimize in full).
//! 2. **Shard** — the remaining candidates are claimed chunk-by-chunk
//!    from a [`ChunkQueue`](crate::exec::ChunkQueue). Each worker owns a
//!    clone of the nnd profile, its own
//!    [`CountingDistance`](crate::dist::CountingDistance) session, and
//!    prunes against the shared [`AtomicF64`](crate::exec::AtomicF64)
//!    bound, re-read inside the inner loop; survivors publish their exact
//!    nnd with a CAS-max.
//! 3. **Merge** — worker profiles fold into the master by pointwise min
//!    (in worker order), call counters are summed (exact accounting), and
//!    the discord is the max exact nnd with ties broken by lowest index.
//!
//! **Result determinism.** The reported discord *positions and
//! distances* are independent of scheduling: a candidate is only ever
//! discarded when its nnd upper bound drops *strictly* below an exact
//! nnd achieved by another candidate of the same pass, so the global
//! maximum always survives with its exact (bit-identical to serial)
//! distance, for any thread count and any interleaving. Two caveats at
//! ≥ 2 workers: distance-call *counts* depend on how fast the bound
//! propagates and may vary run to run (they are always the exact sum of
//! the per-worker counters), and when a discord's nnd is attained by
//! several neighbors at *bit-equal* distance, the reported `neighbor`
//! may be any of them (which worker's observation wins the merge is
//! timing-dependent; the nnd value itself is unaffected). With one
//! resolved worker the engine runs the serial algorithm unchanged
//! (bit-identical calls too, on the scalar backend).
//!
//! The parallel workers always run the scalar distance backend (each
//! worker needs a private counter; the scalar backend is exact, so warm
//! profiles interoperate with serial `hst` through the
//! [`SearchContext`](crate::context::SearchContext) cache in both
//! directions).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::{Discord, ExclusionZones, NndProfile};
use crate::dist::CountingDistance;
use crate::exec::{AtomicF64, ChunkQueue, ExecPolicy};
use crate::sax::SaxIndex;
use crate::ts::{SeqStats, TimeSeries};
use crate::util::rng::Rng64;

use super::super::parallel::par_warmup_profile;
use super::super::{Algorithm, SearchReport};
use super::{minimize, sort_by_nnd_desc, topology, HstSearch, ScanOrder};

/// The sharded-parallel HST engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct HstPar {
    /// Worker threads. `0` (the default) falls through to
    /// [`SearchParams::threads`], then the `HST_THREADS` environment
    /// variable, then the machine's available parallelism
    /// (the [`ExecPolicy`] resolution order).
    ///
    /// [`SearchParams::threads`]: crate::config::SearchParams::threads
    pub threads: usize,
}

/// One worker's contribution to a pass: its refined profile copy, the
/// candidates it confirmed (position, exact nnd), and its distance calls.
type WorkerOutcome = Result<(NndProfile, Vec<(usize, f64)>, u64)>;

impl HstPar {
    fn resolve_threads(&self, params: &SearchParams) -> usize {
        let requested = if self.threads > 0 {
            self.threads
        } else {
            params.threads
        };
        ExecPolicy::new(requested).resolve()
    }

    /// One parallel external-loop pass: find the best discord not excluded
    /// by `zones`. Returns the discord (if any) and the pass's exact
    /// distance-call total (sum of the seed phase and every worker).
    #[allow(clippy::too_many_arguments)] // mirrors the serial pass signature
    fn pass_par(
        &self,
        ctx: &SearchContext,
        ts: &TimeSeries,
        stats: &SeqStats,
        idx: &SaxIndex,
        profile: &mut NndProfile,
        zones: &ExclusionZones,
        params: &SearchParams,
        rng: &mut Rng64,
        first_pass: bool,
        threads: usize,
        published: &AtomicU64,
    ) -> Result<(Option<Discord>, u64)> {
        let s = params.sax.s;
        let n = idx.len();
        let allow = params.allow_self_match;
        let kind = params.distance_kind();
        let scan = ScanOrder::build(idx, rng);

        // Sort_External(), exactly as the serial pass.
        let mut order: Vec<usize> =
            (0..n).filter(|&i| zones.allowed(i, s)).collect();
        let initial_key: Vec<f64> = if first_pass {
            profile.smeared(s)
        } else {
            profile.nnd.clone()
        };
        sort_by_nnd_desc(&mut order, &initial_key);
        let Some(&lead) = order.first() else {
            return Ok((None, 0));
        };

        // Phase 1 — seed: minimize the top candidate serially on the
        // master profile (serial HST's first outer step verbatim).
        let kernel = ctx.kernel();
        let seed_dist = CountingDistance::with_kernel(ts, stats, kind, kernel);
        let lead_ok =
            minimize(lead, &seed_dist, idx, &scan, profile, &0.0f64, s, allow);
        topology::long_range_forw(lead, &seed_dist, profile, 0.0, n, s, allow);
        topology::long_range_back(lead, &seed_dist, profile, 0.0, n, s, allow);
        let mut best: Option<(usize, f64)> = (lead_ok
            && profile.nnd[lead].is_finite())
        .then_some((lead, profile.nnd[lead]));
        let mut pass_calls = seed_dist.calls();
        published.fetch_add(pass_calls, Ordering::Relaxed);
        ctx.check(published.load(Ordering::Relaxed))?;

        // Phase 2 — shard the remaining candidates across the pool.
        let rest = &order[1..];
        if !rest.is_empty() {
            let bound = AtomicF64::new(best.map_or(0.0, |(_, nnd)| nnd));
            let chunk = (rest.len() / (threads * 8)).clamp(16, 1024);
            let queue = ChunkQueue::new(rest, chunk);
            let master: &NndProfile = profile;

            let outcomes: Vec<WorkerOutcome> =
                crate::exec::scope_workers(threads, |_w| {
                    let dist =
                        CountingDistance::with_kernel(ts, stats, kind, kernel);
                    let mut local = master.clone();
                    let mut winners: Vec<(usize, f64)> = Vec::new();
                    let mut reported = 0u64;
                    while let Some((_ci, slice)) = queue.take() {
                        for &i in slice {
                            // exact global accounting at checkpoint
                            // granularity: publish this session's delta,
                            // then enforce budget/cancellation on the sum
                            let delta = dist.calls() - reported;
                            reported = dist.calls();
                            let total = published
                                .fetch_add(delta, Ordering::Relaxed)
                                + delta;
                            ctx.check(total)?;

                            // Avoid_low_nnds() against the shared bound.
                            let mut can = local.nnd[i] >= bound.load();
                            if can {
                                can = minimize(
                                    i, &dist, idx, &scan, &mut local, &bound,
                                    s, allow,
                                );
                            }
                            topology::long_range_forw(
                                i,
                                &dist,
                                &mut local,
                                bound.load(),
                                n,
                                s,
                                allow,
                            );
                            topology::long_range_back(
                                i,
                                &dist,
                                &mut local,
                                bound.load(),
                                n,
                                s,
                                allow,
                            );
                            if can && local.nnd[i].is_finite() {
                                // exact nnd: publish so every other worker
                                // prunes against it immediately
                                bound.fetch_max(local.nnd[i]);
                                winners.push((i, local.nnd[i]));
                            }
                        }
                    }
                    published.fetch_add(
                        dist.calls() - reported,
                        Ordering::Relaxed,
                    );
                    Ok((local, winners, dist.calls()))
                });

            // Phase 3 — ordered merge (worker 0 first): deterministic
            // profile fold, exact call sum, lowest-index tie-break.
            for outcome in outcomes {
                let (local, winners, calls) = outcome?;
                profile.merge_min(&local);
                pass_calls += calls;
                for (i, nnd) in winners {
                    best = match best {
                        None => Some((i, nnd)),
                        Some((bi, bn)) if nnd > bn || (nnd == bn && i < bi) => {
                            Some((i, nnd))
                        }
                        keep => keep,
                    };
                }
            }
        }

        let found = best.map(|(i, nnd)| Discord {
            position: i,
            nnd,
            neighbor: profile.ngh[i],
        });
        Ok((found, pass_calls))
    }
}

impl Algorithm for HstPar {
    fn name(&self) -> &'static str {
        "hst-par"
    }

    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        let threads = self.resolve_threads(params);
        if threads <= 1 {
            // one worker ⇒ the serial algorithm, bit-identical calls too;
            // scalar_only keeps the backend independent of the thread
            // count (the ≥ 2-worker path is always scalar)
            return HstSearch::default()
                .run_serial(ctx, params, self.name(), true);
        }

        let s = params.sax.s;
        let ts = ctx.series();
        let n = ts.num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ctx.check(0)?;
        let start = Instant::now();
        ctx.notify_phase(self.name(), "prepare");
        let kind = params.distance_kind();
        let (stats, idx) = ctx.prepared(&params.sax);
        let stats: &SeqStats = &stats;
        let mut rng = Rng64::new(params.seed ^ 0x4853_5400); // "HST"

        // Warm start mirrors serial hst: the scalar workers are exact, so
        // the context's warm-profile cache serves (and is fed by) both
        // engines interchangeably. A cold context pays the parallel
        // warm-up + short-range topology instead of the serial one.
        let mut prep_calls = 0u64;
        let mut profile = match ctx.warm_profile(s, kind, params.allow_self_match)
        {
            Some(p) if p.len() == n => p,
            _ => {
                let (p, calls) = par_warmup_profile(
                    ts,
                    stats,
                    &idx,
                    params,
                    threads,
                    ctx.kernel(),
                );
                prep_calls = calls;
                p
            }
        };
        let published = AtomicU64::new(prep_calls);
        ctx.check(prep_calls)?;
        ctx.trace_pass(&crate::obs::PassEvent {
            engine: self.name(),
            phase: "prepare",
            index: 0,
            candidates: n as u64,
            // per-worker abandon counters are not merged across the pool
            abandons: 0,
            calls: prep_calls,
            best: f64::NAN,
        });

        ctx.notify_phase(self.name(), "search");
        let mut zones = ExclusionZones::new();
        let mut discords = Vec::new();
        let mut total_calls = prep_calls;
        for ki in 0..params.k {
            let (found, calls) = self.pass_par(
                ctx,
                ts,
                stats,
                &idx,
                &mut profile,
                &zones,
                params,
                &mut rng,
                ki == 0,
                threads,
                &published,
            )?;
            total_calls += calls;
            ctx.trace_pass(&crate::obs::PassEvent {
                engine: self.name(),
                phase: "search",
                index: ki,
                candidates: n as u64,
                abandons: 0,
                calls,
                best: found.as_ref().map(|d| d.nnd).unwrap_or(f64::NAN),
            });
            match found {
                Some(d) => {
                    zones.add(d.position, s);
                    ctx.notify_discord(ki, &d);
                    discords.push(d);
                }
                None => break,
            }
        }

        // Scalar workers are exact: leave the refined profile behind for
        // the next search (serial or parallel) on this context.
        ctx.store_warm_profile(s, kind, params.allow_self_match, profile);

        Ok(SearchReport {
            algo: self.name().to_string(),
            discords,
            distance_calls: total_calls,
            prep_calls,
            elapsed: start.elapsed(),
            n_sequences: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::brute::BruteForce;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn matches_serial_hst_across_thread_counts() {
        let ts = generators::ecg_like(1_600, 100, 1, 91).into_series("e");
        let params = SearchParams::new(80, 4, 4).with_discords(3);
        let serial = HstSearch::default().run(&ts, &params).unwrap();
        for threads in [1usize, 2, 4] {
            let par = HstPar { threads }.run(&ts, &params).unwrap();
            assert_eq!(par.algo, "hst-par");
            assert_eq!(
                par.discords.len(),
                serial.discords.len(),
                "threads={threads}"
            );
            for (p, q) in par.discords.iter().zip(&serial.discords) {
                assert_eq!(p.position, q.position, "threads={threads}");
                assert_eq!(
                    p.nnd.to_bits(),
                    q.nnd.to_bits(),
                    "threads={threads}: {} vs {}",
                    p.nnd,
                    q.nnd
                );
            }
            assert!(par.distance_calls > 0);
            if threads == 1 {
                assert_eq!(
                    par.distance_calls, serial.distance_calls,
                    "one worker must be the serial algorithm verbatim"
                );
            }
        }
    }

    #[test]
    fn exact_against_brute_force() {
        let ts = generators::valve_like(1_400, 130, 1, 92).into_series("v");
        let params =
            SearchParams::new(96, 4, 4).with_discords(2).with_threads(3);
        let par = HstPar::default().run(&ts, &params).unwrap();
        let bf = BruteForce.run(&ts, &params).unwrap();
        assert_eq!(par.discords.len(), bf.discords.len());
        for (p, b) in par.discords.iter().zip(&bf.discords) {
            assert!(
                (p.nnd - b.nnd).abs() < 5e-8,
                "{} vs {}",
                p.nnd,
                b.nnd
            );
        }
    }

    #[test]
    fn warm_context_serves_both_directions() {
        let ts = generators::respiration_like(1_800, 120, 1, 93).into_series("r");
        let params = SearchParams::new(96, 4, 4);
        // hst warms the context, hst-par reuses it …
        let ctx = SearchContext::builder(&ts).build();
        let cold = HstSearch::default().run_ctx(&ctx, &params).unwrap();
        let warm = HstPar { threads: 2 }.run_ctx(&ctx, &params).unwrap();
        assert!(cold.prep_calls > 0);
        assert_eq!(warm.prep_calls, 0, "hst-par must reuse hst's profile");
        assert_eq!(cold.discords[0].position, warm.discords[0].position);
        // … and the other way around
        let ctx2 = SearchContext::builder(&ts).build();
        let cold2 = HstPar { threads: 2 }.run_ctx(&ctx2, &params).unwrap();
        let warm2 = HstSearch::default().run_ctx(&ctx2, &params).unwrap();
        assert!(cold2.prep_calls > 0);
        assert_eq!(warm2.prep_calls, 0, "hst must reuse hst-par's profile");
        assert_eq!(cold2.discords[0].position, warm2.discords[0].position);
    }

    #[test]
    fn cancellation_propagates_from_workers() {
        use crate::context::CancellationToken;
        let ts = generators::sine_with_noise(1_500, 0.4, 94).into_series("s");
        let token = CancellationToken::new();
        token.cancel();
        let ctx = SearchContext::builder(&ts).cancel_token(token).build();
        let err = HstPar { threads: 2 }
            .run_ctx(&ctx, &SearchParams::new(64, 4, 4))
            .unwrap_err();
        assert!(err.to_string().contains("cancelled"), "{err}");
    }

    #[test]
    fn dadd_protocol_is_supported() {
        let ts = generators::ecg_like(1_200, 90, 1, 95).into_series("e");
        let params = SearchParams::new(64, 4, 4).dadd_protocol();
        let serial = HstSearch::default().run(&ts, &params).unwrap();
        let par = HstPar { threads: 2 }.run(&ts, &params).unwrap();
        assert_eq!(par.discords[0].position, serial.discords[0].position);
        assert_eq!(par.discords[0].nnd.to_bits(), serial.discords[0].nnd.to_bits());
    }
}
