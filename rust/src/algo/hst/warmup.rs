//! The HST warm-up procedure (paper Sec. 3.3, Fig. 1 left).
//!
//! Builds the first approximate nnd profile with ~N distance calls:
//!
//! 1. shuffle the members of every SAX cluster (avoids chains of
//!    time-consecutive sequences, which would all be self-matches);
//! 2. concatenate the clusters from the smallest to the biggest;
//! 3. walk the resulting order calling the distance function between each
//!    pair of consecutive sequences — the last sequence of a cluster is
//!    coupled with the first of the next — skipping self-match pairs.
//!
//! Every computed distance upper-bounds the nnd of *both* endpoints, so
//! after the walk almost every sequence has a finite approximate nnd;
//! sequences whose links were all self-matches keep the ∞ sentinel ("no
//! possible discord candidate is neglected").

use crate::discord::NndProfile;
use crate::dist::Distance;
use crate::sax::SaxIndex;
use crate::util::rng::Rng64;

use crate::algo::non_self_match;

/// Run the warm-up chain over `profile`.
pub fn warmup(
    dist: &dyn Distance,
    idx: &SaxIndex,
    profile: &mut NndProfile,
    s: usize,
    allow_self_match: bool,
    rng: &mut Rng64,
) {
    let mut prev: Option<usize> = None;
    for &cid in &idx.by_size {
        let mut members = idx.clusters[cid].clone();
        rng.shuffle(&mut members);
        for seq in members {
            if let Some(p) = prev {
                if non_self_match(p, seq, s, allow_self_match) {
                    let d = dist.dist(p, seq);
                    profile.observe(p, seq, d);
                }
            }
            prev = Some(seq);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SearchParams;
    use crate::dist::{CountingDistance, DistanceKind};
    use crate::ts::series::IntoSeries;
    use crate::ts::{generators, SeqStats};

    fn setup(
        n: usize,
        s: usize,
    ) -> (crate::ts::TimeSeries, SeqStats, SearchParams) {
        let ts = generators::ecg_like(n, 90, 1, 50).into_series("e");
        let stats = SeqStats::compute(&ts, s);
        let params = SearchParams::new(s, 4, 4);
        (ts, stats, params)
    }

    #[test]
    fn costs_about_one_call_per_sequence() {
        let (ts, stats, params) = setup(3_000, 100);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let idx = SaxIndex::build(&ts, &stats, &params.sax);
        let mut profile = NndProfile::new(idx.len());
        let mut rng = Rng64::new(0);
        warmup(&dist, &idx, &mut profile, 100, false, &mut rng);
        let n = idx.len() as u64;
        assert!(dist.calls() <= n, "{} calls > N={}", dist.calls(), n);
        assert!(dist.calls() >= n / 2, "{} calls suspiciously few", dist.calls());
    }

    #[test]
    fn most_sequences_get_finite_nnd() {
        let (ts, stats, params) = setup(3_000, 100);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let idx = SaxIndex::build(&ts, &stats, &params.sax);
        let mut profile = NndProfile::new(idx.len());
        let mut rng = Rng64::new(1);
        warmup(&dist, &idx, &mut profile, 100, false, &mut rng);
        let finite = profile.nnd.iter().filter(|v| v.is_finite()).count();
        assert!(
            finite * 10 >= profile.len() * 8,
            "only {}/{} finite",
            finite,
            profile.len()
        );
        // neighbors recorded consistently and non-self-match
        for i in 0..profile.len() {
            if profile.nnd[i].is_finite() {
                let g = profile.ngh[i];
                assert_ne!(g, crate::discord::NO_NEIGHBOR);
                assert!(i.abs_diff(g) >= 100);
            }
        }
    }

    #[test]
    fn skips_self_matches() {
        // tiny cluster of overlapping sequences: no valid link possible,
        // sentinel survives (paper's sequence-11 example in Fig. 1)
        let (ts, stats, params) = setup(400, 152);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let idx = SaxIndex::build(&ts, &stats, &params.sax);
        let mut profile = NndProfile::new(idx.len());
        let mut rng = Rng64::new(2);
        warmup(&dist, &idx, &mut profile, 152, false, &mut rng);
        for i in 0..profile.len() {
            if profile.nnd[i].is_finite() {
                assert!(i.abs_diff(profile.ngh[i]) >= 152);
            }
        }
    }
}
