//! Time-topology refinements (paper Sec. 3.4 & 3.6).
//!
//! Time series exhibit the Consecutive Neighborhood Preserving property
//! (Zhu et al. 2018): the nearest neighbor of sequence i+1 is very often
//! ngh(i)+1. Both functions here turn that into targeted distance calls.

use crate::discord::{NndProfile, NO_NEIGHBOR};
use crate::dist::Distance;

use crate::algo::non_self_match;

/// Short-range pass (Sec. 3.4): one forward sweep proposing
/// `ngh(i)+1` as the neighbor of `i+1`, one backward sweep proposing
/// `ngh(i)−1` for `i−1`. ~≤ 2N distance calls, usually far fewer because
/// proposals already in place are skipped.
pub fn short_range(
    dist: &dyn Distance,
    profile: &mut NndProfile,
    n: usize,
    s: usize,
    allow_self_match: bool,
) {
    // forward: i -> i+1
    for i in 0..n.saturating_sub(1) {
        let g = profile.ngh[i];
        if g == NO_NEIGHBOR {
            continue;
        }
        try_suggest(dist, profile, i + 1, g + 1, n, s, allow_self_match);
    }
    // backward: i -> i-1
    for i in (1..n).rev() {
        let g = profile.ngh[i];
        if g == NO_NEIGHBOR || g == 0 {
            continue;
        }
        try_suggest(dist, profile, i - 1, g - 1, n, s, allow_self_match);
    }
}

/// Evaluate the suggestion "cand is tgt's neighbor" if it is admissible
/// and not already recorded. Exact evaluations update both endpoints.
#[inline]
fn try_suggest(
    dist: &dyn Distance,
    profile: &mut NndProfile,
    tgt: usize,
    cand: usize,
    n: usize,
    s: usize,
    allow: bool,
) {
    if tgt >= n || cand >= n {
        return;
    }
    if profile.ngh[tgt] == cand {
        return; // already known
    }
    if !non_self_match(tgt, cand, s, allow) {
        return;
    }
    let cutoff = profile.nnd[tgt].max(profile.nnd[cand]);
    let d = dist.dist_early(tgt, cand, cutoff);
    if d < cutoff {
        profile.observe(tgt, cand, d);
    }
}

/// Long-range forward topology (paper Listing 1): after sequence `i` got
/// an exact (or strongly refined) nnd, walk its forward time-neighbors
/// `i+1 … i+s` proposing `ngh(i)+j`, stopping as soon as
/// (a) the peak has ended (`nnd[i+j] < best_dist`),
/// (b) the proposal is already in place,
/// (c) bounds run out, or
/// (d) the topology loses coherence (no improvement).
pub fn long_range_forw(
    i: usize,
    dist: &dyn Distance,
    profile: &mut NndProfile,
    best_dist: f64,
    n: usize,
    s: usize,
    allow: bool,
) {
    let g = profile.ngh[i];
    if g == NO_NEIGHBOR {
        return;
    }
    for j in 1..=s {
        let t = i + j;
        let c = g + j;
        if t >= n || c >= n {
            return; // outside time-series limits
        }
        if profile.nnd[t] < best_dist {
            return; // not a discord: peak has ended
        }
        if profile.ngh[t] == c {
            return; // distance already calculated
        }
        if !non_self_match(t, c, s, allow) {
            return;
        }
        let old = profile.nnd[t];
        let cutoff = old.max(profile.nnd[c]);
        let d = dist.dist_early(t, c, cutoff);
        if d < cutoff {
            profile.observe(t, c, d);
        }
        if d >= old {
            return; // the time topology provides no improvement
        }
    }
}

/// Long-range backward topology (mirror of [`long_range_forw`]).
pub fn long_range_back(
    i: usize,
    dist: &dyn Distance,
    profile: &mut NndProfile,
    best_dist: f64,
    _n: usize,
    s: usize,
    allow: bool,
) {
    let g = profile.ngh[i];
    if g == NO_NEIGHBOR {
        return;
    }
    for j in 1..=s {
        if i < j || g < j {
            return; // outside time-series limits
        }
        let t = i - j;
        let c = g - j;
        if profile.nnd[t] < best_dist {
            return;
        }
        if profile.ngh[t] == c {
            return;
        }
        if !non_self_match(t, c, s, allow) {
            return;
        }
        let old = profile.nnd[t];
        let cutoff = old.max(profile.nnd[c]);
        let d = dist.dist_early(t, c, cutoff);
        if d < cutoff {
            profile.observe(t, c, d);
        }
        if d >= old {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::hst::warmup::warmup;
    use crate::config::SearchParams;
    use crate::context::SearchContext;
    use crate::dist::{CountingDistance, DistanceKind};
    use crate::sax::SaxIndex;
    use crate::ts::series::IntoSeries;
    use crate::ts::{generators, SeqStats, TimeSeries};
    use crate::util::rng::Rng64;

    fn warm_profile(
        ts: &TimeSeries,
        s: usize,
    ) -> (SeqStats, SearchParams, NndProfile) {
        let stats = SeqStats::compute(ts, s);
        let params = SearchParams::new(s, 4, 4);
        let idx = SaxIndex::build(ts, &stats, &params.sax);
        let dist = CountingDistance::new(ts, &stats, DistanceKind::Znorm);
        let mut profile = NndProfile::new(idx.len());
        let mut rng = Rng64::new(7);
        warmup(&dist, &idx, &mut profile, s, false, &mut rng);
        (stats, params, profile)
    }

    #[test]
    fn short_range_improves_profile_quality() {
        let ts = generators::ecg_like(4_000, 100, 1, 60).into_series("e");
        let s = 100;
        let (stats, _params, mut profile) = warm_profile(&ts, s);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let before: f64 = profile.nnd.iter().filter(|v| v.is_finite()).sum();
        let n = profile.len();
        short_range(&dist, &mut profile, n, s, false);
        let after: f64 = profile.nnd.iter().filter(|v| v.is_finite()).sum();
        assert!(
            after < before,
            "profile mass should shrink: {after} !< {before}"
        );
        // bounded cost: at most 2N suggestions
        assert!(dist.calls() <= 2 * n as u64);
    }

    #[test]
    fn short_range_never_breaks_upper_bound_invariant() {
        let ts = generators::sine_with_noise(1_200, 0.3, 61).into_series("s");
        let s = 64;
        let (stats, params, mut profile) = warm_profile(&ts, s);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let n = profile.len();
        short_range(&dist, &mut profile, n, s, false);
        let ctx = SearchContext::builder(&ts).build();
        let exact =
            crate::algo::brute::BruteForce::exact_profile(&ctx, &params, &dist)
                .unwrap();
        for i in 0..n {
            assert!(profile.nnd[i] >= exact.nnd[i] - 5e-8, "i={i}");
        }
    }

    #[test]
    fn long_range_levels_a_peak() {
        // Build a profile, clarify one sequence exactly, then check that
        // the long-range pass lowers its time-neighbors' nnds.
        let ts = generators::valve_like(3_000, 200, 1, 62).into_series("v");
        let s = 128;
        let (stats, _params, mut profile) = warm_profile(&ts, s);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let n = profile.len();
        short_range(&dist, &mut profile, n, s, false);

        // exact nnd for the middle sequence
        let i = n / 2;
        for j in 0..n {
            if j.abs_diff(i) >= s {
                let d = dist.dist(i, j);
                profile.observe(i, j, d);
            }
        }
        let before: Vec<f64> = (1..=s)
            .filter(|&j| i + j < n)
            .map(|j| profile.nnd[i + j])
            .collect();
        long_range_forw(i, &dist, &mut profile, 0.0, n, s, false);
        let after: Vec<f64> = (1..=s)
            .filter(|&j| i + j < n)
            .map(|j| profile.nnd[i + j])
            .collect();
        assert!(
            after.iter().zip(&before).all(|(a, b)| a <= b),
            "nnds can only decrease"
        );
        // either the walk improved a neighbor, or the profile was already
        // time-coherent at i+1 (warm-up can get lucky on smooth series)
        let g = profile.ngh[i];
        let improved = after.iter().zip(&before).any(|(a, b)| a < b);
        assert!(
            improved || profile.ngh[i + 1] == g + 1,
            "no improvement and no pre-existing coherence"
        );
    }

    #[test]
    fn long_range_respects_best_dist_stop() {
        let ts = generators::ecg_like(2_000, 90, 1, 63).into_series("e");
        let s = 80;
        let (stats, _params, mut profile) = warm_profile(&ts, s);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let n = profile.len();
        let i = n / 3;
        // huge best_dist: every nnd < best_dist, so the walk stops at j=1
        let calls_before = dist.calls();
        long_range_forw(i, &dist, &mut profile, f64::INFINITY, n, s, false);
        assert_eq!(dist.calls(), calls_before, "no calls when peak ended");
    }

    #[test]
    fn bounds_are_respected_at_series_edges() {
        let ts = generators::sine_with_noise(600, 0.2, 64).into_series("s");
        let s = 64;
        let (stats, _params, mut profile) = warm_profile(&ts, s);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let n = profile.len();
        // must not panic at either edge
        long_range_forw(n - 1, &dist, &mut profile, 0.0, n, s, false);
        long_range_back(0, &dist, &mut profile, 0.0, n, s, false);
        long_range_forw(0, &dist, &mut profile, 0.0, n, s, false);
        long_range_back(n - 1, &dist, &mut profile, 0.0, n, s, false);
    }
}
