//! Discord-search algorithms.
//!
//! * [`brute`] — O(N²) ground truth (the correctness oracle for tests).
//! * [`hotsax`] — the 2005 baseline (Keogh, Lin & Fu).
//! * [`hst`] — **the paper's contribution**: HOT SAX Time.
//! * [`hst::par`] — `hst-par`, HST with the outer candidate loop sharded
//!   over the [`exec`](crate::exec) worker pool (the paper's Sec. 5
//!   follow-up); results identical to serial `hst`.
//! * [`stream::HstStream`](crate::stream::HstStream) — `hst-stream`,
//!   serial HST pinned to the exact scalar backend; the engine the
//!   sliding-window [`stream`](crate::stream) monitor drives on every
//!   refresh.
//! * [`dadd`] — Disk-Aware Discord Discovery / DRAG (Yankov et al. 2008).
//! * [`rra`] — Rare Rule Anomaly via Sequitur (Senin et al. 2015).
//! * [`scamp`] — exact matrix profile (SCAMP/STOMP-style; serial + XLA-tiled);
//!   `scamp-par` splits diagonals across the same worker pool.
//! * [`mdim`](crate::mdim) — the multivariate engines `brute-md` /
//!   `hst-md` (k-of-d aggregate distance). Registered here through their
//!   univariate faces, which treat a plain series as one channel; the
//!   multivariate entry point is
//!   [`MdimAlgorithm`](crate::mdim::MdimAlgorithm).
//! * [`vl::HstVl`](crate::vl::HstVl) — `hst-vl`, the variable-length
//!   work-sharing engine: one ascending pass over a
//!   [`LengthRange`](crate::config::LengthRange), bit-identical to serial
//!   `hst` at every length, warm-carrying stats and nnd profiles across
//!   lengths instead of re-running cold like [`merlin`].
//!
//! Every engine implements [`Algorithm`] and returns a [`SearchReport`]
//! carrying the discord set, the distance-call count (the paper's primary
//! metric), and wall-clock time.
//!
//! Engines run through a [`SearchContext`] session
//! ([`Algorithm::run_ctx`], the primary entry point): the context owns the
//! prepared per-series state (stats, SAX indexes, warm profiles, distance
//! backend) so repeated searches skip preparation. [`Algorithm::run`] is
//! the one-shot convenience wrapper over a throwaway context.

pub mod brute;
pub mod dadd;
pub mod merlin;
pub mod parallel;
pub mod prescrimp;
pub mod hotsax;
pub mod hst;
pub mod rra;
pub mod scamp;

use std::time::Duration;

use anyhow::Result;

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::DiscordSet;
use crate::ts::TimeSeries;

/// Outcome of one discord search.
#[derive(Debug, Clone)]
pub struct SearchReport {
    /// Algorithm identifier.
    pub algo: String,
    /// Discords in rank order (1st = highest nnd).
    pub discords: DiscordSet,
    /// Total calls to the sequence-distance function (includes
    /// `prep_calls`).
    pub distance_calls: u64,
    /// Distance calls spent preparing shared state during *this* search
    /// (HST's warm-up + short-range topology). 0 when the preparation was
    /// served from a warm [`SearchContext`], and for engines whose
    /// preparation needs no distance calls.
    pub prep_calls: u64,
    /// Wall-clock time of the search proper (excludes series generation).
    pub elapsed: Duration,
    /// Number of sequences N in the search space.
    pub n_sequences: usize,
}

impl SearchReport {
    /// Cost per sequence for this search (paper Sec. 4.2).
    pub fn cps(&self) -> f64 {
        crate::metrics::cps(
            self.distance_calls,
            self.n_sequences,
            self.discords.len().max(1),
        )
    }

    /// Serialize for reports and the service protocol.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj()
            .set("algo", self.algo.as_str())
            .set(
                "discords",
                self.discords.iter().map(|d| d.to_json()).collect::<Vec<_>>(),
            )
            .set("distance_calls", self.distance_calls)
            .set("prep_calls", self.prep_calls)
            .set("elapsed_secs", self.elapsed.as_secs_f64())
            .set("n_sequences", self.n_sequences)
            .set("cps", self.cps())
    }
}

/// A discord-search engine.
pub trait Algorithm {
    /// Short identifier ("hst", "hotsax", …).
    fn name(&self) -> &'static str;

    /// The engine body: find the first `params.k` discords of the
    /// context's series, reusing (and extending) the context's prepared
    /// state. Implementors provide this; callers should prefer
    /// [`run_ctx`](Self::run_ctx), which wraps it in a trace span.
    fn search(&self, ctx: &SearchContext, params: &SearchParams)
        -> Result<SearchReport>;

    /// Find the first `params.k` discords of the context's series,
    /// reusing (and extending) the context's prepared state. The primary
    /// entry point: drive many searches through one [`SearchContext`] to
    /// amortize preparation.
    ///
    /// Provided: opens a search span on the context's
    /// [`TraceSink`](crate::obs::TraceSink) (if any), delegates to
    /// [`search`](Self::search), and closes the span with the report's
    /// call accounting. Engines never open spans themselves, so internal
    /// engine-to-engine reuse (e.g. `hst-par` falling back to serial
    /// `hst`) cannot nest spans.
    fn run_ctx(
        &self,
        ctx: &SearchContext,
        params: &SearchParams,
    ) -> Result<SearchReport> {
        let n = ctx.series().num_sequences(params.sax.s);
        ctx.trace_search_start(self.name(), n, params.sax.s, params.k);
        let report = self.search(ctx, params)?;
        ctx.trace_search_end(self.name(), report.distance_calls, report.prep_calls);
        Ok(report)
    }

    /// One-shot convenience: find the first `params.k` discords of `ts`
    /// through a throwaway context. Preparation is rebuilt — and the
    /// series cloned into the context — on every call; use
    /// [`run_ctx`](Self::run_ctx) to amortize both at scale.
    fn run(&self, ts: &TimeSeries, params: &SearchParams) -> Result<SearchReport> {
        let ctx = SearchContext::builder(ts).build();
        self.run_ctx(&ctx, params)
    }
}

/// Canonical id of every registered engine — [`by_name`] resolves each,
/// and the id equals the engine's [`Algorithm::name`]. One entry per row
/// of the README "Engines" table; `tests/docs_consistency.rs` keeps the
/// two in sync so the table can never go stale again.
pub const ALL_ENGINES: [&str; 14] = [
    "brute",
    "brute-md",
    "hotsax",
    "hst",
    "hst-par",
    "hst-md",
    "hst-stream",
    "hst-vl",
    "dadd",
    "rra",
    "scamp",
    "scamp-par",
    "prescrimp",
    "merlin",
];

/// Look up an algorithm by name (CLI / service entry point).
pub fn by_name(name: &str) -> Option<Box<dyn Algorithm + Send + Sync>> {
    match name.to_ascii_lowercase().as_str() {
        "brute" => Some(Box::new(brute::BruteForce)),
        "hotsax" | "hot-sax" | "hot_sax" => Some(Box::new(hotsax::HotSax)),
        "hst" | "hotsaxtime" => Some(Box::new(hst::HstSearch::default())),
        "hst-par" | "hstpar" | "hst_par" => Some(Box::new(hst::par::HstPar::default())),
        "hst-stream" | "hststream" | "hst_stream" => {
            Some(Box::new(crate::stream::HstStream))
        }
        "brute-md" | "brutemd" | "brute_md" => {
            Some(Box::new(crate::mdim::brute::BruteMd))
        }
        "hst-md" | "hstmd" | "hst_md" => {
            Some(Box::new(crate::mdim::hst::HstMd::default()))
        }
        "hst-vl" | "hstvl" | "hst_vl" => {
            Some(Box::new(crate::vl::HstVl::default()))
        }
        "dadd" | "drag" => Some(Box::new(dadd::Dadd::default())),
        "rra" => Some(Box::new(rra::Rra::default())),
        "scamp" | "stomp" => Some(Box::new(scamp::Scamp::default())),
        "scamp-par" => Some(Box::new(parallel::ParallelScamp::default())),
        "prescrimp" => Some(Box::new(prescrimp::PreScrimp::default())),
        "merlin" => Some(Box::new(merlin::Merlin::default())),
        _ => None,
    }
}

/// Self-match predicate shared by all engines: sequences overlap when
/// |i − j| < s (unless the Table 7 protocol allows self-matches).
#[inline]
pub(crate) fn non_self_match(i: usize, j: usize, s: usize, allow: bool) -> bool {
    allow || i.abs_diff(j) >= s
}

/// Up-front budget check shared by the matrix-profile engines: their cost
/// is data-independent (all pairs above the exclusion band), so the
/// context's distance-call budget can be enforced before any work starts.
pub(crate) fn ensure_profile_budget(
    ctx: &SearchContext,
    n: usize,
    s: usize,
) -> Result<()> {
    if let Some(budget) = ctx.budget() {
        let expected: u64 = (s as u64..n as u64).map(|d| n as u64 - d).sum();
        anyhow::ensure!(
            expected <= budget,
            "distance-call budget {budget} below the {expected} pair \
             evaluations an exact matrix profile needs"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_engines() {
        for id in ALL_ENGINES {
            let engine = by_name(id).unwrap_or_else(|| panic!("{id} missing"));
            assert_eq!(engine.name(), id, "canonical id must round-trip");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn self_match_predicate() {
        assert!(!non_self_match(10, 15, 10, false));
        assert!(non_self_match(10, 20, 10, false));
        assert!(non_self_match(20, 10, 10, false));
        assert!(non_self_match(10, 11, 10, true), "table 7 protocol");
    }
}
