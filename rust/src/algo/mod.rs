//! Discord-search algorithms.
//!
//! * [`brute`] — O(N²) ground truth (the correctness oracle for tests).
//! * [`hotsax`] — the 2005 baseline (Keogh, Lin & Fu).
//! * [`hst`] — **the paper's contribution**: HOT SAX Time.
//! * [`dadd`] — Disk-Aware Discord Discovery / DRAG (Yankov et al. 2008).
//! * [`rra`] — Rare Rule Anomaly via Sequitur (Senin et al. 2015).
//! * [`scamp`] — exact matrix profile (SCAMP/STOMP-style; serial + XLA-tiled).
//!
//! Every engine implements [`Algorithm`] and returns a [`SearchReport`]
//! carrying the discord set, the distance-call count (the paper's primary
//! metric), and wall-clock time.

pub mod brute;
pub mod dadd;
pub mod merlin;
pub mod parallel;
pub mod prescrimp;
pub mod hotsax;
pub mod hst;
pub mod rra;
pub mod scamp;

use std::time::Duration;

use anyhow::Result;

use crate::config::SearchParams;
use crate::discord::DiscordSet;
use crate::ts::TimeSeries;

/// Outcome of one discord search.
#[derive(Debug, Clone)]
pub struct SearchReport {
    /// Algorithm identifier.
    pub algo: String,
    /// Discords in rank order (1st = highest nnd).
    pub discords: DiscordSet,
    /// Total calls to the sequence-distance function.
    pub distance_calls: u64,
    /// Wall-clock time of the search proper (excludes series generation).
    pub elapsed: Duration,
    /// Number of sequences N in the search space.
    pub n_sequences: usize,
}

impl SearchReport {
    /// Cost per sequence for this search (paper Sec. 4.2).
    pub fn cps(&self) -> f64 {
        crate::metrics::cps(
            self.distance_calls,
            self.n_sequences,
            self.discords.len().max(1),
        )
    }

    /// Serialize for reports and the service protocol.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj()
            .set("algo", self.algo.as_str())
            .set(
                "discords",
                self.discords.iter().map(|d| d.to_json()).collect::<Vec<_>>(),
            )
            .set("distance_calls", self.distance_calls)
            .set("elapsed_secs", self.elapsed.as_secs_f64())
            .set("n_sequences", self.n_sequences)
            .set("cps", self.cps())
    }
}

/// A discord-search engine.
pub trait Algorithm {
    /// Short identifier ("hst", "hotsax", …).
    fn name(&self) -> &'static str;

    /// Find the first `params.k` discords of `ts`.
    fn run(&self, ts: &TimeSeries, params: &SearchParams) -> Result<SearchReport>;
}

/// Look up an algorithm by name (CLI / service entry point).
pub fn by_name(name: &str) -> Option<Box<dyn Algorithm + Send + Sync>> {
    match name.to_ascii_lowercase().as_str() {
        "brute" => Some(Box::new(brute::BruteForce)),
        "hotsax" | "hot-sax" | "hot_sax" => Some(Box::new(hotsax::HotSax)),
        "hst" | "hotsaxtime" => Some(Box::new(hst::HstSearch::default())),
        "dadd" | "drag" => Some(Box::new(dadd::Dadd::default())),
        "rra" => Some(Box::new(rra::Rra::default())),
        "scamp" | "stomp" => Some(Box::new(scamp::Scamp::default())),
        "scamp-par" => Some(Box::new(parallel::ParallelScamp::default())),
        "prescrimp" => Some(Box::new(prescrimp::PreScrimp::default())),
        _ => None,
    }
}

/// Self-match predicate shared by all engines: sequences overlap when
/// |i − j| < s (unless the Table 7 protocol allows self-matches).
#[inline]
pub(crate) fn non_self_match(i: usize, j: usize, s: usize, allow: bool) -> bool {
    allow || i.abs_diff(j) >= s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_engines() {
        for n in ["brute", "hotsax", "hst", "dadd", "rra", "scamp", "scamp-par", "prescrimp"] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn self_match_predicate() {
        assert!(!non_self_match(10, 15, 10, false));
        assert!(non_self_match(10, 20, 10, false));
        assert!(non_self_match(20, 10, 10, false));
        assert!(non_self_match(10, 11, 10, true), "table 7 protocol");
    }
}
