//! HOT SAX (Keogh, Lin & Fu, ICDM 2005): the baseline HST improves on.
//!
//! Outer loop: sequences ordered by ascending SAX-cluster size (small
//! clusters first — likely "isolated" sequences), shuffled within a
//! cluster. Inner loop: same-cluster members first, then all remaining
//! sequences in pseudo-random order; abandons a candidate as soon as its
//! running nnd drops below the best-so-far discord distance.
//!
//! Faithful to the paper's comparison setup: for k discords the search is
//! repeated per discord with fresh state (no carried-over nnd profile —
//! that carry-over is exactly one of HST's improvements, Sec. 3.2), adding
//! exclusion zones for the already-found discords.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::{Discord, ExclusionZones};
use crate::dist::Distance;
use crate::sax::SaxIndex;
use crate::util::rng::Rng64;

use super::{non_self_match, Algorithm, SearchReport};

/// The HOT SAX engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct HotSax;

/// One full HOT SAX pass: find the single best discord not excluded by
/// `zones`. Returns None when every position is excluded; errors when the
/// context cancels the search or the call budget runs out.
fn find_one(
    ctx: &SearchContext,
    dist: &dyn Distance,
    idx: &SaxIndex,
    params: &SearchParams,
    zones: &ExclusionZones,
    rng: &mut Rng64,
) -> Result<Option<Discord>> {
    let s = params.sax.s;
    let n = idx.len();
    let allow = params.allow_self_match;

    // ---- outer order: clusters by ascending size, members shuffled ----
    let mut outer: Vec<usize> = Vec::with_capacity(n);
    for &cid in &idx.by_size {
        let mut members = idx.clusters[cid].clone();
        rng.shuffle(&mut members);
        outer.extend(members);
    }

    // ---- random tail order for the inner loop (fixed per pass) ----
    let mut random_order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut random_order);

    let mut best_dist = 0.0f64;
    let mut best: Option<Discord> = None;

    for &i in &outer {
        if !zones.allowed(i, s) {
            continue;
        }
        ctx.check(dist.calls())?;
        let mut nnd_i = f64::INFINITY;
        let mut ngh_i = usize::MAX;
        let mut pruned = false;

        // phase 1: same-cluster members first (likely close neighbors,
        // best chance of an early prune)…
        for &j in idx.cluster_members(i) {
            if !non_self_match(i, j, s, allow) || i == j {
                continue;
            }
            let d = dist.dist_early(i, j, nnd_i);
            if d < nnd_i {
                nnd_i = d;
                ngh_i = j;
                if nnd_i < best_dist {
                    pruned = true;
                    break; // cannot be the discord
                }
            }
        }

        // …phase 2: everything else in the pseudo-random order.
        if !pruned {
            let own_cluster = idx.cluster_of[i];
            for &j in &random_order {
                if idx.cluster_of[j] == own_cluster {
                    continue; // already visited in phase 1
                }
                if !non_self_match(i, j, s, allow) {
                    continue;
                }
                let d = dist.dist_early(i, j, nnd_i);
                if d < nnd_i {
                    nnd_i = d;
                    ngh_i = j;
                    if nnd_i < best_dist {
                        pruned = true;
                        break;
                    }
                }
            }
        }

        if !pruned && nnd_i.is_finite() && nnd_i >= best_dist {
            best_dist = nnd_i;
            best = Some(Discord {
                position: i,
                nnd: nnd_i,
                neighbor: ngh_i,
            });
        }
    }
    Ok(best)
}

impl Algorithm for HotSax {
    fn name(&self) -> &'static str {
        "hotsax"
    }

    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        let s = params.sax.s;
        let n = ctx.series().num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ctx.check(0)?;
        let start = Instant::now();
        ctx.notify_phase(self.name(), "prepare");
        let (stats, idx) = ctx.prepared(&params.sax);
        let dist = ctx.distance(&stats, params.distance_kind());
        let mut rng = Rng64::new(params.seed ^ 0x4853_5458); // "HSTX"

        // Faithful to the 2005 comparison protocol: no state carried over
        // between discords (that carry-over is HST's improvement), so the
        // context contributes the index/stats but no warm profile.
        ctx.notify_phase(self.name(), "search");
        let mut zones = ExclusionZones::new();
        let mut discords = Vec::new();
        for rank in 0..params.k {
            let calls_before = dist.calls();
            let abandons_before = dist.abandons();
            let found = find_one(ctx, dist.as_ref(), &idx, params, &zones, &mut rng)?;
            ctx.trace_pass(&crate::obs::PassEvent {
                engine: self.name(),
                phase: "search",
                index: rank,
                candidates: n as u64,
                abandons: dist.abandons() - abandons_before,
                calls: dist.calls() - calls_before,
                best: found.as_ref().map(|d| d.nnd).unwrap_or(f64::NAN),
            });
            match found {
                Some(d) => {
                    zones.add(d.position, s);
                    ctx.notify_discord(rank, &d);
                    discords.push(d);
                }
                None => break,
            }
        }

        Ok(SearchReport {
            algo: self.name().to_string(),
            discords,
            distance_calls: dist.calls(),
            prep_calls: 0,
            elapsed: start.elapsed(),
            n_sequences: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::brute::BruteForce;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;
    use crate::ts::TimeSeries;

    fn agree_with_brute(ts: &TimeSeries, params: &SearchParams) {
        let hs = HotSax.run(ts, params).unwrap();
        let bf = BruteForce.run(ts, params).unwrap();
        assert_eq!(hs.discords.len(), bf.discords.len());
        for (h, b) in hs.discords.iter().zip(&bf.discords) {
            // positions can differ on exact ties; nnd values must agree
            assert!(
                (h.nnd - b.nnd).abs() < 5e-8,
                "nnd mismatch: {} vs {} (pos {} vs {})",
                h.nnd,
                b.nnd,
                h.position,
                b.position
            );
        }
    }

    #[test]
    fn exact_on_ecg() {
        let ts = generators::ecg_like(1_500, 100, 1, 11).into_series("e");
        agree_with_brute(&ts, &SearchParams::new(80, 4, 4));
    }

    #[test]
    fn exact_on_sine_low_noise() {
        let ts = generators::sine_with_noise(1_000, 0.01, 5).into_series("s");
        agree_with_brute(&ts, &SearchParams::new(64, 4, 4));
    }

    #[test]
    fn exact_on_three_discords() {
        let ts = generators::valve_like(1_800, 150, 2, 7).into_series("v");
        agree_with_brute(&ts, &SearchParams::new(100, 4, 4).with_discords(3));
    }

    #[test]
    fn uses_fewer_calls_than_brute() {
        let ts = generators::ecg_like(3_000, 120, 1, 2).into_series("e");
        let params = SearchParams::new(100, 4, 4);
        let hs = HotSax.run(&ts, &params).unwrap();
        let bf = BruteForce.run(&ts, &params).unwrap();
        assert!(
            hs.distance_calls < bf.distance_calls / 2,
            "hotsax {} vs brute {}",
            hs.distance_calls,
            bf.distance_calls
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let ts = generators::respiration_like(2_000, 130, 1, 8).into_series("r");
        let params = SearchParams::new(128, 4, 4).with_seed(99);
        let a = HotSax.run(&ts, &params).unwrap();
        let b = HotSax.run(&ts, &params).unwrap();
        assert_eq!(a.distance_calls, b.distance_calls);
        assert_eq!(a.discords[0].position, b.discords[0].position);
    }
}
