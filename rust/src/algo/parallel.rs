//! Parallel engines — the paper's "natural follow up" (Sec. 5:
//! "Parallelizing HST is also a natural follow up of the present work").
//!
//! Both pieces here run on the [`exec`](crate::exec) subsystem (scoped
//! worker pool, deterministic chunking, ordered merge):
//!
//! * [`ParallelScamp`] — the exact matrix profile split by diagonal
//!   ranges, one partial profile per worker, merged in worker order. This
//!   is the same decomposition SCAMP uses across GPU thread blocks. The
//!   worker count resolves through [`ExecPolicy`]
//!   ([`SearchParams::threads`] → `HST_THREADS` → available parallelism).
//! * [`par_warmup_profile`] — the HST warm-up + short-range topology over
//!   P disjoint chunks of the cluster chain: the parallel initialization
//!   shared by [`hst-par`](crate::algo::hst::par::HstPar).
//!
//! Each worker owns its own [`CountingDistance`] (the counter is a
//! `Cell`, deliberately not `Sync`); call counts are summed afterwards so
//! the accounting stays exact.
//!
//! [`SearchParams::threads`]: crate::config::SearchParams::threads

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::NndProfile;
use crate::dist::{CountingDistance, DistanceKind, Kernel};
use crate::exec::{scope_workers, ExecPolicy};
use crate::sax::SaxIndex;
use crate::ts::{SeqStats, TimeSeries};
use crate::util::rng::Rng64;

use super::{brute::BruteForce, non_self_match, Algorithm, SearchReport};

/// Merge `other` into `base` (pointwise min, keeping neighbors; see
/// [`NndProfile::merge_min`]).
pub fn merge_profiles(base: &mut NndProfile, other: &NndProfile) {
    base.merge_min(other);
}

/// Exact matrix profile with `threads` workers over diagonal ranges.
pub fn par_matrix_profile(
    ts: &TimeSeries,
    stats: &SeqStats,
    threads: usize,
) -> (NndProfile, u64) {
    let s = stats.s;
    let n = stats.len();
    let threads = threads.max(1).min(n.saturating_sub(s).max(1));
    let pts = &ts.points;
    let sf = s as f64;

    // interleaved diagonals: balanced load (long diagonals are spread
    // across workers); the per-diagonal recurrence is identical to the
    // serial engine, so the merged profile is bit-identical to serial
    let results = scope_workers(threads, |w| {
        let mut profile = NndProfile::new(n);
        let mut pairs = 0u64;
        let mut diag = s + w;
        while diag < n {
            let mut qt = 0.0;
            for t in 0..s {
                qt += pts[t] * pts[diag + t];
            }
            let mut i = 0usize;
            loop {
                let j = i + diag;
                let corr = (qt - sf * stats.mean[i] * stats.mean[j])
                    / (sf * stats.std[i] * stats.std[j]);
                let d = (2.0 * sf * (1.0 - corr)).max(0.0).sqrt();
                profile.observe(i, j, d);
                pairs += 1;
                i += 1;
                if i + diag >= n {
                    break;
                }
                qt += pts[i + s - 1] * pts[i + diag + s - 1]
                    - pts[i - 1] * pts[i + diag - 1];
            }
            diag += threads;
        }
        (profile, pairs)
    });

    let mut merged = NndProfile::new(n);
    let mut total_pairs = 0u64;
    for (p, c) in results {
        merge_profiles(&mut merged, &p);
        total_pairs += c;
    }
    (merged, total_pairs)
}

/// Multi-threaded SCAMP engine. The worker count comes from the shared
/// [`ExecPolicy`] resolution over [`SearchParams::threads`]
/// (`0` → `HST_THREADS` → available parallelism) — nothing is hardcoded
/// in the engine.
///
/// [`SearchParams::threads`]: crate::config::SearchParams::threads
#[derive(Debug, Default, Clone, Copy)]
pub struct ParallelScamp;

impl Algorithm for ParallelScamp {
    fn name(&self) -> &'static str {
        "scamp-par"
    }

    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        let s = params.sax.s;
        let ts = ctx.series();
        let n = ts.num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ensure!(params.znormalize, "matrix profile is z-normalized only");
        // data-independent cost: the budget is enforced up front
        super::ensure_profile_budget(ctx, n, s)?;
        ctx.check(0)?;
        let start = Instant::now();
        ctx.notify_phase(self.name(), "prepare");
        let stats = ctx.stats(s);
        ctx.notify_phase(self.name(), "search");
        let threads = ExecPolicy::new(params.threads).resolve();
        let (profile, pairs) = par_matrix_profile(ts, &stats, threads);
        let discords = BruteForce::discords_from_profile(&profile, s, params.k);
        ctx.trace_pass(&crate::obs::PassEvent {
            engine: self.name(),
            phase: "search",
            index: 0,
            candidates: n as u64,
            abandons: 0,
            calls: pairs,
            best: discords.first().map(|d| d.nnd).unwrap_or(f64::NAN),
        });
        for (rank, d) in discords.iter().enumerate() {
            ctx.notify_discord(rank, d);
        }
        Ok(SearchReport {
            algo: self.name().to_string(),
            discords,
            distance_calls: pairs,
            prep_calls: 0,
            elapsed: start.elapsed(),
            n_sequences: n,
        })
    }
}

/// Parallel HST initialization: split the shuffled cluster chain into
/// `threads` contiguous segments, run the warm-up links and the
/// short-range sweeps per segment, and merge. Returns (profile, calls).
/// Every worker session runs on `kernel` (callers pass their context's
/// choice through so the whole search uses one inner loop).
pub fn par_warmup_profile(
    ts: &TimeSeries,
    stats: &SeqStats,
    idx: &SaxIndex,
    params: &SearchParams,
    threads: usize,
    kernel: Kernel,
) -> (NndProfile, u64) {
    let s = params.sax.s;
    let n = idx.len();
    let threads = threads.max(1);
    let allow = params.allow_self_match;

    // build the global chain exactly like the serial warm-up
    let mut rng = Rng64::new(params.seed ^ 0x4853_5400);
    let mut chain: Vec<usize> = Vec::with_capacity(n);
    for &cid in &idx.by_size {
        let mut members = idx.clusters[cid].clone();
        rng.shuffle(&mut members);
        chain.extend(members);
    }

    let kind = if params.znormalize {
        DistanceKind::Znorm
    } else {
        DistanceKind::Raw
    };

    let seg = n.div_ceil(threads);
    let chain = &chain;
    let results = scope_workers(threads, |w| {
        let lo = (w * seg).min(n);
        // overlap by one so the link crossing the boundary is computed
        let hi = ((w + 1) * seg + 1).min(n);
        let dist = CountingDistance::with_kernel(ts, stats, kind, kernel);
        let mut profile = NndProfile::new(n);
        for t in lo..hi.saturating_sub(1) {
            let (a, b) = (chain[t], chain[t + 1]);
            if non_self_match(a, b, s, allow) {
                let d = dist.dist(a, b);
                profile.observe(a, b, d);
            }
        }
        (profile, dist.calls())
    });

    let mut merged = NndProfile::new(n);
    let mut calls = 0u64;
    for (p, c) in results {
        merge_profiles(&mut merged, &p);
        calls += c;
    }

    // short-range topology stays serial (it chains through the profile)
    let dist = CountingDistance::with_kernel(ts, stats, kind, kernel);
    crate::algo::hst::topology::short_range(&dist, &mut merged, n, s, allow);
    (merged, calls + dist.calls())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::scamp::Scamp;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn parallel_profile_equals_serial() {
        let ts = generators::ecg_like(1_600, 110, 1, 700).into_series("e");
        let stats = SeqStats::compute(&ts, 96);
        let (serial, serial_pairs) = Scamp::matrix_profile(&ts, &stats);
        for threads in [1, 2, 4, 7] {
            let (par, pairs) = par_matrix_profile(&ts, &stats, threads);
            assert_eq!(pairs, serial_pairs, "threads={threads}");
            for i in 0..serial.len() {
                assert_eq!(
                    par.nnd[i].to_bits(),
                    serial.nnd[i].to_bits(),
                    "threads={threads} i={i}: same per-diagonal recurrence \
                     must give bit-identical minima"
                );
            }
        }
    }

    #[test]
    fn parallel_scamp_engine_matches_brute() {
        let ts = generators::valve_like(1_200, 140, 1, 701).into_series("v");
        let params = SearchParams::new(96, 4, 4)
            .with_discords(2)
            .with_threads(3);
        let par = ParallelScamp.run(&ts, &params).unwrap();
        let bf = BruteForce.run(&ts, &params).unwrap();
        for (a, b) in par.discords.iter().zip(&bf.discords) {
            assert!((a.nnd - b.nnd).abs() < 1e-6);
        }
    }

    #[test]
    fn par_warmup_is_valid_upper_bound_and_cheap() {
        let ts = generators::respiration_like(2_400, 130, 1, 702).into_series("r");
        let s = 128;
        let stats = SeqStats::compute(&ts, s);
        let params = SearchParams::new(s, 4, 4);
        let idx = SaxIndex::build(&ts, &stats, &params.sax);
        let (profile, calls) =
            par_warmup_profile(&ts, &stats, &idx, &params, 4, Kernel::active());
        // cost stays ~2 calls/sequence (+ thread-boundary overlaps)
        assert!(calls <= 3 * idx.len() as u64 + 8);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let ctx = SearchContext::builder(&ts).build();
        let exact = BruteForce::exact_profile(&ctx, &params, &dist).unwrap();
        for i in 0..idx.len() {
            assert!(profile.nnd[i] >= exact.nnd[i] - 5e-8, "i={i}");
        }
    }

    #[test]
    fn thread_count_does_not_change_pair_total() {
        let ts = generators::sine_with_noise(900, 0.2, 703).into_series("s");
        let stats = SeqStats::compute(&ts, 64);
        let (_, p1) = par_matrix_profile(&ts, &stats, 1);
        let (_, p8) = par_matrix_profile(&ts, &stats, 8);
        assert_eq!(p1, p8);
    }

    #[test]
    fn scamp_par_resolves_threads_from_params() {
        // any explicit thread count must give the same report as serial
        let ts = generators::ecg_like(900, 80, 1, 704).into_series("e");
        let params = SearchParams::new(64, 4, 4);
        let serial = Scamp.run(&ts, &params).unwrap();
        for threads in [1usize, 2, 4] {
            let par = ParallelScamp
                .run(&ts, &params.clone().with_threads(threads))
                .unwrap();
            assert_eq!(par.distance_calls, serial.distance_calls);
            assert_eq!(
                par.discords[0].position,
                serial.discords[0].position
            );
            assert_eq!(
                par.discords[0].nnd.to_bits(),
                serial.discords[0].nnd.to_bits()
            );
        }
    }
}
