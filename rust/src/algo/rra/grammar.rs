//! Grammar induction for RRA: Re-Pair over a symbol stream.
//!
//! Grammarviz's RRA uses Sequitur; Re-Pair is the batch member of the same
//! grammar-compression family (repeatedly replace the most frequent digram
//! with a fresh nonterminal until no digram repeats). What RRA consumes is
//! not the grammar itself but the *rule coverage density*: how many rule
//! applications span each position of the input — well-compressed (rule
//! dense) regions are grammatically "ordinary", rule-sparse regions are
//! candidate anomalies. Re-Pair yields the same density signal with a
//! simpler, more testable implementation (see DESIGN.md substitutions).

use std::collections::HashMap;

/// Result of grammar induction.
#[derive(Debug, Clone)]
pub struct GrammarResult {
    /// Number of rule applications covering each input symbol position.
    pub coverage: Vec<u32>,
    /// Number of distinct rules created.
    pub n_rules: usize,
    /// Length of the fully-compressed top-level sequence.
    pub final_len: usize,
}

/// One stream element: current symbol + the input interval it expands to.
#[derive(Debug, Clone, Copy)]
struct Elem {
    sym: u32,
    start: u32,
    end: u32, // exclusive
}

/// Run Re-Pair on `symbols`. Terminals must be < `u32::MAX / 2`;
/// nonterminals are allocated above the maximum input symbol.
pub fn repair(symbols: &[u32]) -> GrammarResult {
    let n = symbols.len();
    let mut coverage = vec![0u32; n];
    if n < 2 {
        return GrammarResult {
            coverage,
            n_rules: 0,
            final_len: n,
        };
    }
    let mut stream: Vec<Elem> = symbols
        .iter()
        .enumerate()
        .map(|(i, &s)| Elem {
            sym: s,
            start: i as u32,
            end: (i + 1) as u32,
        })
        .collect();
    let mut next_sym = symbols.iter().copied().max().unwrap_or(0) + 1;
    let mut n_rules = 0usize;

    loop {
        // count digrams
        let mut counts: HashMap<(u32, u32), u32> = HashMap::new();
        for w in stream.windows(2) {
            *counts.entry((w[0].sym, w[1].sym)).or_insert(0) += 1;
        }
        // most frequent repeating digram (deterministic tie-break)
        let Some((&digram, &cnt)) = counts
            .iter()
            .max_by_key(|&(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
        else {
            break;
        };
        if cnt < 2 {
            break;
        }

        // replace non-overlapping occurrences left-to-right
        let rule_sym = next_sym;
        next_sym += 1;
        n_rules += 1;
        let mut out: Vec<Elem> = Vec::with_capacity(stream.len());
        let mut i = 0;
        while i < stream.len() {
            if i + 1 < stream.len()
                && (stream[i].sym, stream[i + 1].sym) == digram
            {
                let start = stream[i].start;
                let end = stream[i + 1].end;
                // one more rule application covers [start, end)
                for c in &mut coverage[start as usize..end as usize] {
                    *c += 1;
                }
                out.push(Elem {
                    sym: rule_sym,
                    start,
                    end,
                });
                i += 2;
            } else {
                out.push(stream[i]);
                i += 1;
            }
        }
        stream = out;
    }

    GrammarResult {
        coverage,
        n_rules,
        final_len: stream.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_singleton() {
        assert_eq!(repair(&[]).final_len, 0);
        let r = repair(&[5]);
        assert_eq!(r.final_len, 1);
        assert_eq!(r.n_rules, 0);
        assert_eq!(r.coverage, vec![0]);
    }

    #[test]
    fn repetitive_input_compresses_hard() {
        // abab abab abab abab
        let syms: Vec<u32> = (0..32).map(|i| (i % 2) as u32).collect();
        let r = repair(&syms);
        assert!(r.final_len <= 4, "final len {}", r.final_len);
        assert!(r.n_rules >= 2);
        // every position covered by at least one rule
        assert!(r.coverage.iter().all(|&c| c > 0));
    }

    #[test]
    fn unique_symbols_do_not_compress() {
        let syms: Vec<u32> = (0..16).collect();
        let r = repair(&syms);
        assert_eq!(r.final_len, 16);
        assert_eq!(r.n_rules, 0);
        assert!(r.coverage.iter().all(|&c| c == 0));
    }

    #[test]
    fn anomalous_region_gets_lower_coverage() {
        // long repeating background with a unique block in the middle
        let mut syms: Vec<u32> = Vec::new();
        for i in 0..40 {
            syms.push((i % 4) as u32);
        }
        syms.extend([90, 91, 92, 93]); // the anomaly: unique symbols
        for i in 0..40 {
            syms.push((i % 4) as u32);
        }
        let r = repair(&syms);
        let bg: f64 = r.coverage[..40].iter().map(|&c| c as f64).sum::<f64>() / 40.0;
        let an: f64 = r.coverage[40..44].iter().map(|&c| c as f64).sum::<f64>() / 4.0;
        assert!(
            an < bg,
            "anomaly coverage {an} should be below background {bg}"
        );
    }

    #[test]
    fn deterministic() {
        let syms: Vec<u32> = (0..200).map(|i| (i * i % 7) as u32).collect();
        let a = repair(&syms);
        let b = repair(&syms);
        assert_eq!(a.coverage, b.coverage);
        assert_eq!(a.n_rules, b.n_rules);
    }
}
