//! RRA — Rare Rule Anomaly (Senin et al., EDBT 2015), the Table 6 baseline.
//!
//! Pipeline (strategy NONE, the only strategy the paper deems a fair
//! comparison — Sec. 4.3):
//!
//! 1. SAX-discretize all sequences and apply *numerosity reduction* (keep
//!    a word only where it differs from the previously kept one).
//! 2. Grammar induction over the reduced word stream ([`grammar::repair`],
//!    a Sequitur-family compressor) → per-position *rule coverage*.
//! 3. Rule-sparse (low-coverage) intervals are the candidate anomalies;
//!    the outer search loop visits sequences in ascending mean coverage.
//! 4. Refinement: HOT SAX-style inner loop with best-so-far pruning over
//!    that outer order, counting distance calls.
//!
//! Like Grammarviz's RRA, the quality of the result hinges on how well
//! rule-sparseness predicts discords; the distance-call count is the
//! comparable cost metric. (Our refinement scans all sequences, so the
//! returned discord is exact — the original may return near-discords; the
//! call-count comparison is what Table 6 reproduces.)

pub mod grammar;

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::{Discord, ExclusionZones};
use crate::dist::Distance;
use crate::sax::SaxIndex;
use crate::util::rng::Rng64;

use super::{non_self_match, Algorithm, SearchReport};

/// The RRA engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct Rra;

/// Mean rule coverage per sequence start (the rarity score; low = rare).
pub fn coverage_curve(idx: &SaxIndex, n_points: usize, s: usize) -> Vec<f64> {
    let n = idx.len();
    // numerosity reduction over the word stream
    let mut kept_syms: Vec<u32> = Vec::new();
    let mut kept_pos: Vec<usize> = Vec::new();
    let mut prev: Option<usize> = None;
    for i in 0..n {
        let cid = idx.cluster_of[i];
        if prev != Some(cid) {
            kept_syms.push(cid as u32);
            kept_pos.push(i);
            prev = Some(cid);
        }
    }
    let g = grammar::repair(&kept_syms);

    // spread symbol coverage back over the points each kept word spans
    let mut point_cov = vec![0.0f64; n_points];
    for (t, &pos) in kept_pos.iter().enumerate() {
        let end = if t + 1 < kept_pos.len() {
            kept_pos[t + 1]
        } else {
            n
        };
        let c = g.coverage[t] as f64;
        // the word at `pos` describes the window [pos, pos+s); attribute
        // its coverage to the points up to the next kept word
        for p in pos..end.min(n_points) {
            point_cov[p] += c;
        }
    }

    // mean coverage per sequence window
    let mut prefix = vec![0.0f64; n_points + 1];
    for (i, &c) in point_cov.iter().enumerate() {
        prefix[i + 1] = prefix[i] + c;
    }
    (0..n)
        .map(|i| (prefix[(i + s).min(n_points)] - prefix[i]) / s as f64)
        .collect()
}

/// One refinement pass: best discord not excluded, outer loop in ascending
/// coverage order.
fn find_one(
    ctx: &SearchContext,
    dist: &dyn Distance,
    order: &[usize],
    random_order: &[usize],
    params: &SearchParams,
    zones: &ExclusionZones,
) -> Result<Option<Discord>> {
    let s = params.sax.s;
    let allow = params.allow_self_match;
    let mut best_dist = 0.0f64;
    let mut best: Option<Discord> = None;
    for &i in order {
        if !zones.allowed(i, s) {
            continue;
        }
        ctx.check(dist.calls())?;
        let mut nnd_i = f64::INFINITY;
        let mut ngh_i = usize::MAX;
        let mut pruned = false;
        for &j in random_order {
            if i == j || !non_self_match(i, j, s, allow) {
                continue;
            }
            let d = dist.dist_early(i, j, nnd_i);
            if d < nnd_i {
                nnd_i = d;
                ngh_i = j;
                if nnd_i < best_dist {
                    pruned = true;
                    break;
                }
            }
        }
        if !pruned && nnd_i.is_finite() && nnd_i >= best_dist {
            best_dist = nnd_i;
            best = Some(Discord {
                position: i,
                nnd: nnd_i,
                neighbor: ngh_i,
            });
        }
    }
    Ok(best)
}

impl Algorithm for Rra {
    fn name(&self) -> &'static str {
        "rra"
    }

    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        let s = params.sax.s;
        let ts = ctx.series();
        let n = ts.num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ctx.check(0)?;
        let start = Instant::now();
        ctx.notify_phase(self.name(), "prepare");
        let (stats, idx) = ctx.prepared(&params.sax);
        let dist = ctx.distance(&stats, params.distance_kind());
        let mut rng = Rng64::new(params.seed ^ 0x5252_4100); // "RRA"

        // rarity ordering from grammar coverage
        let cov = coverage_curve(&idx, ts.n_total(), s);
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            cov[a]
                .partial_cmp(&cov[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut random_order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut random_order);

        ctx.notify_phase(self.name(), "search");
        let mut zones = ExclusionZones::new();
        let mut discords = Vec::new();
        for rank in 0..params.k {
            let calls_before = dist.calls();
            let abandons_before = dist.abandons();
            let found =
                find_one(ctx, dist.as_ref(), &order, &random_order, params, &zones)?;
            ctx.trace_pass(&crate::obs::PassEvent {
                engine: self.name(),
                phase: "search",
                index: rank,
                candidates: n as u64,
                abandons: dist.abandons() - abandons_before,
                calls: dist.calls() - calls_before,
                best: found.as_ref().map(|d| d.nnd).unwrap_or(f64::NAN),
            });
            match found {
                Some(d) => {
                    zones.add(d.position, s);
                    ctx.notify_discord(rank, &d);
                    discords.push(d);
                }
                None => break,
            }
        }

        Ok(SearchReport {
            algo: self.name().to_string(),
            discords,
            distance_calls: dist.calls(),
            prep_calls: 0,
            elapsed: start.elapsed(),
            n_sequences: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::brute::BruteForce;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;
    use crate::ts::SeqStats;

    #[test]
    fn refinement_returns_the_exact_discord() {
        let ts = generators::ecg_like(1_500, 100, 1, 90).into_series("e");
        let params = SearchParams::new(80, 4, 4);
        let rra = Rra.run(&ts, &params).unwrap();
        let bf = BruteForce.run(&ts, &params).unwrap();
        assert!((rra.discords[0].nnd - bf.discords[0].nnd).abs() < 5e-8);
    }

    #[test]
    fn coverage_curve_has_right_length_and_sign() {
        let ts = generators::valve_like(2_000, 150, 1, 91).into_series("v");
        let s = 128;
        let params = SearchParams::new(s, 4, 4);
        let stats = SeqStats::compute(&ts, s);
        let idx = SaxIndex::build(&ts, &stats, &params.sax);
        let cov = coverage_curve(&idx, ts.n_total(), s);
        assert_eq!(cov.len(), ts.num_sequences(s));
        assert!(cov.iter().all(|&c| c >= 0.0));
        assert!(cov.iter().any(|&c| c > 0.0), "periodic data must compress");
    }

    #[test]
    fn anomaly_region_is_rule_sparse() {
        // periodic valve data with an injected glitch: the glitch window's
        // coverage should sit in the lower half of the distribution
        let mut pts = generators::valve_like(3_000, 200, 0, 92);
        let mut rng = crate::util::rng::Rng64::new(4);
        generators::inject(&mut pts, 1_500, 128, generators::Anomaly::Bump, &mut rng);
        let ts = pts.into_series("v");
        let s = 128;
        let params = SearchParams::new(s, 4, 4);
        let stats = SeqStats::compute(&ts, s);
        let idx = SaxIndex::build(&ts, &stats, &params.sax);
        let cov = coverage_curve(&idx, ts.n_total(), s);
        let mut sorted = cov.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        assert!(
            cov[1_500] <= median,
            "glitch coverage {} should be <= median {median}",
            cov[1_500]
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let ts = generators::respiration_like(1_800, 120, 1, 93).into_series("r");
        let params = SearchParams::new(100, 4, 4).with_seed(3);
        let a = Rra.run(&ts, &params).unwrap();
        let b = Rra.run(&ts, &params).unwrap();
        assert_eq!(a.distance_calls, b.distance_calls);
    }
}
