//! SCAMP/STOMP-style exact matrix profile (the Sec. 4.5 baseline).
//!
//! Computes the full self-join matrix profile — the exact nnd of *every*
//! sequence — in O(N²) time and O(N) space with the streaming dot-product
//! recurrence along diagonals:
//!
//!   QT(i+1, j+1) = QT(i, j) − p_i·p_j + p_{i+s}·p_{j+s}
//!
//! and the paper's Eq. 3 to turn dots into z-normalized distances. The
//! paper notes single-core SCAMP is essentially STOMP; that is what the
//! serial path implements. An XLA-tiled variant (the `mp_tile` Pallas
//! artifact) lives in [`crate::runtime`] and is exercised by the fig6
//! bench and the end-to-end example.
//!
//! "Distance calls" for SCAMP are the number of evaluated pairs — the
//! paper compares it by runtime only (its cost is data-independent), but
//! counting keeps the reports uniform.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::NndProfile;
use crate::ts::{SeqStats, TimeSeries};

use super::{brute::BruteForce, Algorithm, SearchReport};

/// The serial matrix-profile engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct Scamp;

impl Scamp {
    /// Exact matrix profile (z-normalized Euclidean, non-self-match band
    /// of half-width s). Returns the profile and the number of evaluated
    /// pairs.
    pub fn matrix_profile(ts: &TimeSeries, stats: &SeqStats) -> (NndProfile, u64) {
        let s = stats.s;
        let n = stats.len();
        let pts = &ts.points;
        let mut profile = NndProfile::new(n);
        let mut pairs = 0u64;
        let sf = s as f64;

        // Walk diagonals j - i = diag for diag in s..n (the exclusion band
        // |i-j| < s is skipped entirely).
        for diag in s..n {
            // initial dot product QT(0, diag)
            let mut qt = 0.0;
            for t in 0..s {
                qt += pts[t] * pts[diag + t];
            }
            let mut i = 0usize;
            loop {
                let j = i + diag;
                // Eq. 3: d = sqrt(2s(1 − (qt − s·μiμj) / (s·σiσj)))
                let corr = (qt - sf * stats.mean[i] * stats.mean[j])
                    / (sf * stats.std[i] * stats.std[j]);
                let d = (2.0 * sf * (1.0 - corr)).max(0.0).sqrt();
                profile.observe(i, j, d);
                pairs += 1;
                i += 1;
                if i + diag >= n {
                    break;
                }
                // slide the window: remove head product, add tail product
                qt += pts[i + s - 1] * pts[i + diag + s - 1] - pts[i - 1] * pts[i + diag - 1];
            }
        }
        (profile, pairs)
    }
}

impl Algorithm for Scamp {
    fn name(&self) -> &'static str {
        "scamp"
    }

    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        let s = params.sax.s;
        let ts = ctx.series();
        let n = ts.num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ensure!(
            params.znormalize,
            "matrix-profile path is z-normalized only"
        );
        ensure!(
            !params.allow_self_match,
            "matrix profile uses the standard exclusion band"
        );
        // data-independent cost: the budget is enforced up front
        super::ensure_profile_budget(ctx, n, s)?;
        ctx.check(0)?;
        let start = Instant::now();
        ctx.notify_phase(self.name(), "prepare");
        let stats = ctx.stats(s);
        ctx.notify_phase(self.name(), "search");
        let (profile, pairs) = Self::matrix_profile(ts, &stats);
        let discords = BruteForce::discords_from_profile(&profile, s, params.k);
        ctx.trace_pass(&crate::obs::PassEvent {
            engine: self.name(),
            phase: "search",
            index: 0,
            candidates: n as u64,
            abandons: 0,
            calls: pairs,
            best: discords.first().map(|d| d.nnd).unwrap_or(f64::NAN),
        });
        for (rank, d) in discords.iter().enumerate() {
            ctx.notify_discord(rank, d);
        }
        // NOT stored as a context warm profile: Eq. 3 dot-form distances
        // differ from the scalar Eq. 2 loop by float noise, so this
        // profile is not a strict upper bound for the Distance-backend
        // engines.
        Ok(SearchReport {
            algo: self.name().to_string(),
            discords,
            distance_calls: pairs,
            prep_calls: 0,
            elapsed: start.elapsed(),
            n_sequences: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::brute::BruteForce;
    use crate::config::SearchParams;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn profile_matches_brute_force() {
        let ts = generators::ecg_like(1_000, 90, 1, 80).into_series("e");
        let s = 64;
        let params = SearchParams::new(s, 4, 4);
        let stats = SeqStats::compute(&ts, s);
        let dist = crate::dist::CountingDistance::new(
            &ts,
            &stats,
            crate::dist::DistanceKind::Znorm,
        );
        let ctx = SearchContext::builder(&ts).build();
        let exact = BruteForce::exact_profile(&ctx, &params, &dist).unwrap();
        let (mp, _) = Scamp::matrix_profile(&ts, &stats);
        for i in 0..mp.len() {
            assert!(
                (mp.nnd[i] - exact.nnd[i]).abs() < 1e-6,
                "i={i}: {} vs {}",
                mp.nnd[i],
                exact.nnd[i]
            );
        }
    }

    #[test]
    fn discords_match_brute() {
        let ts = generators::sine_with_noise(1_500, 0.05, 81).into_series("s");
        let params = SearchParams::new(100, 4, 4).with_discords(3);
        let sc = Scamp.run(&ts, &params).unwrap();
        let bf = BruteForce.run(&ts, &params).unwrap();
        for (a, b) in sc.discords.iter().zip(&bf.discords) {
            assert!((a.nnd - b.nnd).abs() < 1e-6);
        }
    }

    #[test]
    fn pair_count_is_quadratic_and_data_independent() {
        let s = 50;
        let params = SearchParams::new(s, 5, 4);
        let a = generators::ecg_like(800, 70, 1, 1).into_series("a");
        let b = generators::random_walk(800, 1.0, 2).into_series("b");
        let ra = Scamp.run(&a, &params).unwrap();
        let rb = Scamp.run(&b, &params).unwrap();
        assert_eq!(ra.distance_calls, rb.distance_calls);
        let n = a.num_sequences(s) as u64;
        // all pairs above the band: sum_{diag=s}^{n-1} (n - diag)
        let expect: u64 = (s as u64..n).map(|d| n - d).sum();
        assert_eq!(ra.distance_calls, expect);
    }

    #[test]
    fn rejects_incompatible_protocols() {
        let ts = generators::ecg_like(600, 70, 1, 82).into_series("e");
        let raw = SearchParams::new(64, 4, 4).dadd_protocol();
        assert!(Scamp.run(&ts, &raw).is_err());
    }
}
