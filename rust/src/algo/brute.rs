//! Brute-force discord search: the O(N²) ground truth (paper Sec. 2.3).
//!
//! Computes the exact nnd profile by evaluating every non-self-match pair
//! once (symmetric update), then extracts the k discords by repeated argmax
//! under the exclusion zones. Used as the correctness oracle for every
//! other engine; only suitable for small N.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::{Discord, ExclusionZones, NndProfile};
use crate::dist::Distance;

use super::{non_self_match, Algorithm, SearchReport};

/// The brute-force engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct BruteForce;

impl BruteForce {
    /// Exact nnd profile of the context's series (every pair evaluated
    /// once through `dist`). Checks the context's run controls once per
    /// outer row.
    pub fn exact_profile(
        ctx: &SearchContext,
        params: &SearchParams,
        dist: &dyn Distance,
    ) -> Result<NndProfile> {
        let s = params.sax.s;
        let n = ctx.series().num_sequences(s);
        let mut profile = NndProfile::new(n);
        for i in 0..n {
            ctx.check(dist.calls())?;
            for j in (i + 1)..n {
                if non_self_match(i, j, s, params.allow_self_match) {
                    let d = dist.dist(i, j);
                    profile.observe(i, j, d);
                }
            }
        }
        Ok(profile)
    }

    /// Extract the top-k discords from an exact profile.
    pub fn discords_from_profile(
        profile: &NndProfile,
        s: usize,
        k: usize,
    ) -> Vec<Discord> {
        let mut zones = ExclusionZones::new();
        let mut out = Vec::new();
        for _ in 0..k {
            let mut best: Option<usize> = None;
            for i in 0..profile.len() {
                if !zones.allowed(i, s) {
                    continue;
                }
                if profile.nnd[i].is_finite()
                    && best.map(|b| profile.nnd[i] > profile.nnd[b]).unwrap_or(true)
                {
                    best = Some(i);
                }
            }
            let Some(b) = best else { break };
            out.push(Discord {
                position: b,
                nnd: profile.nnd[b],
                neighbor: profile.ngh[b],
            });
            zones.add(b, s);
        }
        out
    }
}

impl Algorithm for BruteForce {
    fn name(&self) -> &'static str {
        "brute"
    }

    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        let s = params.sax.s;
        let n = ctx.series().num_sequences(s);
        ensure!(n >= 2, "series too short for s={s}");
        ctx.check(0)?;
        let start = Instant::now();
        ctx.notify_phase(self.name(), "prepare");
        let stats = ctx.stats(s);
        let dist = ctx.distance(&stats, params.distance_kind());
        ctx.notify_phase(self.name(), "search");
        let profile = Self::exact_profile(ctx, params, dist.as_ref())?;
        let discords = Self::discords_from_profile(&profile, s, params.k);
        ctx.trace_pass(&crate::obs::PassEvent {
            engine: self.name(),
            phase: "search",
            index: 0,
            candidates: n as u64,
            abandons: dist.abandons(),
            calls: dist.calls(),
            best: discords.first().map(|d| d.nnd).unwrap_or(f64::NAN),
        });
        for (rank, d) in discords.iter().enumerate() {
            ctx.notify_discord(rank, d);
        }
        // the exact profile is the best possible warm start for later
        // searches on this context (exact sessions only — an f32 backend
        // must not feed the cache)
        if dist.is_exact() {
            ctx.store_warm_profile(s, dist.kind(), params.allow_self_match, profile);
        }
        Ok(SearchReport {
            algo: self.name().to_string(),
            discords,
            distance_calls: dist.calls(),
            prep_calls: 0,
            elapsed: start.elapsed(),
            n_sequences: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn finds_injected_anomaly() {
        // A flat sine with one injected bump: the discord must cover it.
        let mut pts = generators::sine_with_noise(1_200, 0.05, 3);
        let mut rng = crate::util::rng::Rng64::new(1);
        generators::inject(&mut pts, 600, 64, generators::Anomaly::Bump, &mut rng);
        let ts = pts.into_series("bump");
        let params = SearchParams::new(64, 4, 4);
        let rep = BruteForce.run(&ts, &params).unwrap();
        let d = &rep.discords[0];
        assert!(
            (537..=663).contains(&d.position),
            "discord at {} should overlap the bump at 600..664",
            d.position
        );
        assert!(d.nnd > 0.0);
    }

    #[test]
    fn call_count_is_all_pairs() {
        let ts = generators::sine_with_noise(300, 0.5, 1).into_series("t");
        let s = 50;
        let params = SearchParams::new(s, 5, 4);
        let rep = BruteForce.run(&ts, &params).unwrap();
        let n = ts.num_sequences(s);
        let mut expect = 0u64;
        for i in 0..n {
            for j in (i + 1)..n {
                if j - i >= s {
                    expect += 1;
                }
            }
        }
        assert_eq!(rep.distance_calls, expect);
    }

    #[test]
    fn k_discords_do_not_overlap() {
        let ts = generators::ecg_like(2_000, 120, 2, 9).into_series("ecg");
        let params = SearchParams::new(100, 4, 4).with_discords(4);
        let rep = BruteForce.run(&ts, &params).unwrap();
        assert!(rep.discords.len() >= 2);
        for (a_idx, a) in rep.discords.iter().enumerate() {
            for b in &rep.discords[a_idx + 1..] {
                assert!(
                    a.position.abs_diff(b.position) >= 100,
                    "{} vs {}",
                    a.position,
                    b.position
                );
            }
        }
        // sorted by nnd descending
        for w in rep.discords.windows(2) {
            assert!(w[0].nnd >= w[1].nnd - 1e-12);
        }
    }

    #[test]
    fn neighbor_is_not_self_match() {
        let ts = generators::valve_like(1_500, 150, 1, 4).into_series("v");
        let params = SearchParams::new(128, 4, 4);
        let rep = BruteForce.run(&ts, &params).unwrap();
        let d = &rep.discords[0];
        assert!(d.position.abs_diff(d.neighbor) >= 128);
    }
}
