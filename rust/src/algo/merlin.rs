//! MERLIN (Nakamura, Imamura, Mercer & Keogh, ICDM 2020): parameter-free
//! discovery of discords of *every* length in a range.
//!
//! The paper's related-work section points to MERLIN as the DADD-based
//! successor for arbitrary-length discord scans; it is the natural
//! "extension feature" for an HST framework and reuses our [`Dadd`]
//! engine as its inner oracle.
//!
//! Algorithm (following the MERLIN paper's r-selection schedule):
//! * L = minL: start r = 2·√L (an upper bound for z-normalized distance)
//!   and halve until DRAG succeeds.
//! * next 4 lengths: r = 0.99 · (previous length's discord nnd).
//! * afterwards: r = μ − 2σ of the last 5 discord nnds; on failure retry
//!   with r ← 0.99·r.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::{LengthRange, SearchParams};
use crate::context::SearchContext;
use crate::discord::Discord;
use crate::dist::DistanceKind;
use crate::metrics::length_normalized_nnd;
use crate::ts::TimeSeries;

use super::dadd::Dadd;
use super::{Algorithm, SearchReport};

/// One per-length result.
#[derive(Debug, Clone)]
pub struct LengthDiscord {
    /// Sequence length L.
    pub s: usize,
    /// Top discord at that length.
    pub discord: Discord,
    /// The r value DRAG finally succeeded with.
    pub r_used: f64,
    /// DRAG attempts needed (r re-selections).
    pub attempts: usize,
}

/// MERLIN driver over our DADD engine.
///
/// The all-zero [`Default`] is the registry form (`algo::by_name("merlin")`):
/// it derives the scan range from the search params at
/// [`run_ctx`](Algorithm::run_ctx) time — lengths `[s/2, s]` in steps of
/// `max(1, s/8)`.
#[derive(Debug, Clone, Default)]
pub struct Merlin {
    /// Smallest discord length scanned (inclusive).
    pub min_len: usize,
    /// Largest discord length scanned (inclusive).
    pub max_len: usize,
    /// Step between scanned lengths (1 in the original; larger steps make
    /// coarse scans cheap).
    pub step: usize,
}

impl Merlin {
    /// Scan every length in `[min_len, max_len]` (step 1).
    pub fn new(min_len: usize, max_len: usize) -> Merlin {
        Merlin {
            min_len,
            max_len,
            step: 1,
        }
    }

    /// Coarser scan: only every `step`-th length.
    pub fn with_step(mut self, step: usize) -> Merlin {
        self.step = step.max(1);
        self
    }

    /// Scan a shared [`LengthRange`] (the form `hst-vl` comparisons use);
    /// panics on an invalid range — [`scan`](Self::scan) re-validates
    /// fallibly for ranges built from the raw public fields.
    pub fn from_range(range: LengthRange) -> Merlin {
        range.validate().expect("invalid length range");
        Merlin {
            min_len: range.min,
            max_len: range.max,
            step: range.step,
        }
    }

    /// The configured fields as the shared [`LengthRange`] type.
    pub fn range(&self) -> LengthRange {
        LengthRange {
            min: self.min_len,
            max: self.max_len,
            step: self.step,
        }
    }

    /// One-shot scan of `ts` through a throwaway context (see
    /// [`scan`](Self::scan) for the session form).
    pub fn scan_series(&self, ts: &TimeSeries) -> Result<(Vec<LengthDiscord>, u64)> {
        let ctx = SearchContext::builder(ts).build();
        self.scan(&ctx)
    }

    /// Scan all lengths over the context's series; returns one discord
    /// per length plus the total distance-call count. The context's stats
    /// cache is shared across lengths (and with any other engine using
    /// the same context).
    pub fn scan(&self, ctx: &SearchContext) -> Result<(Vec<LengthDiscord>, u64)> {
        let ts = ctx.series();
        let range = self.range();
        range.validate().map_err(|e| anyhow::anyhow!(e))?;
        ensure!(
            ts.n_total() >= 2 * range.max,
            "series too short for max_len {}",
            range.max
        );

        let mut out: Vec<LengthDiscord> = Vec::new();
        let mut total_calls = 0u64;
        let mut recent: Vec<f64> = Vec::new(); // last discord nnds

        for (li, s) in range.lengths().enumerate() {
            // Budget is enforced cumulatively across lengths here; within
            // one length, DADD checks against the per-length session, so
            // the overshoot is bounded by one length's cost.
            ctx.check(total_calls)?;
            let stats = ctx.stats(s);
            let dist = ctx.distance(&stats, DistanceKind::Znorm);
            let params = SearchParams::new(s, pick_p(s), 4);

            // r schedule
            let mut r = match recent.len() {
                0 => 2.0 * (s as f64).sqrt(),
                1..=4 => 0.99 * recent.last().unwrap(),
                _ => {
                    let tail = &recent[recent.len() - 5..];
                    let mu = tail.iter().sum::<f64>() / 5.0;
                    let var =
                        tail.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / 5.0;
                    (mu - 2.0 * var.sqrt()).max(1e-6)
                }
            };

            let mut attempts = 0;
            let found = loop {
                attempts += 1;
                ensure!(attempts <= 64, "MERLIN failed to converge at L={s}");
                let dadd = Dadd {
                    r,
                    page_size: 10_000,
                };
                let outcome = dadd.run_detailed(ctx, &params, dist.as_ref())?;
                if let Some(d) = outcome.discords.first() {
                    break d.clone();
                }
                // r too big: the discord's nnd is below r
                r *= if recent.is_empty() { 0.5 } else { 0.99 };
            };
            total_calls += dist.calls();
            // one trace pass per scanned length: the whole r-schedule for
            // this L, however many DRAG attempts it took
            ctx.trace_pass(&crate::obs::PassEvent {
                engine: "merlin",
                phase: "search",
                index: li,
                candidates: stats.len() as u64,
                abandons: dist.abandons(),
                calls: dist.calls(),
                best: found.nnd,
            });
            recent.push(found.nnd);
            out.push(LengthDiscord {
                s,
                discord: found,
                r_used: r,
                attempts,
            });
        }
        Ok((out, total_calls))
    }
}

impl Algorithm for Merlin {
    fn name(&self) -> &'static str {
        "merlin"
    }

    /// Multi-length scan as a registry engine: lengths come from the
    /// configured range, from `params.s_range`, or — for the all-zero
    /// [`Default`] registry form with no range in the params — from
    /// [`LengthRange::around`]`(params.sax.s)`. The report carries the
    /// top `params.k` discords across all lengths, ranked by the
    /// length-normalized score
    /// [`length_normalized_nnd`](crate::metrics::length_normalized_nnd)
    /// (`nnd/√s` — the same scale `hst-vl` ranks on; raw nnd grows with
    /// √s, which made raw ranking favor longer lengths). Per-length raw
    /// results remain available via [`scan`](Self::scan).
    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        let s = params.sax.s;
        ctx.check(0)?;
        let start = Instant::now();
        ctx.notify_phase(self.name(), "prepare");
        let range = if self.max_len == 0 {
            params.s_range.unwrap_or_else(|| LengthRange::around(s))
        } else {
            self.range()
        };
        let scan_cfg = Merlin {
            min_len: range.min,
            max_len: range.max,
            step: range.step,
        };
        ctx.notify_phase(self.name(), "search");
        let (found, calls) = scan_cfg.scan(ctx)?;
        let mut ranked: Vec<&LengthDiscord> = found.iter().collect();
        ranked.sort_by(|a, b| {
            let sa = length_normalized_nnd(a.discord.nnd, a.s);
            let sb = length_normalized_nnd(b.discord.nnd, b.s);
            sb.partial_cmp(&sa)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.s.cmp(&b.s))
        });
        let discords: Vec<Discord> = ranked
            .iter()
            .take(params.k)
            .map(|ld| ld.discord.clone())
            .collect();
        for (rank, d) in discords.iter().enumerate() {
            ctx.notify_discord(rank, d);
        }
        Ok(SearchReport {
            algo: self.name().to_string(),
            discords,
            distance_calls: calls,
            prep_calls: 0,
            elapsed: start.elapsed(),
            n_sequences: ctx.series().num_sequences(s),
        })
    }
}

/// Largest P <= 8 dividing s (MERLIN itself is SAX-free; P only matters
/// because our DADD shares the search-params plumbing).
fn pick_p(s: usize) -> usize {
    for p in [8usize, 6, 5, 4, 3, 2] {
        if s % p == 0 {
            return p;
        }
    }
    1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{brute::BruteForce, Algorithm};
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn per_length_discords_match_brute() {
        let ts = generators::ecg_like(1_400, 100, 1, 400).into_series("e");
        let merlin = Merlin::new(60, 72).with_step(4);
        let (found, calls) = merlin.scan_series(&ts).unwrap();
        assert_eq!(found.len(), 4); // 60, 64, 68, 72
        assert!(calls > 0);
        for ld in &found {
            let params = SearchParams::new(ld.s, pick_p(ld.s), 4);
            let truth = BruteForce.run(&ts, &params).unwrap();
            assert!(
                (ld.discord.nnd - truth.discords[0].nnd).abs() < 5e-8,
                "L={}: merlin {} vs brute {}",
                ld.s,
                ld.discord.nnd,
                truth.discords[0].nnd
            );
        }
    }

    #[test]
    fn r_schedule_warm_starts_after_first_length() {
        let ts = generators::valve_like(1_600, 150, 1, 401).into_series("v");
        let merlin = Merlin::new(96, 104).with_step(2);
        let (found, _) = merlin.scan_series(&ts).unwrap();
        // after the cold start, the warm-started lengths converge fast
        for ld in &found[1..] {
            assert!(ld.attempts <= 8, "L={} took {} attempts", ld.s, ld.attempts);
        }
    }

    #[test]
    fn rejects_degenerate_ranges() {
        let ts = generators::sine_with_noise(500, 0.1, 402).into_series("s");
        assert!(Merlin::new(100, 50).scan_series(&ts).is_err());
        assert!(
            Merlin::new(100, 400).scan_series(&ts).is_err(),
            "series too short"
        );
    }

    #[test]
    fn registry_form_scans_around_params_s() {
        // by_name("merlin") returns the all-zero Default: the scan range
        // derives from params.sax.s via the shared LengthRange::around
        let ts = generators::ecg_like(900, 80, 1, 403).into_series("e");
        let engine = crate::algo::by_name("merlin").unwrap();
        let params = SearchParams::new(48, 4, 4);
        let rep = engine.run(&ts, &params).unwrap();
        assert_eq!(rep.algo, "merlin");
        assert_eq!(rep.discords.len(), 1);
        assert!(rep.distance_calls > 0);
        // the reported discord is the per-length scan's best under the
        // length-normalized (nnd/√s) ranking
        let (found, _) = Merlin::from_range(LengthRange::around(48))
            .scan_series(&ts)
            .unwrap();
        let best = found
            .iter()
            .max_by(|a, b| {
                length_normalized_nnd(a.discord.nnd, a.s)
                    .partial_cmp(&length_normalized_nnd(b.discord.nnd, b.s))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(rep.discords[0].position, best.discord.position);
        assert_eq!(
            rep.discords[0].nnd.to_bits(),
            best.discord.nnd.to_bits()
        );
    }

    #[test]
    fn params_s_range_overrides_the_derivation() {
        let ts = generators::ecg_like(900, 80, 1, 404).into_series("e");
        let range = LengthRange::new(40, 48, 4);
        let params = SearchParams::new(48, 4, 4).with_length_range(range);
        let rep = Merlin::default()
            .run_ctx(&SearchContext::builder(&ts).build(), &params)
            .unwrap();
        // the explicit range scans 3 lengths; its best matches a direct scan
        let (found, _) = Merlin::from_range(range).scan_series(&ts).unwrap();
        assert_eq!(found.len(), 3);
        let best = found
            .iter()
            .max_by(|a, b| {
                length_normalized_nnd(a.discord.nnd, a.s)
                    .partial_cmp(&length_normalized_nnd(b.discord.nnd, b.s))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(rep.discords[0].position, best.discord.position);
        // an explicitly configured engine wins over both
        let rep2 = Merlin::new(44, 48)
            .with_step(4)
            .run_ctx(&SearchContext::builder(&ts).build(), &params)
            .unwrap();
        assert!(rep2.distance_calls > 0);
        assert_eq!(Merlin::new(44, 48).with_step(4).range().count(), 2);
    }
}
