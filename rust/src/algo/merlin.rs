//! MERLIN (Nakamura, Imamura, Mercer & Keogh, ICDM 2020): parameter-free
//! discovery of discords of *every* length in a range.
//!
//! The paper's related-work section points to MERLIN as the DADD-based
//! successor for arbitrary-length discord scans; it is the natural
//! "extension feature" for an HST framework and reuses our [`Dadd`]
//! engine as its inner oracle.
//!
//! Algorithm (following the MERLIN paper's r-selection schedule):
//! * L = minL: start r = 2·√L (an upper bound for z-normalized distance)
//!   and halve until DRAG succeeds.
//! * next 4 lengths: r = 0.99 · (previous length's discord nnd).
//! * afterwards: r = μ − 2σ of the last 5 discord nnds; on failure retry
//!   with r ← 0.99·r.

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::discord::Discord;
use crate::dist::{CountingDistance, DistanceKind};
use crate::ts::{SeqStats, TimeSeries};

use super::dadd::Dadd;

/// One per-length result.
#[derive(Debug, Clone)]
pub struct LengthDiscord {
    /// Sequence length L.
    pub s: usize,
    /// Top discord at that length.
    pub discord: Discord,
    /// The r value DRAG finally succeeded with.
    pub r_used: f64,
    /// DRAG attempts needed (r re-selections).
    pub attempts: usize,
}

/// MERLIN driver over our DADD engine.
#[derive(Debug, Clone)]
pub struct Merlin {
    /// Smallest discord length scanned (inclusive).
    pub min_len: usize,
    /// Largest discord length scanned (inclusive).
    pub max_len: usize,
    /// Step between scanned lengths (1 in the original; larger steps make
    /// coarse scans cheap).
    pub step: usize,
}

impl Merlin {
    /// Scan every length in `[min_len, max_len]` (step 1).
    pub fn new(min_len: usize, max_len: usize) -> Merlin {
        Merlin {
            min_len,
            max_len,
            step: 1,
        }
    }

    /// Coarser scan: only every `step`-th length.
    pub fn with_step(mut self, step: usize) -> Merlin {
        self.step = step.max(1);
        self
    }

    /// Scan all lengths; returns one discord per length plus the total
    /// distance-call count.
    pub fn run(&self, ts: &TimeSeries) -> Result<(Vec<LengthDiscord>, u64)> {
        ensure!(self.min_len >= 4, "min_len too small");
        ensure!(self.min_len <= self.max_len, "empty length range");
        ensure!(
            ts.n_total() >= 2 * self.max_len,
            "series too short for max_len {}",
            self.max_len
        );

        let mut out: Vec<LengthDiscord> = Vec::new();
        let mut total_calls = 0u64;
        let mut recent: Vec<f64> = Vec::new(); // last discord nnds

        let mut s = self.min_len;
        while s <= self.max_len {
            let stats = SeqStats::compute(ts, s);
            let dist = CountingDistance::new(ts, &stats, DistanceKind::Znorm);
            let params = SearchParams::new(s, pick_p(s), 4);

            // r schedule
            let mut r = match recent.len() {
                0 => 2.0 * (s as f64).sqrt(),
                1..=4 => 0.99 * recent.last().unwrap(),
                _ => {
                    let tail = &recent[recent.len() - 5..];
                    let mu = tail.iter().sum::<f64>() / 5.0;
                    let var =
                        tail.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / 5.0;
                    (mu - 2.0 * var.sqrt()).max(1e-6)
                }
            };

            let mut attempts = 0;
            let found = loop {
                attempts += 1;
                ensure!(attempts <= 64, "MERLIN failed to converge at L={s}");
                let dadd = Dadd {
                    r,
                    page_size: 10_000,
                };
                let outcome = dadd.run_detailed(ts, &params, &dist);
                if let Some(d) = outcome.discords.first() {
                    break d.clone();
                }
                // r too big: the discord's nnd is below r
                r *= if recent.is_empty() { 0.5 } else { 0.99 };
            };
            total_calls += dist.calls();
            recent.push(found.nnd);
            out.push(LengthDiscord {
                s,
                discord: found,
                r_used: r,
                attempts,
            });
            s += self.step;
        }
        Ok((out, total_calls))
    }
}

/// Largest P <= 8 dividing s (MERLIN itself is SAX-free; P only matters
/// because our DADD shares the search-params plumbing).
fn pick_p(s: usize) -> usize {
    for p in [8usize, 6, 5, 4, 3, 2] {
        if s % p == 0 {
            return p;
        }
    }
    1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{brute::BruteForce, Algorithm};
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn per_length_discords_match_brute() {
        let ts = generators::ecg_like(1_400, 100, 1, 400).into_series("e");
        let merlin = Merlin::new(60, 72).with_step(4);
        let (found, calls) = merlin.run(&ts).unwrap();
        assert_eq!(found.len(), 4); // 60, 64, 68, 72
        assert!(calls > 0);
        for ld in &found {
            let params = SearchParams::new(ld.s, pick_p(ld.s), 4);
            let truth = BruteForce.run(&ts, &params).unwrap();
            assert!(
                (ld.discord.nnd - truth.discords[0].nnd).abs() < 5e-8,
                "L={}: merlin {} vs brute {}",
                ld.s,
                ld.discord.nnd,
                truth.discords[0].nnd
            );
        }
    }

    #[test]
    fn r_schedule_warm_starts_after_first_length() {
        let ts = generators::valve_like(1_600, 150, 1, 401).into_series("v");
        let merlin = Merlin::new(96, 104).with_step(2);
        let (found, _) = merlin.run(&ts).unwrap();
        // after the cold start, the warm-started lengths converge fast
        for ld in &found[1..] {
            assert!(ld.attempts <= 8, "L={} took {} attempts", ld.s, ld.attempts);
        }
    }

    #[test]
    fn rejects_degenerate_ranges() {
        let ts = generators::sine_with_noise(500, 0.1, 402).into_series("s");
        assert!(Merlin::new(100, 50).run(&ts).is_err());
        assert!(Merlin::new(100, 400).run(&ts).is_err(), "series too short");
    }
}
