//! Variable-length discord search: the `hst-vl` engine.
//!
//! MERLIN answers "find the discord at *every* length" by re-running a
//! near-cold DADD per length — each length pays its own r-schedule
//! retries and a fresh candidate scan. This subsystem keeps the question
//! but retires the cold restarts: one [`HstVl`] scan walks a
//! [`LengthRange`] ascending and makes the per-length
//! [`SearchContext`](crate::context::SearchContext) caches talk to each
//! other through a [`VlContext`]:
//!
//! * rolling window sums extend from `s` to `s + step` instead of being
//!   recomputed (bit-equal to the cold recompute — see
//!   [`context`](self::context));
//! * the refined [`NndProfile`](crate::discord::NndProfile) each length
//!   leaves behind is carried to the next length as a warm upper-bound
//!   profile (exact re-evaluation of the carried neighbor pairs; the
//!   previous length's joint SAX clusters stand in when a neighbor is
//!   lost), so every length after the first skips HST's warm-up chain
//!   and starts from a profile that is already nearly tight.
//!
//! Exactness is non-negotiable: each per-length search *is*
//! [`HstSearch`](crate::algo::hst::HstSearch)'s serial engine, handed a
//! valid warm profile — positions and nnd bit patterns are identical to
//! running serial `hst` independently at every length; only the call
//! counts drop. Cross-length results are ranked on the length-normalized
//! score [`metrics::length_normalized_nnd`] (`nnd/√s`), the same scale
//! [`merlin`](crate::algo::merlin) reports on.
//!
//! ```
//! use hstime::prelude::*;
//!
//! let ts = generators::ecg_like(1_000, 80, 1, 7).into_series("demo");
//! let ctx = SearchContext::builder(&ts).build();
//! let params = SearchParams::new(64, 4, 4)
//!     .with_length_range(LengthRange::new(48, 64, 8));
//! let report = HstVl::default().scan(&ctx, &params).unwrap();
//! assert_eq!(report.lengths.len(), 3); // s = 48, 56, 64
//! assert!(report.lengths[1].warm, "later lengths start warm");
//! assert_eq!(report.ranked[0].score,
//!     metrics::length_normalized_nnd(
//!         report.ranked[0].discord.nnd, report.ranked[0].s));
//! ```
//!
//! [`metrics::length_normalized_nnd`]: crate::metrics::length_normalized_nnd

pub mod context;

use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::algo::hst::HstSearch;
use crate::algo::{Algorithm, SearchReport};
use crate::config::{LengthRange, SaxParams, SearchParams};
use crate::context::SearchContext;
use crate::discord::Discord;
use crate::metrics::length_normalized_nnd;
use crate::util::json::Json;

pub use context::VlContext;

/// Canonical registry id of the variable-length engine.
pub const ENGINE_ID: &str = "hst-vl";

/// The variable-length work-sharing engine.
///
/// The all-zero [`Default`] is the registry form
/// (`algo::by_name("hst-vl")`): the scanned range comes from
/// `SearchParams.s_range` when set, else
/// [`LengthRange::around`]`(params.sax.s)` — the same derivation
/// `merlin` uses, so the two engines cover identical ranges for
/// identical requests.
#[derive(Debug, Clone, Default)]
pub struct HstVl {
    /// Explicit scan range; the all-zero sentinel defers to the params.
    pub range: LengthRange,
}

/// One scanned length: the serial-HST report plus the cross-length
/// bookkeeping.
#[derive(Debug, Clone)]
pub struct VlLength {
    /// Sequence length s.
    pub s: usize,
    /// The per-length search report (bit-identical to serial `hst`).
    pub report: SearchReport,
    /// Exact distance calls the warm-profile transfer into this length
    /// spent (0 for the cold first length).
    pub transfer_calls: u64,
    /// Whether this length started from a transferred warm profile.
    pub warm: bool,
}

/// One cross-length ranked discord.
#[derive(Debug, Clone)]
pub struct VlDiscord {
    /// The length the discord was found at.
    pub s: usize,
    /// The discord (raw nnd, as serial `hst` reports it).
    pub discord: Discord,
    /// Its length-normalized score `nnd/√s`
    /// ([`length_normalized_nnd`]).
    pub score: f64,
}

/// Outcome of one [`HstVl::scan`].
#[derive(Debug, Clone)]
pub struct VlReport {
    /// Per-length results, ascending in s.
    pub lengths: Vec<VlLength>,
    /// All discords across all lengths, ranked by descending
    /// [`VlDiscord::score`] (ties: shorter s, then lower position).
    pub ranked: Vec<VlDiscord>,
    /// Total distance calls across the whole scan (per-length searches
    /// plus the warm-profile transfers).
    pub total_calls: u64,
    /// Wall-clock time of the whole scan.
    pub elapsed: Duration,
}

impl VlReport {
    /// Serialize for reports and the service protocol.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("algo", ENGINE_ID)
            .set("total_calls", self.total_calls)
            .set("elapsed_secs", self.elapsed.as_secs_f64())
            .set(
                "lengths",
                self.lengths
                    .iter()
                    .map(|l| {
                        Json::obj()
                            .set("s", l.s)
                            .set("warm", l.warm)
                            .set("transfer_calls", l.transfer_calls)
                            .set("report", l.report.to_json())
                    })
                    .collect::<Vec<_>>(),
            )
            .set(
                "ranked",
                self.ranked
                    .iter()
                    .map(|r| {
                        r.discord
                            .to_json()
                            .set("s", r.s)
                            .set("score", r.score)
                    })
                    .collect::<Vec<_>>(),
            )
    }
}

impl HstVl {
    /// Scan an explicit, validated range (panics on an invalid one; the
    /// JSON path validates at parse time instead).
    pub fn from_range(range: LengthRange) -> HstVl {
        range.validate().expect("invalid length range");
        HstVl { range }
    }

    /// The range a scan under `params` covers: the engine's own range
    /// when configured, else `params.s_range`, else
    /// [`LengthRange::around`]`(params.sax.s)`.
    pub fn resolved_range(&self, params: &SearchParams) -> LengthRange {
        if !self.range.is_unset() {
            self.range
        } else if let Some(r) = params.s_range {
            r
        } else {
            LengthRange::around(params.sax.s)
        }
    }

    /// The per-length search parameters of the scan: `base` with its
    /// length replaced by `s` (the base P is kept when it divides `s`,
    /// else the shared [`SaxParams::default_p`] rule applies). Public so
    /// tests and benches can construct the *identical* per-length serial
    /// `hst` baseline the bit-identity guarantee is stated against.
    pub fn params_for_length(base: &SearchParams, s: usize) -> SearchParams {
        let p = if base.sax.p != 0 && s % base.sax.p == 0 {
            base.sax.p
        } else {
            SaxParams::default_p(s)
        };
        SearchParams {
            sax: SaxParams { s, p, alphabet: base.sax.alphabet },
            k: base.k,
            seed: base.seed,
            znormalize: base.znormalize,
            allow_self_match: base.allow_self_match,
            threads: base.threads,
            s_range: None,
        }
    }

    /// Scan every length of the resolved range in one ascending pass.
    ///
    /// The first length runs serial HST cold; every later length first
    /// advances the rolling stats ([`VlContext::advance`]), carries the
    /// previous length's refined profile forward
    /// ([`VlContext::transfer_profile`]), and then runs serial HST warm.
    /// The context's distance-call budget is enforced cumulatively
    /// across lengths, like `merlin`'s scan.
    pub fn scan(
        &self,
        ctx: &SearchContext,
        base: &SearchParams,
    ) -> Result<VlReport> {
        let range = self.resolved_range(base);
        range.validate().map_err(|e| anyhow::anyhow!(e))?;
        let ts = ctx.series();
        ensure!(
            ts.n_total() >= 2 * range.max,
            "series too short for max length {}",
            range.max
        );
        ctx.check(0)?;
        let start = Instant::now();
        let kind = base.distance_kind();
        let allow = base.allow_self_match;

        let mut total_calls = 0u64;
        let mut lengths: Vec<VlLength> = Vec::with_capacity(range.count());
        let mut vlc: Option<VlContext> = None;
        let mut prev_sax: Option<SaxParams> = None;
        for (li, s) in range.lengths().enumerate() {
            ctx.check(total_calls)?;
            let pl = Self::params_for_length(base, s);
            let mut transfer_calls = 0u64;
            let warm = match (&mut vlc, &prev_sax) {
                (Some(v), Some(psax)) => {
                    v.advance_into(ctx, s);
                    transfer_calls = v
                        .transfer_profile(ctx, psax.s, psax, s, total_calls)?;
                    total_calls += transfer_calls;
                    // The transfer's exact re-evaluations are distance
                    // calls of this span; a pass event keeps the trace's
                    // per-span call sum equal to the report total.
                    ctx.trace_pass(&crate::obs::PassEvent {
                        engine: ENGINE_ID,
                        phase: "prepare",
                        index: li,
                        candidates: ts.num_sequences(s) as u64,
                        abandons: 0,
                        calls: transfer_calls,
                        best: f64::NAN,
                    });
                    true
                }
                _ => {
                    vlc = Some(VlContext::new(ts, s, kind, allow));
                    false
                }
            };
            let report =
                HstSearch::default().run_serial(ctx, &pl, ENGINE_ID, true)?;
            total_calls += report.distance_calls;
            prev_sax = Some(pl.sax);
            lengths.push(VlLength { s, report, transfer_calls, warm });
        }

        let mut ranked: Vec<VlDiscord> = lengths
            .iter()
            .flat_map(|l| {
                l.report.discords.iter().map(move |d| VlDiscord {
                    s: l.s,
                    discord: d.clone(),
                    score: length_normalized_nnd(d.nnd, l.s),
                })
            })
            .collect();
        ranked.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.s.cmp(&b.s))
                .then(a.discord.position.cmp(&b.discord.position))
        });

        Ok(VlReport {
            lengths,
            ranked,
            total_calls,
            elapsed: start.elapsed(),
        })
    }
}

impl Algorithm for HstVl {
    fn name(&self) -> &'static str {
        ENGINE_ID
    }

    /// The registry face of the scan: the report carries the top
    /// `params.k` discords across all lengths under the
    /// length-normalized ranking, total calls across the scan, and —
    /// as `prep_calls` — the warm-profile transfer cost plus whatever
    /// per-length preparation was paid (the cold first length).
    /// `n_sequences` counts windows at the longest scanned length, the
    /// one every scanned length's window count is bounded below by.
    fn search(
        &self,
        ctx: &SearchContext,
        params: &SearchParams,
    ) -> Result<SearchReport> {
        let range = self.resolved_range(params);
        let vr = self.scan(ctx, params)?;
        let discords: Vec<Discord> = vr
            .ranked
            .iter()
            .take(params.k)
            .map(|vd| vd.discord.clone())
            .collect();
        for (rank, d) in discords.iter().enumerate() {
            ctx.notify_discord(rank, d);
        }
        let prep_calls: u64 = vr
            .lengths
            .iter()
            .map(|l| l.transfer_calls + l.report.prep_calls)
            .sum();
        Ok(SearchReport {
            algo: ENGINE_ID.to_string(),
            discords,
            distance_calls: vr.total_calls,
            prep_calls,
            elapsed: vr.elapsed,
            n_sequences: ctx.series().num_sequences(range.max),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::merlin::Merlin;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn bit_identical_to_per_length_serial_hst() {
        let ts =
            generators::ecg_like(1_400, 100, 1, 800).into_series("vl-e");
        let base = SearchParams::new(72, 4, 4).with_seed(3).with_discords(2);
        let range = LengthRange::new(56, 72, 8);
        let ctx = SearchContext::builder(&ts).build();
        let vr = HstVl::from_range(range).scan(&ctx, &base).unwrap();
        assert_eq!(vr.lengths.len(), 3);
        for vl in &vr.lengths {
            // a fresh context per length: the independent serial baseline
            let pl = HstVl::params_for_length(&base, vl.s);
            let cold_ctx = SearchContext::builder(&ts).build();
            let cold = HstSearch::default()
                .run_ctx(&cold_ctx, &pl)
                .unwrap();
            assert_eq!(
                vl.report.discords.len(),
                cold.discords.len(),
                "s={}",
                vl.s
            );
            for (a, b) in vl.report.discords.iter().zip(&cold.discords) {
                assert_eq!(a.position, b.position, "s={}", vl.s);
                assert_eq!(
                    a.nnd.to_bits(),
                    b.nnd.to_bits(),
                    "s={}: {:016x} vs {:016x}",
                    vl.s,
                    a.nnd.to_bits(),
                    b.nnd.to_bits()
                );
            }
        }
    }

    #[test]
    fn warm_lengths_skip_the_warmup_and_save_calls() {
        let ts =
            generators::valve_like(1_800, 130, 1, 801).into_series("vl-v");
        let base = SearchParams::new(96, 4, 4);
        let range = LengthRange::new(72, 96, 8);
        let ctx = SearchContext::builder(&ts).build();
        let vr = HstVl::from_range(range).scan(&ctx, &base).unwrap();
        assert!(!vr.lengths[0].warm);
        assert!(vr.lengths[0].report.prep_calls > 0, "cold start pays prep");
        let mut serial_total = 0u64;
        for vl in &vr.lengths[1..] {
            assert!(vl.warm);
            assert_eq!(
                vl.report.prep_calls, 0,
                "warm length s={} must skip the warm-up",
                vl.s
            );
            assert!(vl.transfer_calls > 0);
        }
        for vl in &vr.lengths {
            let pl = HstVl::params_for_length(&base, vl.s);
            let cold_ctx = SearchContext::builder(&ts).build();
            serial_total += HstSearch::default()
                .run_ctx(&cold_ctx, &pl)
                .unwrap()
                .distance_calls;
        }
        assert!(
            vr.total_calls < serial_total,
            "work sharing must beat independent runs: {} vs {}",
            vr.total_calls,
            serial_total
        );
    }

    #[test]
    fn strictly_fewer_calls_than_merlin_on_the_same_range() {
        let ts =
            generators::ecg_like(1_200, 90, 1, 802).into_series("vl-m");
        let range = LengthRange::new(48, 64, 8);
        let base = SearchParams::new(64, 4, 4);
        let ctx = SearchContext::builder(&ts).build();
        let vl = HstVl::from_range(range).scan(&ctx, &base).unwrap();
        let merlin_ctx = SearchContext::builder(&ts).build();
        let (_, merlin_calls) = Merlin::from_range(range)
            .scan(&merlin_ctx)
            .unwrap();
        assert!(
            vl.total_calls < merlin_calls,
            "hst-vl {} must be strictly below merlin {}",
            vl.total_calls,
            merlin_calls
        );
    }

    #[test]
    fn ranked_output_uses_the_normalized_score() {
        let ts =
            generators::respiration_like(1_500, 120, 1, 803).into_series("r");
        let base = SearchParams::new(80, 4, 4).with_discords(2);
        let ctx = SearchContext::builder(&ts).build();
        let vr = HstVl::from_range(LengthRange::new(64, 80, 8))
            .scan(&ctx, &base)
            .unwrap();
        assert!(!vr.ranked.is_empty());
        for r in &vr.ranked {
            assert_eq!(r.score, length_normalized_nnd(r.discord.nnd, r.s));
        }
        for w in vr.ranked.windows(2) {
            assert!(w[0].score >= w[1].score, "ranking must be descending");
        }
        // the JSON face carries the same ranking
        let j = vr.to_json();
        assert_eq!(j.get("algo").unwrap().as_str(), Some(ENGINE_ID));
        let ranked = j.get("ranked").unwrap().as_arr().unwrap();
        assert_eq!(ranked.len(), vr.ranked.len());
        assert_eq!(
            ranked[0].get("score").unwrap().as_f64(),
            Some(vr.ranked[0].score)
        );
    }

    #[test]
    fn registry_form_derives_the_range_like_merlin() {
        let ts =
            generators::ecg_like(1_000, 80, 1, 804).into_series("reg");
        let engine = crate::algo::by_name("hst-vl").unwrap();
        assert_eq!(engine.name(), ENGINE_ID);
        let params = SearchParams::new(48, 4, 4);
        let rep = engine.run(&ts, &params).unwrap();
        assert_eq!(rep.algo, ENGINE_ID);
        assert_eq!(rep.discords.len(), 1);
        assert!(rep.distance_calls > 0);
        // an explicit s_range overrides the derivation
        let params = SearchParams::new(48, 4, 4)
            .with_length_range(LengthRange::new(40, 48, 8));
        let vr = HstVl::default().scan(
            &SearchContext::builder(&ts).build(),
            &params,
        );
        assert_eq!(vr.unwrap().lengths.len(), 2);
    }

    #[test]
    fn params_for_length_keeps_a_dividing_p() {
        let base = SearchParams::new(64, 4, 4).with_seed(9).with_discords(3);
        let p64 = HstVl::params_for_length(&base, 64);
        assert_eq!(p64.sax, base.sax);
        assert_eq!(p64.seed, 9);
        assert_eq!(p64.k, 3);
        assert_eq!(p64.s_range, None);
        // 4 does not divide 42: the shared default rule takes over
        let p42 = HstVl::params_for_length(&base, 42);
        assert_eq!(p42.sax.s, 42);
        assert_eq!(p42.sax.p, SaxParams::default_p(42));
        assert_eq!(p42.sax.s % p42.sax.p, 0);
    }

    #[test]
    fn rejects_invalid_ranges_and_short_series() {
        let ts =
            generators::sine_with_noise(300, 0.1, 805).into_series("s");
        let ctx = SearchContext::builder(&ts).build();
        let base = SearchParams::new(64, 4, 4);
        let err = HstVl { range: LengthRange { min: 64, max: 32, step: 8 } }
            .scan(&ctx, &base)
            .unwrap_err()
            .to_string();
        assert!(err.contains("max=32"), "{err}");
        let err = HstVl::from_range(LengthRange::new(128, 200, 8))
            .scan(&ctx, &base)
            .unwrap_err()
            .to_string();
        assert!(err.contains("too short"), "{err}");
    }
}
