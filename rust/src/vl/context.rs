//! The cross-length session state of the variable-length scan: rolling
//! window sums extended from one length to the next, and the warm-profile
//! transfer that carries nearest-neighbor knowledge between adjacent
//! lengths.
//!
//! Both pieces preserve the bit-identity discipline the rest of the
//! workspace holds itself to:
//!
//! * **Stats extension.** `f64` addition is IEEE-deterministic and
//!   [`window_stats`](crate::ts::window_stats) folds its first pass
//!   left-to-right, so extending a cached window sum by appending the new
//!   points *in order* produces the same bits as a fresh full-window sum.
//!   [`VlContext::advance`] still validates a sample of windows against
//!   the recompute and falls back wholesale on any mismatch, so a seeded
//!   [`SeqStats`] can never violate the
//!   [`seed_stats`](crate::context::SearchContext::seed_stats) contract.
//! * **Profile transfer.** An [`NndProfile`] entry is only ever an
//!   *exactly evaluated* distance to an admissible partner — a true upper
//!   bound of the exact nnd. There is no cheap algebraic bound relating
//!   z-normalized distances at length `s` to length `s + step`, so the
//!   transfer re-evaluates each carried neighbor pair exactly at the new
//!   length; entries whose partner is no longer admissible reset to the ∞
//!   sentinel — the same shift discipline
//!   [`StreamingMonitor`](crate::stream::StreamingMonitor) applies when
//!   its window slides.

use std::sync::Arc;

use anyhow::Result;

use crate::algo::non_self_match;
use crate::config::SaxParams;
use crate::context::SearchContext;
use crate::discord::{NndProfile, NO_NEIGHBOR};
use crate::dist::{CountingDistance, DistanceKind};
use crate::ts::stats::SIGMA_FLOOR;
use crate::ts::{window_stats, SeqStats, TimeSeries};

/// Every this many windows, [`VlContext::advance`] cross-checks its
/// incrementally extended (μ, σ) against a cold [`window_stats`]
/// recompute (the first and last windows are always checked).
const VALIDATE_EVERY: usize = 256;

/// Run-control checkpoint cadence of the transfer loop.
const CHECK_EVERY: usize = 1024;

/// Cross-length session state for one [`HstVl`](super::HstVl) scan.
///
/// Owns the rolling first-pass window sums at the most recently scanned
/// length, so moving to the next length only pays the *new* points of
/// each window instead of a full recompute, plus the fallback counter
/// that makes the validation observable.
#[derive(Debug)]
pub struct VlContext {
    kind: DistanceKind,
    allow_self_match: bool,
    /// `sums[k]` = left-to-right fold of `points[k..k + cur_s]`.
    sums: Vec<f64>,
    cur_s: usize,
    stat_fallbacks: usize,
}

impl VlContext {
    /// Session state anchored at the first scanned length `s`: one pass
    /// over the series fills the window sums the later
    /// [`advance`](Self::advance) calls extend.
    pub fn new(
        ts: &TimeSeries,
        s: usize,
        kind: DistanceKind,
        allow_self_match: bool,
    ) -> VlContext {
        let n = ts.num_sequences(s);
        let sums = (0..n)
            .map(|k| ts.seq(k, s).iter().sum::<f64>())
            .collect();
        VlContext {
            kind,
            allow_self_match,
            sums,
            cur_s: s,
            stat_fallbacks: 0,
        }
    }

    /// The length the cached sums currently cover.
    pub fn current_len(&self) -> usize {
        self.cur_s
    }

    /// How many [`advance`](Self::advance) calls abandoned the
    /// incremental fast path because a sampled window failed the bit
    /// cross-check (expected to stay 0; observable so tests can pin it).
    pub fn stat_fallbacks(&self) -> usize {
        self.stat_fallbacks
    }

    /// Rolling stats for `s_next > current_len()`, produced by extending
    /// the cached window sums with each window's new points in order.
    ///
    /// The result is bit-equal to [`SeqStats::compute`] — the means share
    /// the recompute's exact addition sequence (module docs), and the σ
    /// pass below *is* [`window_stats`]' second pass verbatim. A sampled
    /// cross-check enforces this; one mismatch discards the whole fast
    /// path for this call in favor of the recompute. Either way the
    /// returned stats satisfy the
    /// [`seed_stats`](SearchContext::seed_stats) contract.
    pub fn advance(&mut self, ts: &TimeSeries, s_next: usize) -> SeqStats {
        assert!(
            s_next > self.cur_s,
            "advance must move to a longer length ({} -> {s_next})",
            self.cur_s
        );
        let n_next = ts.num_sequences(s_next);
        let mut mean = Vec::with_capacity(n_next);
        let mut std = Vec::with_capacity(n_next);
        let mut valid = true;
        for k in 0..n_next {
            let w = ts.seq(k, s_next);
            // First pass: extend the cached sum with the window's new
            // points, left to right — the recompute's addition sequence.
            for &x in &w[self.cur_s..] {
                self.sums[k] += x;
            }
            let m = self.sums[k] / w.len() as f64;
            // Second pass: window_stats' σ computation verbatim.
            let var = w.iter().map(|&x| (x - m) * (x - m)).sum::<f64>()
                / w.len() as f64;
            let sd = var.sqrt().max(SIGMA_FLOOR);
            if k == 0 || k + 1 == n_next || k % VALIDATE_EVERY == 0 {
                let (rm, rsd) = window_stats(w);
                if m.to_bits() != rm.to_bits() || sd.to_bits() != rsd.to_bits()
                {
                    valid = false;
                    break;
                }
            }
            mean.push(m);
            std.push(sd);
        }
        if !valid {
            // Fallback: cold recompute, and resync the sums from the
            // windows so later advances start from reference values.
            self.stat_fallbacks += 1;
            mean.clear();
            std.clear();
            for k in 0..n_next {
                let w = ts.seq(k, s_next);
                let (m, sd) = window_stats(w);
                self.sums[k] = w.iter().sum::<f64>();
                mean.push(m);
                std.push(sd);
            }
        }
        self.sums.truncate(n_next);
        self.cur_s = s_next;
        SeqStats { s: s_next, mean, std }
    }

    /// Carry the refined profile at `prev_s` forward to `s_next` as a
    /// warm [`NndProfile`], and store it in `ctx`'s warm-profile cache
    /// for the next per-length search to start from. Returns the exact
    /// distance calls the transfer spent.
    ///
    /// The transfer rule, per window `i` of the new length:
    ///
    /// 1. if `i`'s previous nearest neighbor `j` still exists at `s_next`
    ///    and the pair is still admissible (`allow_self_match` or
    ///    `|i − j| ≥ s_next`), evaluate `d_next(i, j)` exactly and record
    ///    it — an exact distance to an admissible partner is a valid
    ///    upper bound of the new nnd by definition;
    /// 2. otherwise fall back to `i`'s previous-length SAX cluster (the
    ///    joint-word neighbors, via `prev_sax`'s cached index): the
    ///    nearest-in-time admissible member stands in for the lost
    ///    neighbor;
    /// 3. if neither yields an admissible partner, the entry *resets to
    ///    the ∞ sentinel* (`NO_NEIGHBOR`) — never a guessed bound.
    ///
    /// Every recorded value is an exactly evaluated pair distance, so the
    /// produced profile is valid for
    /// [`store_warm_profile`](SearchContext::store_warm_profile) and
    /// preserves the downstream search's bit-identity; only call counts
    /// change.
    pub fn transfer_profile(
        &self,
        ctx: &SearchContext,
        prev_s: usize,
        prev_sax: &SaxParams,
        s_next: usize,
        base_calls: u64,
    ) -> Result<u64> {
        debug_assert_eq!(prev_sax.s, prev_s);
        let Some(prev) =
            ctx.warm_profile(prev_s, self.kind, self.allow_self_match)
        else {
            return Ok(0);
        };
        let stats = ctx.stats(s_next);
        let n_next = stats.len();
        let prev_idx = ctx.index(prev_sax);
        let dist = CountingDistance::with_kernel(
            ctx.series(),
            &stats,
            self.kind,
            ctx.kernel(),
        );
        let allow = self.allow_self_match;
        let mut p = NndProfile::new(n_next);
        for i in 0..n_next {
            if i % CHECK_EVERY == 0 {
                ctx.check(base_calls + dist.calls())?;
            }
            let j = prev.ngh.get(i).copied().unwrap_or(NO_NEIGHBOR);
            if j != NO_NEIGHBOR
                && j < n_next
                && i != j
                && non_self_match(i, j, s_next, allow)
            {
                p.observe(i, j, dist.dist(i, j));
                continue;
            }
            // Cluster-buddy rescue: the previous length's joint SAX word
            // names likely near neighbors; take the closest-in-time
            // admissible one.
            let buddy = prev_idx
                .cluster_members(i)
                .iter()
                .copied()
                .filter(|&m| {
                    m < n_next
                        && m != i
                        && non_self_match(i, m, s_next, allow)
                })
                .min_by_key(|&m| m.abs_diff(i));
            if let Some(m) = buddy {
                p.observe(i, m, dist.dist(i, m));
            }
            // No admissible partner: stays at the ∞ sentinel.
        }
        let calls = dist.calls();
        ctx.store_warm_profile(s_next, self.kind, allow, p);
        Ok(calls)
    }

    /// Convenience used by the engine: advance the stats and seed them
    /// into `ctx` in one step (the `Arc` is returned for callers that
    /// want to inspect them).
    pub fn advance_into(
        &mut self,
        ctx: &SearchContext,
        s_next: usize,
    ) -> Arc<SeqStats> {
        let stats = Arc::new(self.advance(ctx.series(), s_next));
        ctx.seed_stats(Arc::clone(&stats));
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn advance_matches_cold_recompute_bit_for_bit() {
        let ts =
            generators::ecg_like(1_200, 90, 1, 900).into_series("vlctx");
        let mut vlc =
            VlContext::new(&ts, 32, DistanceKind::Znorm, false);
        for s_next in [36usize, 40, 48, 61, 64] {
            let fast = vlc.advance(&ts, s_next);
            let cold = SeqStats::compute(&ts, s_next);
            assert_eq!(fast.len(), cold.len(), "s={s_next}");
            for k in 0..cold.len() {
                assert_eq!(
                    fast.mean[k].to_bits(),
                    cold.mean[k].to_bits(),
                    "mean s={s_next} k={k}"
                );
                assert_eq!(
                    fast.std[k].to_bits(),
                    cold.std[k].to_bits(),
                    "std s={s_next} k={k}"
                );
            }
        }
        assert_eq!(
            vlc.stat_fallbacks(),
            0,
            "the incremental fast path must validate"
        );
    }

    #[test]
    fn advance_handles_large_offsets() {
        // the regime where naive prefix-sum formulations lose digits;
        // the per-window fold stays bit-equal to the recompute
        let mut rng = crate::util::rng::Rng64::new(901);
        let pts: Vec<f64> =
            (0..800).map(|_| 1.0e8 + rng.normal()).collect();
        let ts = TimeSeries::new("off", pts);
        let mut vlc = VlContext::new(&ts, 40, DistanceKind::Znorm, false);
        let fast = vlc.advance(&ts, 56);
        let cold = SeqStats::compute(&ts, 56);
        for k in 0..cold.len() {
            assert_eq!(fast.mean[k].to_bits(), cold.mean[k].to_bits());
            assert_eq!(fast.std[k].to_bits(), cold.std[k].to_bits());
        }
        assert_eq!(vlc.stat_fallbacks(), 0);
    }

    #[test]
    fn transfer_produces_a_valid_upper_bound_profile() {
        use crate::algo::{hst::HstSearch, Algorithm};
        use crate::config::SearchParams;

        let ts =
            generators::valve_like(1_500, 110, 1, 902).into_series("vt");
        let ctx = SearchContext::builder(&ts).build();
        let prev = SearchParams::new(64, 4, 4);
        // a real search leaves the refined profile behind
        HstSearch::default().run_ctx(&ctx, &prev).unwrap();

        let mut vlc = VlContext::new(&ts, 64, DistanceKind::Znorm, false);
        vlc.advance_into(&ctx, 72);
        let calls = vlc
            .transfer_profile(&ctx, 64, &prev.sax, 72, 0)
            .unwrap();
        let n72 = ts.num_sequences(72);
        assert!(calls > 0, "the transfer must evaluate pairs");
        assert!(calls <= n72 as u64, "at most one call per window");

        let warm =
            ctx.warm_profile(72, DistanceKind::Znorm, false).unwrap();
        assert_eq!(warm.len(), n72);
        // every finite entry is an exactly evaluated admissible pair
        let stats = ctx.stats(72);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let mut bounded = 0usize;
        for i in 0..n72 {
            if warm.nnd[i].is_finite() {
                let j = warm.ngh[i];
                assert!(j < n72, "i={i}");
                assert!(i.abs_diff(j) >= 72, "i={i} j={j} overlaps");
                assert_eq!(
                    warm.nnd[i].to_bits(),
                    dist.dist(i, j).to_bits(),
                    "entry must be the exact pair distance (i={i})"
                );
                bounded += 1;
            } else {
                assert_eq!(warm.ngh[i], NO_NEIGHBOR, "i={i}");
            }
        }
        assert!(
            bounded * 10 >= n72 * 9,
            "the transfer should bound nearly every window ({bounded}/{n72})"
        );
    }

    #[test]
    fn transfer_without_a_previous_profile_is_free() {
        let ts =
            generators::sine_with_noise(900, 0.2, 903).into_series("cold");
        let ctx = SearchContext::builder(&ts).build();
        let mut vlc = VlContext::new(&ts, 48, DistanceKind::Znorm, false);
        vlc.advance_into(&ctx, 56);
        let sax = SaxParams::new(48, 4, 4);
        // the index for the rescue path must exist; build it like a
        // previous search would have
        let _ = ctx.index(&sax);
        let calls =
            vlc.transfer_profile(&ctx, 48, &sax, 56, 0).unwrap();
        assert_eq!(calls, 0, "no profile to carry, no calls spent");
        assert!(ctx.warm_profile(56, DistanceKind::Znorm, false).is_none());
    }
}
