//! Evaluation metrics: cost per sequence (the paper's new complexity
//! indicator, Sec. 4.2) and the D-/T-speedups used throughout Sec. 4.

/// Cost per sequence: distance calls / (N · k) — the paper's indicator for
/// comparing searches across series lengths. ~2 means "perfect magic"
/// (one call discards each non-discord), ~N means brute force.
pub fn cps(distance_calls: u64, n_sequences: usize, k_discords: usize) -> f64 {
    assert!(n_sequences > 0 && k_discords > 0);
    distance_calls as f64 / (n_sequences as f64 * k_discords as f64)
}

/// Cost per sequence **per channel**: distance calls / (N · k · d) — the
/// cps indicator extended to the multivariate (mdim) workload, where one
/// aggregate evaluation costs up to `d` per-channel distance calls.
/// Under perfect cross-channel early abandoning the per-channel cps of a
/// SAX-guided search approaches the univariate value; a full-evaluation
/// brute force sits at exactly the univariate brute-force cps.
pub fn cps_per_channel(
    distance_calls: u64,
    n_sequences: usize,
    k_discords: usize,
    channels: usize,
) -> f64 {
    assert!(channels > 0);
    cps(distance_calls, n_sequences, k_discords) / channels as f64
}

/// D-speedup: ratio of distance calls (baseline / candidate). > 1 means
/// the candidate is faster.
pub fn d_speedup(baseline_calls: u64, candidate_calls: u64) -> f64 {
    assert!(candidate_calls > 0);
    baseline_calls as f64 / candidate_calls as f64
}

/// T-speedup: ratio of wall-clock runtimes (baseline / candidate).
pub fn t_speedup(baseline_secs: f64, candidate_secs: f64) -> f64 {
    assert!(candidate_secs > 0.0);
    baseline_secs / candidate_secs
}

/// Length-normalized discord score: `nnd / √s` (the "Matrix Profile Goes
/// MAD" normalization). Euclidean distance between z-normalized windows
/// grows like √s, so dividing by √s puts discords found at different
/// lengths on one comparable scale; both variable-length engines
/// (`hst-vl`, `merlin`) rank their cross-length reports with it.
pub fn length_normalized_nnd(nnd: f64, s: usize) -> f64 {
    assert!(s > 0);
    nnd / (s as f64).sqrt()
}

/// The paper's rule of thumb (Sec. 4.7): extrapolate total distance calls
/// for a long series from a short-extract cps measurement.
/// calls ≈ cps · N · k.
pub fn extrapolate_calls(cps_measured: f64, n_sequences: usize, k_discords: usize) -> f64 {
    cps_measured * n_sequences as f64 * k_discords as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cps_definition() {
        // Table 3: ECG 0606 — 20 621 calls, N = 2299-120+1 = 2180, k=1 → ~9
        let v = cps(20_621, 2_180, 1);
        assert!((v - 9.459).abs() < 0.01);
    }

    #[test]
    fn cps_perfect_magic_is_about_two() {
        let n = 10_000;
        let v = cps(2 * (n as u64 - 1), n, 1);
        assert!((v - 2.0).abs() < 0.001);
    }

    #[test]
    fn cps_per_channel_normalizes_by_channel_count() {
        // 3 channels fully evaluated: per-channel cps equals the
        // univariate cps of the same pair count
        let uni = cps(9_000, 1_000, 1);
        assert_eq!(cps_per_channel(27_000, 1_000, 1, 3), uni);
        assert_eq!(cps_per_channel(9_000, 1_000, 1, 1), uni);
    }

    #[test]
    #[should_panic]
    fn zero_channels_panics() {
        cps_per_channel(10, 10, 1, 0);
    }

    #[test]
    fn speedups() {
        assert!((d_speedup(819_802, 260_615) - 3.1457).abs() < 0.001);
        assert!((t_speedup(14.40, 0.94) - 15.319).abs() < 0.01);
    }

    #[test]
    fn extrapolation_inverts_cps() {
        let calls = 123_456u64;
        let n = 5_000;
        let c = cps(calls, n, 2);
        assert!((extrapolate_calls(c, n, 2) - calls as f64).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn zero_candidate_calls_panics() {
        d_speedup(10, 0);
    }

    #[test]
    fn length_normalized_nnd_divides_by_sqrt_s() {
        assert_eq!(length_normalized_nnd(6.0, 4), 3.0);
        assert_eq!(length_normalized_nnd(0.0, 128), 0.0);
        // monotone in nnd at fixed s
        assert!(
            length_normalized_nnd(2.0, 64) > length_normalized_nnd(1.0, 64)
        );
        // a distance growing exactly like √s normalizes to a constant
        for s in [16usize, 64, 256] {
            let nnd = 1.5 * (s as f64).sqrt();
            assert!((length_normalized_nnd(nnd, s) - 1.5).abs() < 1e-12);
        }
        // longer windows normalize smaller at equal raw nnd
        assert!(
            length_normalized_nnd(3.0, 256) < length_normalized_nnd(3.0, 64)
        );
    }

    #[test]
    #[should_panic]
    fn length_normalized_nnd_rejects_zero_length() {
        length_normalized_nnd(1.0, 0);
    }
}
