//! Thread-count resolution shared by every parallel code path.

/// Environment variable consulted when no explicit thread count was
/// requested (CLI `--threads` / [`SearchParams::threads`] /
/// engine fields all map to an explicit request).
///
/// [`SearchParams::threads`]: crate::config::SearchParams::threads
pub const THREADS_ENV: &str = "HST_THREADS";

/// How many workers a parallel engine should run.
///
/// One resolution order for the whole crate (engines, service, CLI,
/// benches):
///
/// 1. an explicit request (`> 0`) — from an engine field, a
///    [`SearchParams::threads`](crate::config::SearchParams::threads)
///    value, or the CLI `--threads` flag;
/// 2. the [`THREADS_ENV`] (`HST_THREADS`) environment variable, when it
///    parses to a positive integer;
/// 3. [`std::thread::available_parallelism`] (falling back to 4 when the
///    platform cannot report it).
///
/// The resolved count is always ≥ 1.
///
/// **Zero is normalized here, and only here**: `ExecPolicy::new(0)` *is*
/// [`auto`](Self::auto) — a `threads: 0` arriving through the service
/// JSON, the CLI `--threads 0` / `serve --workers 0`, or an engine field
/// falls through to the environment/hardware defaults instead of being
/// treated as a literal worker count. Callers must never special-case
/// zero themselves (the coordinator once did, duplicating this rule);
/// regression tests pin the JSON and CLI paths.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecPolicy {
    requested: usize,
}

impl ExecPolicy {
    /// Policy with an explicit request; `0` means "no request" and falls
    /// through to the environment / hardware defaults.
    pub fn new(requested: usize) -> ExecPolicy {
        ExecPolicy { requested }
    }

    /// No explicit request: resolve from `HST_THREADS`, then hardware.
    pub fn auto() -> ExecPolicy {
        ExecPolicy::new(0)
    }

    /// The explicit request carried by this policy (`0` = none).
    pub fn request(&self) -> usize {
        self.requested
    }

    /// Resolve to a concrete worker count (always ≥ 1; see the type docs
    /// for the resolution order).
    pub fn resolve(&self) -> usize {
        if self.requested > 0 {
            return self.requested;
        }
        if let Ok(v) = std::env::var(THREADS_ENV) {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n > 0 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_request_wins() {
        assert_eq!(ExecPolicy::new(3).resolve(), 3);
        assert_eq!(ExecPolicy::new(1).resolve(), 1);
        assert_eq!(ExecPolicy::new(7).request(), 7);
    }

    #[test]
    fn auto_resolves_to_at_least_one() {
        // no assumptions about the environment beyond positivity
        assert!(ExecPolicy::auto().resolve() >= 1);
        assert_eq!(ExecPolicy::auto().request(), 0);
        assert_eq!(ExecPolicy::default(), ExecPolicy::auto());
    }

    #[test]
    fn zero_is_auto_not_an_explicit_request() {
        // regression: a requested 0 must be the auto policy, never a
        // literal zero-worker pool — this is the single place the
        // normalization lives
        assert_eq!(ExecPolicy::new(0), ExecPolicy::auto());
        assert!(ExecPolicy::new(0).resolve() >= 1);
        assert_eq!(
            ExecPolicy::new(0).resolve(),
            ExecPolicy::auto().resolve()
        );
    }
}
