//! Lock-free shared f64 bound: an `f64` bit-packed into an `AtomicU64`
//! with CAS-min / CAS-max update loops.

use std::sync::atomic::{AtomicU64, Ordering};

/// A shared best-so-far value workers prune against.
///
/// The value only moves monotonically (via [`fetch_max`](Self::fetch_max)
/// or [`fetch_min`](Self::fetch_min)), so `Relaxed` ordering is
/// sufficient: a stale read yields a *looser* bound, which costs pruning
/// power but never correctness. NaN updates are ignored (a NaN never
/// compares greater or smaller, so the CAS loop never stores one).
#[derive(Debug)]
pub struct AtomicF64 {
    bits: AtomicU64,
}

impl AtomicF64 {
    /// A bound starting at `v`.
    pub fn new(v: f64) -> AtomicF64 {
        AtomicF64 {
            bits: AtomicU64::new(v.to_bits()),
        }
    }

    /// The current value.
    #[inline]
    pub fn load(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    /// Raise the bound to `v` if `v` is greater; returns the previous
    /// value. The discord-search direction: the best (largest) confirmed
    /// nnd so far.
    pub fn fetch_max(&self, v: f64) -> f64 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let cf = f64::from_bits(cur);
            if !(v > cf) {
                return cf;
            }
            match self.bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return cf,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Lower the bound to `v` if `v` is smaller; returns the previous
    /// value. The nearest-neighbor direction: the smallest distance seen
    /// so far.
    pub fn fetch_min(&self, v: f64) -> f64 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let cf = f64::from_bits(cur);
            if !(v < cf) {
                return cf;
            }
            match self.bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return cf,
                Err(seen) => cur = seen,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_and_min_move_monotonically() {
        let b = AtomicF64::new(0.0);
        assert_eq!(b.fetch_max(2.5), 0.0);
        assert_eq!(b.fetch_max(1.0), 2.5, "lower value must not regress");
        assert_eq!(b.load(), 2.5);

        let m = AtomicF64::new(f64::INFINITY);
        m.fetch_min(3.0);
        m.fetch_min(9.0);
        assert_eq!(m.load(), 3.0);
    }

    #[test]
    fn nan_updates_are_ignored() {
        let b = AtomicF64::new(1.0);
        b.fetch_max(f64::NAN);
        b.fetch_min(f64::NAN);
        assert_eq!(b.load(), 1.0);
    }

    #[test]
    fn concurrent_fetch_max_keeps_the_global_maximum() {
        let b = AtomicF64::new(f64::NEG_INFINITY);
        std::thread::scope(|scope| {
            for w in 0..8u32 {
                let b = &b;
                scope.spawn(move || {
                    for i in 0..1_000u32 {
                        b.fetch_max(f64::from(w * 1_000 + i));
                    }
                });
            }
        });
        assert_eq!(b.load(), 7_999.0);
    }
}
