//! The execution subsystem: one place for every thread the engines spawn.
//!
//! The paper closes with "Parallelizing HST is also a natural follow up
//! of the present work" (Sec. 5). Before this module existed, the
//! crate's parallelism was two ad-hoc `std::thread::scope` blocks with
//! hardcoded worker counts; every parallel code path now builds on this
//! module:
//!
//! * [`ExecPolicy`] — *how many* workers. One resolution order everywhere:
//!   an explicit request (engine field, [`SearchParams::threads`], CLI
//!   `--threads`) wins, then the `HST_THREADS` environment variable, then
//!   the machine's available parallelism. Used by `hst-par`, `scamp-par`,
//!   the service worker pool, and the CLI.
//! * [`scope_workers`] — *where* they run. A scoped worker pool: spawn
//!   `threads` workers over a shared closure, join all, return their
//!   results **in worker order** (the ordered merge the deterministic
//!   engines rely on). Used by every parallel engine.
//! * [`ChunkQueue`] — *what* they run. Items are split into deterministic
//!   chunks (chunk boundaries depend only on the input length, never on
//!   timing); workers claim chunks dynamically for load balance. `hst-par`
//!   drives it directly because its workers carry per-chunk state (a
//!   profile clone and a private distance session);
//!   [`parallel_for_chunks`] is the convenience composition of the two
//!   for stateless chunk maps, returning per-chunk results in chunk
//!   order.
//! * [`AtomicF64`] — *what they share*. A lock-free f64 bound, bit-packed
//!   in an `AtomicU64` with CAS-min/CAS-max, for the best-so-far value
//!   every worker prunes against (HST's best discord distance so far, a
//!   matrix-profile engine's running minimum).
//!
//! Distance-call accounting under parallelism follows one rule: each
//! worker owns its own [`CountingDistance`](crate::dist::CountingDistance)
//! (its counter is a `Cell`, deliberately not `Sync`) and the per-worker
//! counts are summed after the join — so `distance_calls` and cps stay
//! exact, never sampled or approximated.
//!
//! ```
//! use hstime::exec::{scope_workers, ExecPolicy};
//!
//! // an explicit request always wins the resolution order
//! assert_eq!(ExecPolicy::new(3).resolve(), 3);
//! // with no request, HST_THREADS / available parallelism decide (≥ 1)
//! assert!(ExecPolicy::auto().resolve() >= 1);
//!
//! // results come back in worker order, so reductions are deterministic
//! let squares = scope_workers(4, |w| w * w);
//! assert_eq!(squares, vec![0, 1, 4, 9]);
//! ```
//!
//! [`SearchParams::threads`]: crate::config::SearchParams::threads

mod bound;
mod policy;
mod pool;

pub use bound::AtomicF64;
pub use policy::{ExecPolicy, THREADS_ENV};
pub use pool::{parallel_for_chunks, scope_workers, ChunkQueue};
