//! The scoped worker pool and its chunked work-distribution primitives.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `f(worker_id)` on `threads` scoped workers and return the results
/// **in worker order** (worker 0 first). This ordered merge is what makes
/// reductions over per-worker partial results deterministic: the merge
/// sequence depends only on the worker count, never on completion timing.
///
/// `threads` is clamped to ≥ 1; with a single worker the closure runs on
/// the calling thread (no spawn overhead on the serial path).
///
/// Panics in a worker propagate as a panic here (an engine bug, not a
/// recoverable condition — fallible workers should return `Result` as
/// their `R`).
pub fn scope_workers<R, F>(threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        return vec![f(0)];
    }
    let mut out: Vec<R> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                let f = &f;
                scope.spawn(move || f(w))
            })
            .collect();
        for h in handles {
            out.push(h.join().expect("exec worker panicked"));
        }
    });
    out
}

/// A dynamic queue of deterministic chunks over a shared slice.
///
/// Chunk *boundaries* are a pure function of `(items.len(), chunk_size)`;
/// only the worker→chunk *assignment* is dynamic (an atomic claim
/// counter), so faster workers take more chunks while every result can
/// still be keyed by its stable chunk index.
#[derive(Debug)]
pub struct ChunkQueue<'a, T> {
    items: &'a [T],
    chunk: usize,
    next: AtomicUsize,
}

impl<'a, T> ChunkQueue<'a, T> {
    /// A queue over `items` in chunks of `chunk_size` (clamped to ≥ 1).
    pub fn new(items: &'a [T], chunk_size: usize) -> ChunkQueue<'a, T> {
        ChunkQueue {
            items,
            chunk: chunk_size.max(1),
            next: AtomicUsize::new(0),
        }
    }

    /// Total number of chunks this queue will hand out.
    pub fn num_chunks(&self) -> usize {
        self.items.len().div_ceil(self.chunk)
    }

    /// Claim the next unclaimed chunk: `(chunk_index, slice)`, or `None`
    /// once every chunk has been handed out.
    pub fn take(&self) -> Option<(usize, &'a [T])> {
        loop {
            let seen = self.next.load(Ordering::Relaxed);
            if seen >= self.num_chunks() {
                return None;
            }
            // claim by CAS so `next` never runs away past the chunk count
            if self
                .next
                .compare_exchange_weak(
                    seen,
                    seen + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                )
                .is_err()
            {
                continue;
            }
            let lo = seen * self.chunk;
            let hi = (lo + self.chunk).min(self.items.len());
            return Some((seen, &self.items[lo..hi]));
        }
    }
}

/// Map `f` over deterministic chunks of `items` on `threads` workers and
/// return the per-chunk results **in chunk order**.
///
/// Chunk boundaries depend only on the input length, workers claim chunks
/// dynamically (load balance), and the ordered merge makes the output
/// independent of scheduling — the same `Vec` for any thread count.
pub fn parallel_for_chunks<T, R, F>(
    items: &[T],
    threads: usize,
    chunk_size: usize,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &[T]) -> R + Sync,
{
    let queue = ChunkQueue::new(items, chunk_size);
    let per_worker = scope_workers(threads, |_w| {
        let mut got: Vec<(usize, R)> = Vec::new();
        while let Some((ci, slice)) = queue.take() {
            got.push((ci, f(ci, slice)));
        }
        got
    });
    let mut all: Vec<(usize, R)> = per_worker.into_iter().flatten().collect();
    all.sort_by_key(|&(ci, _)| ci);
    all.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_workers_returns_in_worker_order() {
        for threads in [1, 2, 4, 7] {
            let ids = scope_workers(threads, |w| w);
            assert_eq!(ids, (0..threads).collect::<Vec<_>>());
        }
    }

    #[test]
    fn chunk_queue_hands_out_every_item_exactly_once() {
        let items: Vec<usize> = (0..103).collect();
        let q = ChunkQueue::new(&items, 10);
        assert_eq!(q.num_chunks(), 11);
        let mut seen = Vec::new();
        while let Some((ci, slice)) = q.take() {
            assert_eq!(slice[0], ci * 10, "chunk start is deterministic");
            seen.extend_from_slice(slice);
        }
        assert_eq!(seen, items);
        assert!(q.take().is_none(), "queue stays drained");
    }

    #[test]
    fn chunk_queue_concurrent_claims_do_not_overlap() {
        let items: Vec<usize> = (0..10_000).collect();
        let q = ChunkQueue::new(&items, 7);
        let parts = scope_workers(4, |_| {
            let mut mine = Vec::new();
            while let Some((_, slice)) = q.take() {
                mine.extend_from_slice(slice);
            }
            mine
        });
        let mut all: Vec<usize> = parts.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, items);
    }

    #[test]
    fn parallel_for_chunks_is_deterministic_across_thread_counts() {
        let items: Vec<u64> = (0..1_000).collect();
        let serial = parallel_for_chunks(&items, 1, 16, |ci, slice| {
            (ci, slice.iter().sum::<u64>())
        });
        for threads in [2, 3, 4, 8] {
            let par = parallel_for_chunks(&items, threads, 16, |ci, slice| {
                (ci, slice.iter().sum::<u64>())
            });
            assert_eq!(par, serial, "threads={threads}");
        }
        // chunk indices arrive in order
        for (pos, (ci, _)) in serial.iter().enumerate() {
            assert_eq!(*ci, pos);
        }
    }

    #[test]
    fn empty_input_yields_no_chunks() {
        let items: [u8; 0] = [];
        assert!(ChunkQueue::new(&items, 8).take().is_none());
        let out = parallel_for_chunks(&items, 4, 8, |_, s| s.len());
        assert!(out.is_empty());
    }
}
