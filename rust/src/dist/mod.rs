//! Sequence-distance backends.
//!
//! The distance function is the paper's unit of cost: every engine reports
//! how many times it was called, and every comparison (Tables 1–7) is a
//! comparison of call counts. This module supplies:
//!
//! * [`CountingDistance`] — the scalar fallback backend, always compiled.
//!   It folds z-normalization into the distance loop using the rolling
//!   (μ, σ) of [`SeqStats`](crate::ts::SeqStats) (paper Sec. 2.1, Eq. 2),
//!   supports early abandoning at a cutoff, and counts calls through a
//!   `Cell` (deliberately `!Sync`: parallel engines give each worker its
//!   own counter and sum afterwards, keeping the accounting exact).
//! * `xla_engine` *(requires the `pjrt` cargo feature)* — the batched
//!   backend that evaluates distance chunks through the AOT-compiled XLA
//!   artifacts of [`crate::runtime`].
//! * [`Backend`] / [`active_backend`] — which of the two this build
//!   prefers for batch work.
//! * [`Distance`] — the object-safe trait both backends sit behind; the
//!   [`SearchContext`](crate::context::SearchContext) session layer hands
//!   engines a `Box<dyn Distance>` so the backend is a per-context choice.
//!
//! Exactness contract (every engine relies on it): whenever the true
//! distance is **below** the cutoff, [`CountingDistance::dist_early`]
//! returns the exact value, bit-identical to [`CountingDistance::dist`] —
//! the accumulation order never changes, abandoning only skips work once
//! the partial sum already proves `d >= cutoff`.

#[cfg(feature = "pjrt")]
pub mod xla_engine;

use std::cell::Cell;

use crate::ts::{SeqStats, TimeSeries};

/// The per-sequence rolling statistics the z-normalized distance is
/// defined over (alias of [`crate::ts::SeqStats`], re-exported here
/// because the distance backends are its primary consumer).
pub use crate::ts::SeqStats as ZnormStats;

/// Which sequence distance to compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DistanceKind {
    /// Euclidean distance between z-normalized sequences (paper default).
    Znorm,
    /// Euclidean distance between raw sequences (the Table 7 DADD
    /// protocol).
    Raw,
}

/// Distance-evaluation backends a build may provide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The pure-Rust scalar engine: always available, the fallback.
    Scalar,
    /// XLA artifacts executed through PJRT (needs the `pjrt` feature and
    /// `make artifacts`).
    XlaPjrt,
}

/// The batch backend this build prefers: [`Backend::XlaPjrt`] when
/// compiled with the `pjrt` feature, otherwise the scalar fallback.
pub fn active_backend() -> Backend {
    if cfg!(feature = "pjrt") {
        Backend::XlaPjrt
    } else {
        Backend::Scalar
    }
}

/// Object-safe interface every distance backend implements — the seam the
/// [`SearchContext`](crate::context::SearchContext) session layer selects a
/// backend through. Engines program against `&dyn Distance`; which concrete
/// backend sits behind it (scalar [`CountingDistance`], or the `pjrt`-gated
/// XLA pair engine) is a per-context choice, not a per-engine one.
///
/// Implementations must uphold the exactness contract documented on
/// [`CountingDistance::dist_early`]: whenever the true distance is below
/// `cutoff`, the returned value is exact; otherwise any returned lower
/// bound must itself be `>= cutoff`.
pub trait Distance {
    /// The distance variant this backend computes.
    fn kind(&self) -> DistanceKind;

    /// Distance calls so far in this session (every invocation counts
    /// once, abandoned or not — the paper's accounting).
    fn calls(&self) -> u64;

    /// Early-abandoning distance between the sequences starting at `i`
    /// and `j`: exact when below `cutoff`, otherwise a partial bound that
    /// is `>= cutoff`.
    fn dist_early(&self, i: usize, j: usize, cutoff: f64) -> f64;

    /// Exact distance between the sequences starting at `i` and `j`.
    fn dist(&self, i: usize, j: usize) -> f64 {
        self.dist_early(i, j, f64::INFINITY)
    }

    /// Whether this backend's values are exact f64 distances (bit-level
    /// compatible with [`CountingDistance`]). Backends computing in
    /// reduced precision (the XLA f32 path) return `false`; their results
    /// must not be recorded as strict bounds for exact sessions — the
    /// warm-profile cache checks this before storing or reusing profiles.
    fn is_exact(&self) -> bool {
        true
    }
}

impl Distance for CountingDistance<'_> {
    fn kind(&self) -> DistanceKind {
        CountingDistance::kind(self)
    }

    fn calls(&self) -> u64 {
        CountingDistance::calls(self)
    }

    fn dist_early(&self, i: usize, j: usize, cutoff: f64) -> f64 {
        CountingDistance::dist_early(self, i, j, cutoff)
    }
}

/// Partial sums are checked against the cutoff once per this many points:
/// often enough to abandon early, rarely enough to stay out of the way of
/// the accumulation loop.
const ABANDON_CHECK_EVERY: usize = 16;

/// The scalar distance backend with exact call accounting.
///
/// Holds borrows of the series and its rolling stats; normalization is
/// folded into the loop (`(p − μ)/σ` per point), so no normalized copies
/// of the sequences are ever materialized — the paper's memory trick.
/// Deliberately not `Clone`: a copied live counter would double-count
/// calls — workers construct their own instance and sum `calls()` after.
#[derive(Debug)]
pub struct CountingDistance<'a> {
    ts: &'a TimeSeries,
    stats: &'a SeqStats,
    kind: DistanceKind,
    calls: Cell<u64>,
}

impl<'a> CountingDistance<'a> {
    /// New backend over `ts` with the stats computed for the search's `s`.
    pub fn new(
        ts: &'a TimeSeries,
        stats: &'a SeqStats,
        kind: DistanceKind,
    ) -> CountingDistance<'a> {
        debug_assert!(
            stats.len() <= ts.num_sequences(stats.s),
            "stats cover more sequences than the series has"
        );
        CountingDistance {
            ts,
            stats,
            kind,
            calls: Cell::new(0),
        }
    }

    /// The distance variant this backend computes.
    pub fn kind(&self) -> DistanceKind {
        self.kind
    }

    /// Number of distance calls so far (each [`dist`](Self::dist) or
    /// [`dist_early`](Self::dist_early) invocation counts once, abandoned
    /// or not — matching the paper's accounting).
    pub fn calls(&self) -> u64 {
        self.calls.get()
    }

    /// Exact distance between the sequences starting at `i` and `j`.
    #[inline]
    pub fn dist(&self, i: usize, j: usize) -> f64 {
        self.dist_early(i, j, f64::INFINITY)
    }

    /// Early-abandoning distance: returns the exact distance when it is
    /// below `cutoff`; otherwise may abandon once the running sum proves
    /// `d >= cutoff` and returns that partial lower bound (which is then
    /// `>= cutoff`, so callers comparing `d < cutoff` never observe an
    /// inexact value).
    pub fn dist_early(&self, i: usize, j: usize, cutoff: f64) -> f64 {
        self.calls.set(self.calls.get() + 1);
        let s = self.stats.s;
        let a = self.ts.seq(i, s);
        let b = self.ts.seq(j, s);
        let limit = if cutoff.is_finite() {
            cutoff * cutoff
        } else {
            f64::INFINITY
        };
        let mut acc = 0.0f64;
        match self.kind {
            DistanceKind::Znorm => {
                let mu_a = self.stats.mean[i];
                let mu_b = self.stats.mean[j];
                let inv_sa = 1.0 / self.stats.std[i];
                let inv_sb = 1.0 / self.stats.std[j];
                for (ca, cb) in a
                    .chunks(ABANDON_CHECK_EVERY)
                    .zip(b.chunks(ABANDON_CHECK_EVERY))
                {
                    for (&x, &y) in ca.iter().zip(cb) {
                        let d = (x - mu_a) * inv_sa - (y - mu_b) * inv_sb;
                        acc += d * d;
                    }
                    if acc > limit {
                        return acc.sqrt();
                    }
                }
            }
            DistanceKind::Raw => {
                for (ca, cb) in a
                    .chunks(ABANDON_CHECK_EVERY)
                    .zip(b.chunks(ABANDON_CHECK_EVERY))
                {
                    for (&x, &y) in ca.iter().zip(cb) {
                        let d = x - y;
                        acc += d * d;
                    }
                    if acc > limit {
                        return acc.sqrt();
                    }
                }
            }
        }
        acc.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    fn setup(n: usize, s: usize) -> (TimeSeries, SeqStats) {
        let ts = generators::ecg_like(n, 90, 1, 11).into_series("d");
        let stats = SeqStats::compute(&ts, s);
        (ts, stats)
    }

    fn naive_znorm_dist(ts: &TimeSeries, stats: &SeqStats, i: usize, j: usize) -> f64 {
        let zi = stats.znorm(ts, i);
        let zj = stats.znorm(ts, j);
        zi.iter()
            .zip(&zj)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    #[test]
    fn znorm_matches_naive_normalize_then_subtract() {
        let (ts, stats) = setup(800, 64);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        for (i, j) in [(0, 100), (3, 700), (250, 330), (0, 736)] {
            let got = dist.dist(i, j);
            let want = naive_znorm_dist(&ts, &stats, i, j);
            assert!((got - want).abs() < 1e-9, "({i},{j}): {got} vs {want}");
        }
    }

    #[test]
    fn raw_is_plain_euclidean() {
        let (ts, stats) = setup(500, 50);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Raw);
        let want = ts
            .seq(10, 50)
            .iter()
            .zip(ts.seq(200, 50))
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!((dist.dist(10, 200) - want).abs() < 1e-12);
    }

    #[test]
    fn early_abandon_returns_exact_below_cutoff() {
        let (ts, stats) = setup(1_000, 80);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        for (i, j) in [(0, 100), (50, 400), (111, 911)] {
            let exact = dist.dist(i, j);
            let with_cutoff = dist.dist_early(i, j, exact + 1.0);
            assert_eq!(exact, with_cutoff, "must be bit-identical below cutoff");
        }
    }

    #[test]
    fn early_abandon_bound_is_at_least_cutoff() {
        let (ts, stats) = setup(1_000, 80);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        for (i, j) in [(0, 100), (50, 400), (111, 911)] {
            let exact = dist.dist(i, j);
            let cutoff = exact * 0.5;
            let d = dist.dist_early(i, j, cutoff);
            assert!(d >= cutoff, "abandoned value {d} below cutoff {cutoff}");
            assert!(d <= exact + 1e-12, "partial sum cannot exceed the exact distance");
        }
    }

    #[test]
    fn every_call_is_counted_once() {
        let (ts, stats) = setup(600, 60);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        assert_eq!(dist.calls(), 0);
        let _ = dist.dist(0, 100);
        let _ = dist.dist_early(0, 200, 0.001); // abandons, still counted
        let _ = dist.dist_early(0, 300, f64::INFINITY);
        assert_eq!(dist.calls(), 3);
    }

    #[test]
    fn symmetric_and_zero_on_self() {
        let (ts, stats) = setup(700, 64);
        for kind in [DistanceKind::Znorm, DistanceKind::Raw] {
            let dist = CountingDistance::new(&ts, &stats, kind);
            assert!((dist.dist(20, 500) - dist.dist(500, 20)).abs() < 5e-8);
            assert!(dist.dist(123, 123) < 1e-12);
        }
    }

    #[test]
    fn trait_object_dispatch_matches_concrete_calls() {
        let (ts, stats) = setup(600, 60);
        let concrete = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let dyn_dist: &dyn Distance = &concrete;
        let want = CountingDistance::new(&ts, &stats, DistanceKind::Znorm).dist(5, 300);
        assert_eq!(dyn_dist.dist(5, 300), want);
        assert_eq!(dyn_dist.kind(), DistanceKind::Znorm);
        assert_eq!(dyn_dist.calls(), 1);
    }

    #[test]
    fn scalar_backend_is_the_default_fallback() {
        match active_backend() {
            Backend::Scalar => assert!(!cfg!(feature = "pjrt")),
            Backend::XlaPjrt => assert!(cfg!(feature = "pjrt")),
        }
    }
}
