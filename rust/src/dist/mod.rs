//! Sequence-distance backends.
//!
//! The distance function is the paper's unit of cost: every engine reports
//! how many times it was called, and every comparison (Tables 1–7) is a
//! comparison of call counts. This module supplies:
//!
//! * [`CountingDistance`] — the exact in-process backend, always compiled.
//!   It folds z-normalization into the distance loop using the rolling
//!   (μ, σ) of [`SeqStats`](crate::ts::SeqStats) (paper Sec. 2.1, Eq. 2),
//!   supports early abandoning at a cutoff, and counts calls through a
//!   `Cell` (deliberately `!Sync`: parallel engines give each worker its
//!   own counter and sum afterwards, keeping the accounting exact).
//! * [`Kernel`] — the inner-loop variant [`CountingDistance`] evaluates
//!   with: the portable scalar reference loop, or the chunked 8-lane SIMD
//!   loop (the default). See "Kernel bit-identity" below.
//! * `xla_engine` *(requires the `pjrt` cargo feature)* — the batched
//!   backend that evaluates distance chunks through the AOT-compiled XLA
//!   artifacts of [`crate::runtime`].
//! * [`Backend`] / [`active_backend`] — which of the two this build
//!   prefers for batch work.
//! * [`Distance`] — the object-safe trait both backends sit behind; the
//!   [`SearchContext`](crate::context::SearchContext) session layer hands
//!   engines a `Box<dyn Distance>` so the backend is a per-context choice.
//!
//! Exactness contract (every engine relies on it): whenever the true
//! distance is **below** the cutoff, [`CountingDistance::dist_early`]
//! returns the exact value, bit-identical to [`CountingDistance::dist`] —
//! the accumulation order never changes, abandoning only skips work once
//! the partial sum already proves `d >= cutoff`.
//!
//! # Kernel bit-identity
//!
//! Both kernels use one **fixed summation order**: squared deviations are
//! added into a single `f64` accumulator in ascending point order, and the
//! running sum is compared against the cutoff once per
//! [`ABANDON_CHECK_EVERY`]-point chunk. The SIMD kernel differs only in
//! *how each chunk's squared deviations are produced*: it computes
//! [`LANES`] deviations at a time into a stack array of lanes — a
//! data-parallel step with no loop-carried dependency, which the
//! autovectorizer lowers to packed `f64` arithmetic — and then drains the
//! lane array into the accumulator in ascending lane order. That drain is
//! the **same addition sequence** the scalar kernel performs, so completed
//! evaluations are bit-identical; and because abandon checks happen at the
//! same chunk boundaries over the same partial sums, abandon *decisions*,
//! abandoned partial bounds, and call counts are identical too. No
//! verify-on-abandon fallback is needed: there is no lane-order
//! reassociation anywhere in the sum, by construction.

#[cfg(feature = "pjrt")]
pub mod xla_engine;

use std::cell::Cell;
use std::sync::OnceLock;

use crate::ts::{SeqStats, TimeSeries};

/// The per-sequence rolling statistics the z-normalized distance is
/// defined over (alias of [`crate::ts::SeqStats`], re-exported here
/// because the distance backends are its primary consumer).
pub use crate::ts::SeqStats as ZnormStats;

/// Which sequence distance to compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DistanceKind {
    /// Euclidean distance between z-normalized sequences (paper default).
    Znorm,
    /// Euclidean distance between raw sequences (the Table 7 DADD
    /// protocol).
    Raw,
}

/// Distance-evaluation backends a build may provide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The pure-Rust scalar engine: always available, the fallback.
    Scalar,
    /// XLA artifacts executed through PJRT (needs the `pjrt` feature and
    /// `make artifacts`).
    XlaPjrt,
}

/// The batch backend this build prefers: [`Backend::XlaPjrt`] when
/// compiled with the `pjrt` feature, otherwise the scalar fallback.
pub fn active_backend() -> Backend {
    if cfg!(feature = "pjrt") {
        Backend::XlaPjrt
    } else {
        Backend::Scalar
    }
}

/// Inner-loop variant of [`CountingDistance`]. The two kernels are
/// bit-identical on every input (completed *and* abandoned evaluations —
/// see the [module docs](self) for the fixed-summation-order argument),
/// so the choice is purely a throughput knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kernel {
    /// The portable scalar reference loop (the pre-SIMD kernel, kept
    /// verbatim as the conformance baseline).
    Scalar,
    /// The chunked 8-lane loop: per-chunk squared deviations are computed
    /// into a lane array the autovectorizer lowers to packed `f64` math,
    /// then reduced in the scalar kernel's exact addition order.
    Simd,
}

impl Kernel {
    /// The process-wide default kernel: [`Kernel::Simd`] unless the
    /// `HST_KERNEL` environment variable says `scalar`. Read once and
    /// latched, so every un-pinned [`CountingDistance::new`] session in
    /// the process agrees.
    pub fn active() -> Kernel {
        static ACTIVE: OnceLock<Kernel> = OnceLock::new();
        *ACTIVE.get_or_init(|| match std::env::var("HST_KERNEL") {
            Ok(v) if v.eq_ignore_ascii_case("scalar") => Kernel::Scalar,
            _ => Kernel::Simd,
        })
    }

    /// Parse a kernel name (`scalar` / `simd`), as accepted by the CLI
    /// `--kernel` flag and the `HST_KERNEL` environment variable.
    pub fn from_name(name: &str) -> Option<Kernel> {
        match name {
            "scalar" => Some(Kernel::Scalar),
            "simd" => Some(Kernel::Simd),
            _ => None,
        }
    }

    /// The canonical name ([`from_name`](Self::from_name) inverse).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Simd => "simd",
        }
    }
}

/// Object-safe interface every distance backend implements — the seam the
/// [`SearchContext`](crate::context::SearchContext) session layer selects a
/// backend through. Engines program against `&dyn Distance`; which concrete
/// backend sits behind it (scalar [`CountingDistance`], or the `pjrt`-gated
/// XLA pair engine) is a per-context choice, not a per-engine one.
///
/// Implementations must uphold the exactness contract documented on
/// [`CountingDistance::dist_early`]: whenever the true distance is below
/// `cutoff`, the returned value is exact; otherwise any returned lower
/// bound must itself be `>= cutoff`.
pub trait Distance {
    /// The distance variant this backend computes.
    fn kind(&self) -> DistanceKind;

    /// Distance calls so far in this session (every invocation counts
    /// once, abandoned or not — the paper's accounting).
    fn calls(&self) -> u64;

    /// Calls in this session that ended early-abandoned (the returned
    /// value was a `>= cutoff` partial bound, not a guaranteed-exact
    /// distance). Purely informational — the trace layer reports it;
    /// backends without abandon accounting return 0.
    fn abandons(&self) -> u64 {
        0
    }

    /// Early-abandoning distance between the sequences starting at `i`
    /// and `j`: exact when below `cutoff`, otherwise a partial bound that
    /// is `>= cutoff`.
    fn dist_early(&self, i: usize, j: usize, cutoff: f64) -> f64;

    /// Exact distance between the sequences starting at `i` and `j`.
    fn dist(&self, i: usize, j: usize) -> f64 {
        self.dist_early(i, j, f64::INFINITY)
    }

    /// Whether this backend's values are exact f64 distances (bit-level
    /// compatible with [`CountingDistance`]). Backends computing in
    /// reduced precision (the XLA f32 path) return `false`; their results
    /// must not be recorded as strict bounds for exact sessions — the
    /// warm-profile cache checks this before storing or reusing profiles.
    fn is_exact(&self) -> bool {
        true
    }
}

impl Distance for CountingDistance<'_> {
    fn kind(&self) -> DistanceKind {
        CountingDistance::kind(self)
    }

    fn calls(&self) -> u64 {
        CountingDistance::calls(self)
    }

    fn abandons(&self) -> u64 {
        CountingDistance::abandons(self)
    }

    fn dist_early(&self, i: usize, j: usize, cutoff: f64) -> f64 {
        CountingDistance::dist_early(self, i, j, cutoff)
    }
}

/// Partial sums are checked against the cutoff once per this many points:
/// often enough to abandon early, rarely enough to stay out of the way of
/// the accumulation loop. Both kernels check at exactly these boundaries,
/// which is what keeps their abandon decisions identical.
const ABANDON_CHECK_EVERY: usize = 16;

/// SIMD lane width of the chunked kernel (a full AVX-512 register of
/// `f64`, two AVX2 registers — the autovectorizer splits as needed).
const LANES: usize = 8;

// The SIMD kernel assumes every abandon chunk splits into whole lane
// groups; a remainder inside a chunk would change where the (scalar) tail
// runs relative to the abandon check.
const _: () = assert!(ABANDON_CHECK_EVERY % LANES == 0);

/// Scalar accumulation of `Σ dev(a[t], b[t])²` with an abandon check every
/// [`ABANDON_CHECK_EVERY`] points. This is the pre-SIMD kernel verbatim —
/// the conformance baseline the chunked kernel is tested against.
#[inline(always)]
fn sum_scalar(a: &[f64], b: &[f64], limit: f64, dev: impl Fn(f64, f64) -> f64 + Copy) -> f64 {
    let mut acc = 0.0f64;
    for (ca, cb) in a
        .chunks(ABANDON_CHECK_EVERY)
        .zip(b.chunks(ABANDON_CHECK_EVERY))
    {
        for (&x, &y) in ca.iter().zip(cb) {
            let d = dev(x, y);
            acc += d * d;
        }
        if acc > limit {
            return acc;
        }
    }
    acc
}

/// Chunked 8-lane accumulation: per abandon chunk, squared deviations are
/// computed [`LANES`] at a time into a stack array (no loop-carried
/// dependency — the autovectorizer lowers this to packed `f64` multiplies)
/// and then drained into `acc` in ascending lane order, which is exactly
/// the scalar kernel's addition sequence. Same sums, same abandon
/// boundaries ⇒ bit-identical results on every path.
#[inline(always)]
fn sum_simd(a: &[f64], b: &[f64], limit: f64, dev: impl Fn(f64, f64) -> f64 + Copy) -> f64 {
    let mut acc = 0.0f64;
    for (ca, cb) in a
        .chunks(ABANDON_CHECK_EVERY)
        .zip(b.chunks(ABANDON_CHECK_EVERY))
    {
        let mut la = ca.chunks_exact(LANES);
        let mut lb = cb.chunks_exact(LANES);
        for (ga, gb) in la.by_ref().zip(lb.by_ref()) {
            let mut sq = [0.0f64; LANES];
            for l in 0..LANES {
                let d = dev(ga[l], gb[l]);
                sq[l] = d * d;
            }
            // Fixed summation order: ascending lanes, one accumulator —
            // never a pairwise/tree reduction, so bits match the scalar
            // chain.
            for &q in &sq {
                acc += q;
            }
        }
        // Tail of a short final chunk (< LANES points): scalar-identical.
        for (&x, &y) in la.remainder().iter().zip(lb.remainder()) {
            let d = dev(x, y);
            acc += d * d;
        }
        if acc > limit {
            return acc;
        }
    }
    acc
}

/// The exact distance backend with per-session call accounting.
///
/// Holds borrows of the series and its rolling stats; normalization is
/// folded into the loop (`(p − μ)/σ` per point), so no normalized copies
/// of the sequences are ever materialized — the paper's memory trick.
/// Deliberately not `Clone`: a copied live counter would double-count
/// calls — workers construct their own instance and sum `calls()` after.
///
/// The inner loop runs on a [`Kernel`]; [`new`](Self::new) picks the
/// process default ([`Kernel::active`]), [`with_kernel`](Self::with_kernel)
/// pins one explicitly. The kernels are bit-identical (module docs), so
/// mixing sessions with different kernels never perturbs results.
#[derive(Debug)]
pub struct CountingDistance<'a> {
    ts: &'a TimeSeries,
    stats: &'a SeqStats,
    kind: DistanceKind,
    kernel: Kernel,
    calls: Cell<u64>,
    abandons: Cell<u64>,
}

impl<'a> CountingDistance<'a> {
    /// New backend over `ts` with the stats computed for the search's `s`,
    /// on the process-default [`Kernel`].
    pub fn new(
        ts: &'a TimeSeries,
        stats: &'a SeqStats,
        kind: DistanceKind,
    ) -> CountingDistance<'a> {
        Self::with_kernel(ts, stats, kind, Kernel::active())
    }

    /// New backend pinned to an explicit inner-loop [`Kernel`].
    pub fn with_kernel(
        ts: &'a TimeSeries,
        stats: &'a SeqStats,
        kind: DistanceKind,
        kernel: Kernel,
    ) -> CountingDistance<'a> {
        debug_assert!(
            stats.len() <= ts.num_sequences(stats.s),
            "stats cover more sequences than the series has"
        );
        CountingDistance {
            ts,
            stats,
            kind,
            kernel,
            calls: Cell::new(0),
            abandons: Cell::new(0),
        }
    }

    /// The distance variant this backend computes.
    pub fn kind(&self) -> DistanceKind {
        self.kind
    }

    /// The inner-loop kernel this session evaluates with.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Number of distance calls so far (each [`dist`](Self::dist) or
    /// [`dist_early`](Self::dist_early) invocation counts once, abandoned
    /// or not — matching the paper's accounting).
    pub fn calls(&self) -> u64 {
        self.calls.get()
    }

    /// Number of calls so far that ended early-abandoned: the partial sum
    /// proved `d >= cutoff`, so the returned value was a bound, not the
    /// exact distance. A strict subset of [`calls`](Self::calls); observing
    /// it never changes the evaluation itself.
    pub fn abandons(&self) -> u64 {
        self.abandons.get()
    }

    /// Exact distance between the sequences starting at `i` and `j`.
    #[inline]
    pub fn dist(&self, i: usize, j: usize) -> f64 {
        self.dist_early(i, j, f64::INFINITY)
    }

    /// Early-abandoning distance: returns the exact distance when it is
    /// below `cutoff`; otherwise may abandon once the running sum proves
    /// `d >= cutoff` and returns that partial lower bound (which is then
    /// `>= cutoff`, so callers comparing `d < cutoff` never observe an
    /// inexact value).
    pub fn dist_early(&self, i: usize, j: usize, cutoff: f64) -> f64 {
        self.calls.set(self.calls.get() + 1);
        let s = self.stats.s;
        let a = self.ts.seq(i, s);
        let b = self.ts.seq(j, s);
        let limit = if cutoff.is_finite() {
            cutoff * cutoff
        } else {
            f64::INFINITY
        };
        let acc = match self.kind {
            DistanceKind::Znorm => {
                let mu_a = self.stats.mean[i];
                let mu_b = self.stats.mean[j];
                let inv_sa = 1.0 / self.stats.std[i];
                let inv_sb = 1.0 / self.stats.std[j];
                let dev = move |x: f64, y: f64| (x - mu_a) * inv_sa - (y - mu_b) * inv_sb;
                match self.kernel {
                    Kernel::Scalar => sum_scalar(a, b, limit, dev),
                    Kernel::Simd => sum_simd(a, b, limit, dev),
                }
            }
            DistanceKind::Raw => {
                let dev = |x: f64, y: f64| x - y;
                match self.kernel {
                    Kernel::Scalar => sum_scalar(a, b, limit, dev),
                    Kernel::Simd => sum_simd(a, b, limit, dev),
                }
            }
        };
        if acc > limit {
            self.abandons.set(self.abandons.get() + 1);
        }
        acc.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    fn setup(n: usize, s: usize) -> (TimeSeries, SeqStats) {
        let ts = generators::ecg_like(n, 90, 1, 11).into_series("d");
        let stats = SeqStats::compute(&ts, s);
        (ts, stats)
    }

    fn naive_znorm_dist(ts: &TimeSeries, stats: &SeqStats, i: usize, j: usize) -> f64 {
        let zi = stats.znorm(ts, i);
        let zj = stats.znorm(ts, j);
        zi.iter()
            .zip(&zj)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    #[test]
    fn znorm_matches_naive_normalize_then_subtract() {
        let (ts, stats) = setup(800, 64);
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            let dist = CountingDistance::with_kernel(&ts, &stats, DistanceKind::Znorm, kernel);
            for (i, j) in [(0, 100), (3, 700), (250, 330), (0, 736)] {
                let got = dist.dist(i, j);
                let want = naive_znorm_dist(&ts, &stats, i, j);
                assert!(
                    (got - want).abs() < 1e-9,
                    "{}: ({i},{j}): {got} vs {want}",
                    kernel.name()
                );
            }
        }
    }

    #[test]
    fn raw_is_plain_euclidean() {
        let (ts, stats) = setup(500, 50);
        let want = ts
            .seq(10, 50)
            .iter()
            .zip(ts.seq(200, 50))
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            let dist = CountingDistance::with_kernel(&ts, &stats, DistanceKind::Raw, kernel);
            assert!((dist.dist(10, 200) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn early_abandon_returns_exact_below_cutoff() {
        let (ts, stats) = setup(1_000, 80);
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            let dist = CountingDistance::with_kernel(&ts, &stats, DistanceKind::Znorm, kernel);
            for (i, j) in [(0, 100), (50, 400), (111, 911)] {
                let exact = dist.dist(i, j);
                let with_cutoff = dist.dist_early(i, j, exact + 1.0);
                assert_eq!(exact, with_cutoff, "must be bit-identical below cutoff");
            }
        }
    }

    #[test]
    fn early_abandon_bound_is_at_least_cutoff() {
        let (ts, stats) = setup(1_000, 80);
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            let dist = CountingDistance::with_kernel(&ts, &stats, DistanceKind::Znorm, kernel);
            for (i, j) in [(0, 100), (50, 400), (111, 911)] {
                let exact = dist.dist(i, j);
                let cutoff = exact * 0.5;
                let d = dist.dist_early(i, j, cutoff);
                assert!(d >= cutoff, "abandoned value {d} below cutoff {cutoff}");
                assert!(d <= exact + 1e-12, "partial sum cannot exceed the exact distance");
            }
        }
    }

    #[test]
    fn every_call_is_counted_once() {
        let (ts, stats) = setup(600, 60);
        let dist = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        assert_eq!(dist.calls(), 0);
        let _ = dist.dist(0, 100);
        let _ = dist.dist_early(0, 200, 0.001); // abandons, still counted
        let _ = dist.dist_early(0, 300, f64::INFINITY);
        assert_eq!(dist.calls(), 3);
        assert_eq!(dist.abandons(), 1, "only the cutoff-clipped call abandons");
    }

    #[test]
    fn symmetric_and_zero_on_self() {
        let (ts, stats) = setup(700, 64);
        for kind in [DistanceKind::Znorm, DistanceKind::Raw] {
            for kernel in [Kernel::Scalar, Kernel::Simd] {
                let dist = CountingDistance::with_kernel(&ts, &stats, kind, kernel);
                assert!((dist.dist(20, 500) - dist.dist(500, 20)).abs() < 5e-8);
                assert!(dist.dist(123, 123) < 1e-12);
            }
        }
    }

    #[test]
    fn trait_object_dispatch_matches_concrete_calls() {
        let (ts, stats) = setup(600, 60);
        let concrete = CountingDistance::new(&ts, &stats, DistanceKind::Znorm);
        let dyn_dist: &dyn Distance = &concrete;
        let want = CountingDistance::new(&ts, &stats, DistanceKind::Znorm).dist(5, 300);
        assert_eq!(dyn_dist.dist(5, 300), want);
        assert_eq!(dyn_dist.kind(), DistanceKind::Znorm);
        assert_eq!(dyn_dist.calls(), 1);
    }

    #[test]
    fn scalar_backend_is_the_default_fallback() {
        match active_backend() {
            Backend::Scalar => assert!(!cfg!(feature = "pjrt")),
            Backend::XlaPjrt => assert!(cfg!(feature = "pjrt")),
        }
    }

    #[test]
    fn kernel_names_roundtrip() {
        for k in [Kernel::Scalar, Kernel::Simd] {
            assert_eq!(Kernel::from_name(k.name()), Some(k));
        }
        assert_eq!(Kernel::from_name("avx"), None);
        // active() latches to one of the two valid kernels
        let a = Kernel::active();
        assert!(a == Kernel::Scalar || a == Kernel::Simd);
        assert_eq!(Kernel::active(), a, "active kernel must be stable");
    }

    /// Satellite: the lane-remainder paths the SIMD rewrite is most likely
    /// to get wrong. s not a multiple of `ABANDON_CHECK_EVERY`, s not a
    /// multiple of `LANES`, and s smaller than one lane group — all must
    /// stay bit-identical to the scalar kernel and match the naive sum.
    #[test]
    fn kernels_bit_identical_at_awkward_lengths() {
        let ts = generators::ecg_like(1_200, 90, 1, 11).into_series("d");
        for s in [3usize, 5, 7, 8, 9, 15, 16, 17, 23, 25, 31, 47, 90, 113] {
            let stats = SeqStats::compute(&ts, s);
            for kind in [DistanceKind::Znorm, DistanceKind::Raw] {
                let sc = CountingDistance::with_kernel(&ts, &stats, kind, Kernel::Scalar);
                let si = CountingDistance::with_kernel(&ts, &stats, kind, Kernel::Simd);
                for (i, j) in [(0usize, 200), (17, 801), (333, 950)] {
                    let a = sc.dist(i, j);
                    let b = si.dist(i, j);
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "s={s} {kind:?} ({i},{j}): scalar {a} vs simd {b}"
                    );
                    // abandoned path: same partial bound, bit for bit
                    let cut = a * 0.6;
                    assert_eq!(
                        sc.dist_early(i, j, cut).to_bits(),
                        si.dist_early(i, j, cut).to_bits(),
                        "s={s} {kind:?} ({i},{j}): abandoned bounds differ"
                    );
                }
                assert_eq!(sc.calls(), si.calls(), "s={s} {kind:?}: call counts differ");
            }
        }
    }

    /// Satellite: true distance landing exactly on the cutoff. The abandon
    /// predicate is strict (`acc > limit`), and partial sums only grow, so
    /// a final sum equal to the squared cutoff is never abandoned — both
    /// kernels must return the exact value, bit-identical to `dist`.
    #[test]
    fn cutoff_exactly_on_distance_is_not_abandoned() {
        let (ts, stats) = setup(900, 72);
        for kind in [DistanceKind::Znorm, DistanceKind::Raw] {
            for kernel in [Kernel::Scalar, Kernel::Simd] {
                let dist = CountingDistance::with_kernel(&ts, &stats, kind, kernel);
                for (i, j) in [(0usize, 150), (40, 600), (211, 777)] {
                    let exact = dist.dist(i, j);
                    let at_cutoff = dist.dist_early(i, j, exact);
                    assert_eq!(
                        exact.to_bits(),
                        at_cutoff.to_bits(),
                        "{} {kind:?} ({i},{j}): d==cutoff must return the exact value",
                        kernel.name()
                    );
                }
            }
        }
    }

    /// Satellite: sequences shorter than one lane group (s < LANES) run
    /// entirely on the tail path, which must equal the scalar loop.
    #[test]
    fn shorter_than_one_lane_group() {
        let ts = generators::sine_with_noise(400, 0.3, 5).into_series("tiny");
        for s in 2..LANES {
            let stats = SeqStats::compute(&ts, s);
            let sc = CountingDistance::with_kernel(&ts, &stats, DistanceKind::Znorm, Kernel::Scalar);
            let si = CountingDistance::with_kernel(&ts, &stats, DistanceKind::Znorm, Kernel::Simd);
            for (i, j) in [(0usize, 50), (9, 311)] {
                assert_eq!(sc.dist(i, j).to_bits(), si.dist(i, j).to_bits(), "s={s}");
            }
        }
    }
}
