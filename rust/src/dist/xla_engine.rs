//! Batched distance evaluation through the PJRT runtime (the `pjrt`
//! feature's replacement for hot inner loops).
//!
//! [`XlaBatchEngine`] owns the device-ready row matrix
//! ([`PreparedSeqs`]) for one series and streams candidate sets through
//! the `query_row` artifact in `QUERY_B`-sized chunks, stopping between
//! chunks as soon as a distance below the caller's threshold shows up —
//! the batched analogue of the scalar engine's early abandoning. Chunk
//! granularity is the trade: the scalar engine abandons per *point*, this
//! engine per *chunk of pairs*, winning whenever the accelerator evaluates
//! a chunk faster than the CPU evaluates the abandoned prefix.
//!
//! Accounting: `pair_evals` counts evaluated pairs (one per candidate in
//! each executed chunk) so XLA-side work remains comparable with the
//! scalar engine's `calls()` in cps terms.

use std::cell::Cell;

use anyhow::Result;

use crate::dist::{CountingDistance, Distance, DistanceKind};
use crate::runtime::{ArtifactSet, PreparedSeqs};
use crate::ts::{SeqStats, TimeSeries};

/// Batched distance engine over one prepared series.
pub struct XlaBatchEngine<'a> {
    arts: &'a ArtifactSet,
    prep: PreparedSeqs,
    /// Pair distances evaluated so far (the XLA-side cost counter).
    pub pair_evals: u64,
}

impl<'a> XlaBatchEngine<'a> {
    /// Prepare every sequence of `ts` (z-normalized when `znormalize`)
    /// for artifact upload. Fails when `stats.s` exceeds the artifacts'
    /// padded length — callers fall back to the scalar engine.
    pub fn new(
        arts: &'a ArtifactSet,
        ts: &TimeSeries,
        stats: &SeqStats,
        znormalize: bool,
    ) -> Result<XlaBatchEngine<'a>> {
        let prep = PreparedSeqs::build(arts, ts, stats, znormalize)?;
        Ok(XlaBatchEngine {
            arts,
            prep,
            pair_evals: 0,
        })
    }

    /// Number of prepared sequences.
    pub fn len(&self) -> usize {
        self.prep.n
    }

    /// Whether the series has no prepared sequences.
    pub fn is_empty(&self) -> bool {
        self.prep.n == 0
    }

    /// The device-ready rows (for callers composing their own artifact
    /// invocations).
    pub fn prepared(&self) -> &PreparedSeqs {
        &self.prep
    }

    /// Distances from `query` to `cands`, evaluated chunk-by-chunk.
    ///
    /// Stops after the first chunk containing a distance strictly below
    /// `stop_below` (the candidate is disqualified — no point refining
    /// further). Returns how many candidates were evaluated and their
    /// distances, in candidate order.
    pub fn query_row(
        &mut self,
        query: usize,
        cands: &[usize],
        stop_below: f64,
    ) -> Result<(usize, Vec<f64>)> {
        let b = self.arts.query_b();
        let mut dists: Vec<f64> = Vec::with_capacity(cands.len().min(b));
        let mut done = 0usize;
        for chunk in cands.chunks(b) {
            let (d, dmin) = self.arts.query_row_chunk(&self.prep, query, chunk)?;
            done += chunk.len();
            self.pair_evals += chunk.len() as u64;
            dists.extend(d);
            if dmin < stop_below {
                break;
            }
        }
        Ok((done, dists))
    }

    /// Chain distances `d(ia[t], ib[t])` through the `pair_dist` artifact
    /// (the batched warm-up path).
    pub fn pair_chain(&mut self, ia: &[usize], ib: &[usize]) -> Result<Vec<f64>> {
        let out = self.arts.pair_dist_chain(&self.prep, ia, ib)?;
        self.pair_evals += out.len() as u64;
        Ok(out)
    }
}

/// The XLA backend behind the [`Distance`] trait: one prepared series,
/// pairs evaluated through the `pair_dist` artifact.
///
/// This is the [`SearchContext`](crate::context::SearchContext) session
/// adapter for `Backend::XlaPjrt`: it owns its [`ArtifactSet`] and the
/// device-ready rows, so the `Box<dyn Distance>` a context hands out is
/// self-contained. Two caveats the scalar backend does not have:
///
/// * artifacts compute in f32 — distances agree with the scalar engine to
///   ~1e-6 relative, which is below the paper's comparison tolerances but
///   not bit-identical;
/// * per-pair dispatch cannot early-abandon, so `cutoff` is ignored (the
///   returned distance is always exact, which trivially satisfies the
///   [`Distance`] contract).
///
/// If an individual execution fails mid-session the call is completed by
/// the embedded scalar fallback, so a flaky device degrades throughput,
/// never correctness.
pub struct XlaPairDistance<'a> {
    arts: ArtifactSet,
    prep: PreparedSeqs,
    fallback: CountingDistance<'a>,
    kind: DistanceKind,
    calls: Cell<u64>,
}

impl<'a> XlaPairDistance<'a> {
    /// Load the default artifacts and prepare every sequence of `ts`.
    /// Errors (no artifacts, no PJRT client, `s > s_pad`) mean the caller
    /// should fall back to the scalar backend.
    pub fn try_new(
        ts: &'a TimeSeries,
        stats: &'a SeqStats,
        kind: DistanceKind,
    ) -> Result<XlaPairDistance<'a>> {
        let arts = ArtifactSet::load_default()?;
        let prep =
            PreparedSeqs::build(&arts, ts, stats, kind == DistanceKind::Znorm)?;
        Ok(XlaPairDistance {
            arts,
            prep,
            fallback: CountingDistance::new(ts, stats, kind),
            kind,
            calls: Cell::new(0),
        })
    }
}

impl Distance for XlaPairDistance<'_> {
    fn kind(&self) -> DistanceKind {
        self.kind
    }

    fn is_exact(&self) -> bool {
        false // f32 artifacts: not strict bounds for the f64 scalar path
    }

    fn calls(&self) -> u64 {
        self.calls.get()
    }

    fn dist_early(&self, i: usize, j: usize, cutoff: f64) -> f64 {
        self.calls.set(self.calls.get() + 1);
        match self.arts.pair_dist_chain(&self.prep, &[i], &[j]) {
            Ok(d) if d.len() == 1 => d[0],
            _ => self.fallback.dist_early(i, j, cutoff),
        }
    }
}
