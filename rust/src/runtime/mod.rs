//! PJRT runtime: load + execute the AOT artifacts from `make artifacts`.
//!
//! Python is build-time only; this module is the entire runtime bridge.
//! It has two halves:
//!
//! * **Manifest layer** (always compiled) — [`Manifest`] parses
//!   `artifacts/manifest.txt` and [`default_artifact_dir`] locates it, so
//!   tooling (`hst info`) can inspect artifacts in any build.
//! * **Execution layer** (`pjrt` cargo feature) — `ArtifactSet` compiles
//!   the HLO text through the `xla` crate's PJRT client and executes it;
//!   `PreparedSeqs` holds the padded f32 rows ready for upload. Without
//!   the feature these types do not exist and the scalar engine
//!   ([`crate::dist::CountingDistance`]) is the only backend.
//!
//! Artifacts (see python/compile/aot.py):
//! * `pair_dist`  — f32[PAIR_B, S_PAD] ×2 → f32[PAIR_B] (warm-up chains)
//! * `query_row`  — f32[S_PAD], f32[QUERY_B, S_PAD] → (dists, min, argmin)
//! * `mp_tile`    — two f32[TILE, S_PAD] blocks + (row0, col0, excl) →
//!                  masked (rowmin, rowarg, colmin, colarg)

#[cfg(feature = "pjrt")]
mod exec;

#[cfg(feature = "pjrt")]
pub use exec::{ArtifactSet, PreparedSeqs};

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Padded sequence length the artifacts were lowered for.
    pub s_pad: usize,
    /// Batch size of the `pair_dist` artifact.
    pub pair_b: usize,
    /// Batch size of the `query_row` artifact.
    pub query_b: usize,
    /// Edge length of one `mp_tile` block.
    pub tile: usize,
    /// (name, file) pairs.
    pub entries: Vec<(String, String)>,
}

impl Manifest {
    /// Parse the manifest file written by `python -m compile.aot`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut m = Manifest {
            s_pad: 0,
            pair_b: 0,
            query_b: 0,
            tile: 0,
            entries: Vec::new(),
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            match fields.first() {
                Some(&"config") => {
                    for kv in &fields[1..] {
                        let Some((k, v)) = kv.split_once('=') else {
                            bail!("bad config field {kv:?}");
                        };
                        let v: usize = v.parse().context("config value")?;
                        match k {
                            "s_pad" => m.s_pad = v,
                            "pair_b" => m.pair_b = v,
                            "query_b" => m.query_b = v,
                            "tile" => m.tile = v,
                            _ => {} // forward compatible
                        }
                    }
                }
                Some(&"artifact") => {
                    if fields.len() < 3 {
                        bail!("bad artifact line {line:?}");
                    }
                    m.entries.push((fields[1].to_string(), fields[2].to_string()));
                }
                _ => bail!("unrecognized manifest line {line:?}"),
            }
        }
        if m.s_pad == 0 || m.entries.is_empty() {
            bail!("manifest incomplete: {m:?}");
        }
        Ok(m)
    }
}

/// Default artifact directory (relative to the crate root / cwd).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("HSTIME_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // try cwd, then the cargo manifest dir (tests run from target dirs)
    let cwd = PathBuf::from("artifacts");
    if cwd.join("manifest.txt").exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(name: &str, body: &str) -> PathBuf {
        let mut dir = std::env::temp_dir();
        dir.push(format!("hstime_manifest_{}_{}", std::process::id(), name));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
        dir
    }

    #[test]
    fn parses_config_and_artifacts() {
        let dir = write_manifest(
            "ok",
            "# comment\n\
             config s_pad=512 pair_b=256 query_b=512 tile=128\n\
             artifact pair_dist pair_dist.hlo.txt\n\
             artifact query_row query_row.hlo.txt\n\
             artifact mp_tile mp_tile.hlo.txt\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.s_pad, 512);
        assert_eq!(m.pair_b, 256);
        assert_eq!(m.query_b, 512);
        assert_eq!(m.tile, 128);
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.entries[0].0, "pair_dist");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn incomplete_manifest_is_an_error() {
        let dir = write_manifest("incomplete", "config pair_b=256\n");
        assert!(Manifest::load(&dir).is_err(), "missing s_pad + artifacts");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_lines_are_rejected_unknown_config_keys_ignored() {
        let dir = write_manifest(
            "fwd",
            "config s_pad=64 future_knob=3\nartifact pair_dist p.hlo\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.s_pad, 64);
        std::fs::remove_dir_all(dir).ok();

        let dir = write_manifest("bad", "bogus line here\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_directory_gives_context() {
        let err = Manifest::load(Path::new("/nonexistent/hstime-artifacts"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("manifest.txt"), "{err}");
    }
}
