//! PJRT execution: compile the AOT artifacts and run them.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute` (the pattern of /opt/xla-example/load_hlo). The interchange
//! format is HLO **text** — xla_extension 0.5.1 rejects jax≥0.5's
//! serialized protos (64-bit instruction ids), while the text parser
//! reassigns ids.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::discord::NndProfile;
use crate::ts::{SeqStats, TimeSeries};

use super::{default_artifact_dir, Manifest};

/// Compiled executables for all shipped artifacts.
pub struct ArtifactSet {
    manifest: Manifest,
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pair_dist: xla::PjRtLoadedExecutable,
    query_row: xla::PjRtLoadedExecutable,
    mp_tile: xla::PjRtLoadedExecutable,
}

impl ArtifactSet {
    /// Compile all artifacts on the CPU PJRT client. Fails with a clear
    /// message when `make artifacts` has not been run.
    pub fn load(dir: &Path) -> Result<ArtifactSet> {
        let manifest = Manifest::load(dir)?;
        // The manifest layer is lenient (hst info inspects partial
        // manifests); execution needs every batch dimension.
        if manifest.pair_b == 0 || manifest.query_b == 0 || manifest.tile == 0 {
            bail!(
                "manifest missing batch config (pair_b={}, query_b={}, tile={}): \
                 regenerate with python -m compile.aot",
                manifest.pair_b,
                manifest.query_b,
                manifest.tile
            );
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes: Vec<(String, xla::PjRtLoadedExecutable)> = Vec::new();
        for (name, file) in &manifest.entries {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            exes.push((name.clone(), exe));
        }
        let mut take = |want: &str| -> Result<xla::PjRtLoadedExecutable> {
            let pos = exes
                .iter()
                .position(|(n, _)| n == want)
                .with_context(|| format!("manifest missing artifact {want}"))?;
            Ok(exes.remove(pos).1)
        };
        let pair_dist = take("pair_dist")?;
        let query_row = take("query_row")?;
        let mp_tile = take("mp_tile")?;
        Ok(ArtifactSet {
            manifest,
            client,
            pair_dist,
            query_row,
            mp_tile,
        })
    }

    /// Load from [`default_artifact_dir`].
    pub fn load_default() -> Result<ArtifactSet> {
        Self::load(&default_artifact_dir())
    }

    /// Padded sequence length of the compiled artifacts.
    pub fn s_pad(&self) -> usize {
        self.manifest.s_pad
    }

    /// Batch size of the `pair_dist` artifact.
    pub fn pair_b(&self) -> usize {
        self.manifest.pair_b
    }

    /// Batch size of the `query_row` artifact.
    pub fn query_b(&self) -> usize {
        self.manifest.query_b
    }

    /// Edge length of one `mp_tile` block.
    pub fn tile(&self) -> usize {
        self.manifest.tile
    }

    /// Chain distances d(ia[t], ib[t]) via the `pair_dist` artifact.
    pub fn pair_dist_chain(
        &self,
        prep: &PreparedSeqs,
        ia: &[usize],
        ib: &[usize],
    ) -> Result<Vec<f64>> {
        assert_eq!(ia.len(), ib.len());
        let b = self.pair_b();
        let s_pad = self.s_pad();
        let mut out = Vec::with_capacity(ia.len());
        let mut x = vec![0.0f32; b * s_pad];
        let mut y = vec![0.0f32; b * s_pad];
        for chunk_start in (0..ia.len()).step_by(b) {
            let chunk = (ia.len() - chunk_start).min(b);
            x[..].fill(0.0);
            y[..].fill(0.0);
            for t in 0..chunk {
                x[t * s_pad..(t + 1) * s_pad]
                    .copy_from_slice(prep.row(ia[chunk_start + t]));
                y[t * s_pad..(t + 1) * s_pad]
                    .copy_from_slice(prep.row(ib[chunk_start + t]));
            }
            let lx = xla::Literal::vec1(&x).reshape(&[b as i64, s_pad as i64])?;
            let ly = xla::Literal::vec1(&y).reshape(&[b as i64, s_pad as i64])?;
            let res = self.pair_dist.execute::<xla::Literal>(&[lx, ly])?[0][0]
                .to_literal_sync()?;
            let d = res.to_tuple1()?.to_vec::<f32>()?;
            out.extend(d[..chunk].iter().map(|&v| v as f64));
        }
        Ok(out)
    }

    /// One `query_row` chunk: distances from `query` to `cands`
    /// (|cands| <= query_b). Returns (dists, min over the real entries).
    pub fn query_row_chunk(
        &self,
        prep: &PreparedSeqs,
        query: usize,
        cands: &[usize],
    ) -> Result<(Vec<f64>, f64)> {
        let b = self.query_b();
        let s_pad = self.s_pad();
        assert!(cands.len() <= b, "chunk larger than QUERY_B");
        let mut c = vec![0.0f32; b * s_pad];
        for (t, &j) in cands.iter().enumerate() {
            c[t * s_pad..(t + 1) * s_pad].copy_from_slice(prep.row(j));
        }
        // padding rows are zero vectors; their distance to the query is
        // |q| which is harmless because we ignore entries >= cands.len()
        let lq = xla::Literal::vec1(prep.row(query));
        let lc = xla::Literal::vec1(&c).reshape(&[b as i64, s_pad as i64])?;
        let res = self.query_row.execute::<xla::Literal>(&[lq, lc])?[0][0]
            .to_literal_sync()?;
        let parts = res.to_tuple()?;
        let d32 = parts[0].to_vec::<f32>()?;
        let dists: Vec<f64> = d32[..cands.len()].iter().map(|&v| v as f64).collect();
        let dmin = dists.iter().cloned().fold(f64::INFINITY, f64::min);
        Ok((dists, dmin))
    }

    /// One masked matrix-profile tile: rows `row0..row0+TILE` vs columns
    /// `col0..col0+TILE`, exclusion half-width `excl`. Merges the returned
    /// row/col minima into `profile` (entries beyond `prep.n` skipped).
    pub fn mp_tile_update(
        &self,
        prep: &PreparedSeqs,
        row0: usize,
        col0: usize,
        excl: usize,
        profile: &mut NndProfile,
    ) -> Result<()> {
        let t = self.tile();
        let s_pad = self.s_pad();
        let fill = |start: usize| -> Vec<f32> {
            let mut m = vec![0.0f32; t * s_pad];
            for r in 0..t {
                if start + r < prep.n {
                    m[r * s_pad..(r + 1) * s_pad].copy_from_slice(prep.row(start + r));
                }
            }
            m
        };
        let a = fill(row0);
        let b = fill(col0);
        let la = xla::Literal::vec1(&a).reshape(&[t as i64, s_pad as i64])?;
        let lb = xla::Literal::vec1(&b).reshape(&[t as i64, s_pad as i64])?;
        let res = self
            .mp_tile
            .execute::<xla::Literal>(&[
                la,
                lb,
                xla::Literal::scalar(row0 as i32),
                xla::Literal::scalar(col0 as i32),
                xla::Literal::scalar(excl as i32),
            ])?[0][0]
            .to_literal_sync()?;
        let parts = res.to_tuple()?;
        let rowmin = parts[0].to_vec::<f32>()?;
        let rowarg = parts[1].to_vec::<i32>()?;
        let colmin = parts[2].to_vec::<f32>()?;
        let colarg = parts[3].to_vec::<i32>()?;
        const BIG: f32 = 1.0e38;
        for r in 0..t {
            let gi = row0 + r;
            if gi >= prep.n || rowmin[r] >= BIG {
                continue;
            }
            let j = rowarg[r] as usize;
            if j < prep.n {
                profile.observe_one(gi, j, rowmin[r] as f64);
            }
        }
        for cidx in 0..t {
            let gj = col0 + cidx;
            if gj >= prep.n || colmin[cidx] >= BIG {
                continue;
            }
            let i = colarg[cidx] as usize;
            if i < prep.n {
                profile.observe_one(gj, i, colmin[cidx] as f64);
            }
        }
        Ok(())
    }

    /// Full matrix profile via tiles (the XLA SCAMP path). Covers every
    /// (row-block, col-block) pair on and above the diagonal; the masked
    /// kernel updates both row and column profiles, so each unordered pair
    /// is evaluated once.
    pub fn matrix_profile(&self, prep: &PreparedSeqs, s: usize) -> Result<NndProfile> {
        let t = self.tile();
        let n = prep.n;
        let mut profile = NndProfile::new(n);
        let mut row0 = 0;
        while row0 < n {
            let mut col0 = row0;
            while col0 < n {
                self.mp_tile_update(prep, row0, col0, s, &mut profile)?;
                col0 += t;
            }
            row0 += t;
        }
        Ok(profile)
    }
}

/// All sequences of one series, z-normalized (or raw) and zero-padded to
/// `s_pad`, as f32 rows ready for literal upload.
pub struct PreparedSeqs {
    /// Number of sequences.
    pub n: usize,
    s_pad: usize,
    data: Vec<f32>,
}

impl PreparedSeqs {
    /// Prepare every sequence of `ts`. Fails when `s > s_pad` (caller
    /// should fall back to the scalar engine).
    pub fn build(
        arts: &ArtifactSet,
        ts: &TimeSeries,
        stats: &SeqStats,
        znormalize: bool,
    ) -> Result<PreparedSeqs> {
        let s = stats.s;
        let s_pad = arts.s_pad();
        if s > s_pad {
            bail!("sequence length {s} exceeds artifact s_pad {s_pad}");
        }
        let n = stats.len();
        let mut data = vec![0.0f32; n * s_pad];
        let mut buf = vec![0.0f64; s];
        for k in 0..n {
            let row = &mut data[k * s_pad..k * s_pad + s];
            if znormalize {
                stats.znorm_into(ts, k, &mut buf);
                for (o, &v) in row.iter_mut().zip(&buf) {
                    *o = v as f32;
                }
            } else {
                for (o, &v) in row.iter_mut().zip(ts.seq(k, s)) {
                    *o = v as f32;
                }
            }
        }
        Ok(PreparedSeqs { n, s_pad, data })
    }

    /// Row `k` (zero-padded).
    #[inline]
    pub fn row(&self, k: usize) -> &[f32] {
        &self.data[k * self.s_pad..(k + 1) * self.s_pad]
    }
}
