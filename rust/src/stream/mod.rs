//! Streaming discord monitoring: incremental sliding-window search.
//!
//! HST's core insight — sequences close in time have similar
//! nearest-neighbor distances, so warm profiles transfer between
//! overlapping searches (paper Sec. 3.2) — is exactly the structure of a
//! sliding-window monitor: consecutive windows overlap almost entirely,
//! so almost all of the previous refresh's exact nnd knowledge is still
//! valid after the window advances. This module turns that observation
//! into an incremental engine:
//!
//! * [`StreamingMonitor`] ingests appended points and maintains, per
//!   point, the state a search needs — rolling z-norm stats (one new
//!   `(μ, σ)` per point via the pure per-window kernel
//!   [`ts::window_stats`](crate::ts::window_stats)), the SAX word of the
//!   one new sequence (inserted at the leading edge, evicted at the
//!   trailing edge), and the nnd profile, which is **shifted** across
//!   window advances: entries whose neighbor is still inside the window
//!   keep their exact pair distance as a valid upper bound, entries whose
//!   neighbor was evicted reset to the ∞ sentinel.
//! * Each [`refresh`](StreamingMonitor::refresh) is then a *warm*
//!   [`SearchContext`](crate::context::SearchContext) search: the monitor
//!   seeds the context's stats/index caches from its deques and hands the
//!   shifted profile to the warm-profile cache, so only the few new
//!   sequences pay real work instead of the cold ~2N-call warm-up.
//! * [`HstStream`] (engine id `hst-stream`) is the registered
//!   [`Algorithm`](crate::algo::Algorithm) face of the same search: serial
//!   HST on the scalar backend, reporting as `hst-stream`. Through the
//!   service coordinator's context LRU, repeated `hst-stream` jobs get the
//!   same warm-profile carry-over the monitor applies across window
//!   shifts.
//!
//! **Exactness survives streaming.** After any sequence of appends, a
//! refresh's discord set over the current window is bit-identical
//! (positions and distances) to a cold serial `hst` run on that window.
//! The proof obligations are discharged by construction: per-window stats
//! and SAX words are pure functions of the window (so incremental entries
//! equal a cold recompute bit for bit), and every shifted profile entry is
//! an exactly-evaluated pair distance whose pair is still admissible —
//! hence a valid upper bound, which is all HST's pruning needs. The
//! property test `prop_stream_refresh_matches_cold_hst_bitwise`
//! (`tests/integration_stream.rs`) checks this over random series and
//! random append schedules, along with the strict distance-call reduction
//! of warm refreshes.
//!
//! ```
//! use hstime::prelude::*;
//!
//! let pts = generators::sine_with_noise(3_000, 0.1, 7);
//! let params = SearchParams::new(64, 4, 4);
//! let mut mon = StreamingMonitor::new(params.clone(), 1_500).unwrap();
//!
//! // fill the window, then refresh: the first refresh is a cold search
//! for &x in &pts[..1_500] {
//!     mon.append(x).unwrap();
//! }
//! let cold = mon.refresh().unwrap();
//! assert!(!cold.warm);
//!
//! // slide the window and refresh again: warm, and strictly cheaper
//! for &x in &pts[1_500..1_700] {
//!     mon.append(x).unwrap();
//! }
//! let warm = mon.refresh().unwrap();
//! assert!(warm.warm && warm.prep_calls == 0);
//! assert!(warm.distance_calls < cold.distance_calls);
//!
//! // discords are reported in global stream coordinates, and match a
//! // cold batch search over the same window exactly
//! let batch = algo::hst::HstSearch::default()
//!     .run(&mon.window_series(), &params)
//!     .unwrap();
//! assert_eq!(
//!     warm.discords[0].position,
//!     mon.window_start() + batch.discords[0].position as u64
//! );
//! assert_eq!(warm.discords[0].nnd.to_bits(), batch.discords[0].nnd.to_bits());
//! ```

mod engine;
mod monitor;

pub use engine::HstStream;
pub use monitor::{StreamDiscord, StreamUpdate, StreamingMonitor};
