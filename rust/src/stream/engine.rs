//! `hst-stream` — the registered engine face of the streaming search.

use anyhow::Result;

use crate::algo::hst::HstSearch;
use crate::algo::{Algorithm, SearchReport};
use crate::config::SearchParams;
use crate::context::SearchContext;

/// The engine id `hst-stream` reports under (shared with the monitor's
/// internal refresh searches).
pub(crate) const ENGINE_ID: &str = "hst-stream";

/// Serial HST pinned to the exact scalar backend, reporting as
/// `hst-stream` — the engine the [`StreamingMonitor`] drives on every
/// refresh, registered in [`algo::by_name`](crate::algo::by_name) so the
/// service and CLI can address it directly.
///
/// On a one-shot run it behaves exactly like `hst` (a static series is a
/// stream with no appends). Its value shows on a *warm*
/// [`SearchContext`]: it always reads and feeds the context's
/// warm-profile cache, so repeated `hst-stream` jobs through the service
/// coordinator's context LRU get the same carry-over the monitor applies
/// across window shifts.
///
/// [`StreamingMonitor`]: super::StreamingMonitor
#[derive(Debug, Default, Clone, Copy)]
pub struct HstStream;

impl Algorithm for HstStream {
    fn name(&self) -> &'static str {
        ENGINE_ID
    }

    fn search(&self, ctx: &SearchContext, params: &SearchParams) -> Result<SearchReport> {
        // scalar_only: streaming exactness (bit-identity with cold serial
        // runs) requires the exact backend regardless of the context's
        // configured one.
        HstSearch::default().run_serial(ctx, params, self.name(), true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    #[test]
    fn one_shot_matches_serial_hst_bitwise() {
        let ts = generators::ecg_like(1_400, 100, 1, 71).into_series("e");
        let params = SearchParams::new(80, 4, 4).with_discords(2);
        let hst = HstSearch::default().run(&ts, &params).unwrap();
        let hs = HstStream.run(&ts, &params).unwrap();
        assert_eq!(hs.algo, "hst-stream");
        assert_eq!(hs.discords.len(), hst.discords.len());
        for (a, b) in hs.discords.iter().zip(&hst.discords) {
            assert_eq!(a.position, b.position);
            assert_eq!(a.nnd.to_bits(), b.nnd.to_bits());
        }
        assert_eq!(hs.distance_calls, hst.distance_calls);
    }

    #[test]
    fn warm_context_carries_across_runs() {
        let ts = generators::sine_with_noise(1_500, 0.2, 72).into_series("s");
        let params = SearchParams::new(64, 4, 4);
        let ctx = SearchContext::builder(&ts).build();
        let cold = HstStream.run_ctx(&ctx, &params).unwrap();
        let warm = HstStream.run_ctx(&ctx, &params).unwrap();
        assert!(cold.prep_calls > 0);
        assert_eq!(warm.prep_calls, 0);
        assert_eq!(cold.discords[0].position, warm.discords[0].position);
    }
}
