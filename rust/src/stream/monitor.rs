//! The sliding-window streaming monitor (see the [module docs](super)).

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::config::SearchParams;
use crate::context::SearchContext;
use crate::discord::{NndProfile, NND_INIT, NO_NEIGHBOR};
use crate::dist::Kernel;
use crate::sax::{SaxIndex, SaxWord, WordBuilder};
use crate::snapshot::{MonitorSnapshot, SnapshotError};
use crate::ts::{window_stats, SeqStats, TimeSeries};
use crate::util::json::Json;

use super::engine::ENGINE_ID;

/// "no neighbor yet" marker in global stream coordinates.
const NO_STREAM_NEIGHBOR: u64 = u64::MAX;

/// One discord reported by a refresh, in **global stream coordinates**
/// (position 0 = the first point ever appended).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamDiscord {
    /// Global position of the discord sequence's first point.
    pub position: u64,
    /// Its exact nearest-neighbor distance within the current window.
    pub nnd: f64,
    /// Global position of the nearest neighbor.
    pub neighbor: u64,
}

impl StreamDiscord {
    /// Serialize for the service protocol (`docs/PROTOCOL.md`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("position", self.position)
            .set("nnd", self.nnd)
            .set("neighbor", self.neighbor)
    }
}

/// Outcome of one [`StreamingMonitor::refresh`].
#[derive(Debug, Clone)]
pub struct StreamUpdate {
    /// 1-based refresh sequence number.
    pub refresh: u64,
    /// Global position of the window's first point.
    pub window_start: u64,
    /// Points in the window at refresh time.
    pub window_len: usize,
    /// Sequences N in the refreshed search space.
    pub n_sequences: usize,
    /// Whether a previous refresh's shifted profile warmed this search.
    pub warm: bool,
    /// Distance calls this refresh spent (exact accounting).
    pub distance_calls: u64,
    /// Distance calls spent on preparation (0 on warm refreshes).
    pub prep_calls: u64,
    /// The window's discords, best first, in global coordinates.
    pub discords: Vec<StreamDiscord>,
}

impl StreamUpdate {
    /// Cost per sequence of this refresh (the paper's cps, per refresh).
    pub fn cps(&self) -> f64 {
        crate::metrics::cps(
            self.distance_calls,
            self.n_sequences,
            self.discords.len().max(1),
        )
    }

    /// Serialize for the service protocol (`docs/PROTOCOL.md`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("refresh", self.refresh)
            .set("window_start", self.window_start)
            .set("window_len", self.window_len)
            .set("n_sequences", self.n_sequences)
            .set("warm", self.warm)
            .set("distance_calls", self.distance_calls)
            .set("prep_calls", self.prep_calls)
            .set("cps", self.cps())
            .set(
                "discords",
                self.discords.iter().map(|d| d.to_json()).collect::<Vec<_>>(),
            )
    }
}

/// Incremental sliding-window discord monitor (see the
/// [module docs](super) for the design and the exactness argument).
///
/// Per appended point the monitor does O(s) work: the one new complete
/// sequence's rolling stats ([`window_stats`]) and SAX word
/// ([`WordBuilder`]), plus O(1) deque bookkeeping — never a full-window
/// recompute. A [`refresh`](Self::refresh) materializes the prepared
/// state into a [`SearchContext`] (stats, index, and the shifted warm
/// profile) and runs a warm serial HST search reporting as `hst-stream`.
pub struct StreamingMonitor {
    name: String,
    params: SearchParams,
    capacity: usize,
    refresh_every: usize,
    kernel: Kernel,
    wb: WordBuilder,
    /// Window points; front = oldest.
    buf: VecDeque<f64>,
    /// Global position of `buf[0]`.
    start: u64,
    /// Per-sequence rolling stats, aligned with sequence starts.
    stats_mean: VecDeque<f64>,
    stats_std: VecDeque<f64>,
    /// Per-sequence SAX words, same alignment.
    words: VecDeque<SaxWord>,
    /// Carried nnd profile; `ngh` holds **global** neighbor positions so
    /// window shifts need no renumbering until refresh time.
    nnd: VecDeque<f64>,
    ngh: VecDeque<u64>,
    /// Scratch for the newest sequence's points.
    scratch: Vec<f64>,
    warm: bool,
    pending: usize,
    refreshes: u64,
    total_calls: u64,
}

impl StreamingMonitor {
    /// A monitor holding at most `capacity` points. `capacity` must be at
    /// least `2·s` so the window always admits non-self-match pairs
    /// (4·s or more is a sensible floor in practice).
    pub fn new(params: SearchParams, capacity: usize) -> Result<StreamingMonitor> {
        let s = params.sax.s;
        ensure!(
            capacity >= 2 * s,
            "window capacity {capacity} too small for s={s} (need >= 2·s)"
        );
        let wb = WordBuilder::new(&params.sax);
        Ok(StreamingMonitor {
            name: "stream".to_string(),
            params,
            capacity,
            refresh_every: 0,
            kernel: Kernel::active(),
            wb,
            buf: VecDeque::with_capacity(capacity + 1),
            start: 0,
            stats_mean: VecDeque::new(),
            stats_std: VecDeque::new(),
            words: VecDeque::new(),
            nnd: VecDeque::new(),
            ngh: VecDeque::new(),
            scratch: Vec::with_capacity(s),
            warm: false,
            pending: 0,
            refreshes: 0,
            total_calls: 0,
        })
    }

    /// Name used for the window series (shows up in reports).
    pub fn with_name(mut self, name: impl Into<String>) -> StreamingMonitor {
        self.name = name.into();
        self
    }

    /// Auto-refresh every `points` appended points (`0`, the default,
    /// means refreshes are explicit via [`refresh`](Self::refresh)).
    pub fn with_refresh_every(mut self, points: usize) -> StreamingMonitor {
        self.refresh_every = points;
        self
    }

    /// Pin the inner-loop [`Kernel`] refresh searches run on (default:
    /// [`Kernel::active`]). Bit-neutral: the kernels are bit-identical,
    /// so the streaming exactness story is unaffected either way.
    pub fn with_kernel(mut self, kernel: Kernel) -> StreamingMonitor {
        self.kernel = kernel;
        self
    }

    /// The inner-loop kernel refresh searches run on.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The stream name (see [`with_name`](Self::with_name)).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Export the monitor's full state as a [`MonitorSnapshot`] — every
    /// field a warm restart needs, bit for bit. [`from_snapshot`]
    /// (Self::from_snapshot) on the result continues exactly where this
    /// monitor stands: same window, same carried profile, same counters.
    pub fn snapshot(&self) -> MonitorSnapshot {
        MonitorSnapshot {
            name: self.name.clone(),
            params: self.params.clone(),
            capacity: self.capacity,
            refresh_every: self.refresh_every,
            kernel: self.kernel,
            buf: self.buf.iter().copied().collect(),
            start: self.start,
            stats_mean: self.stats_mean.iter().copied().collect(),
            stats_std: self.stats_std.iter().copied().collect(),
            words: self.words.iter().cloned().collect(),
            nnd: self.nnd.iter().copied().collect(),
            ngh: self.ngh.iter().copied().collect(),
            warm: self.warm,
            pending: self.pending,
            refreshes: self.refreshes,
            total_calls: self.total_calls,
        }
    }

    /// Rebuild a monitor from a snapshot. Derived machinery (the SAX
    /// word builder and the scratch buffer) is reconstructed from the
    /// restored params; everything else is restored bit for bit, so the
    /// first post-restore [`refresh`](Self::refresh) is indistinguishable
    /// from one the original monitor would have run. The snapshot's
    /// cross-field invariants are re-validated here — a decoded-but-
    /// tampered snapshot never becomes a live monitor.
    pub fn from_snapshot(
        snap: MonitorSnapshot,
    ) -> Result<StreamingMonitor, SnapshotError> {
        snap.validate()?;
        let s = snap.params.sax.s;
        let wb = WordBuilder::new(&snap.params.sax);
        let mut buf = VecDeque::with_capacity(snap.capacity + 1);
        buf.extend(snap.buf);
        Ok(StreamingMonitor {
            name: snap.name,
            params: snap.params,
            capacity: snap.capacity,
            refresh_every: snap.refresh_every,
            kernel: snap.kernel,
            wb,
            buf,
            start: snap.start,
            stats_mean: snap.stats_mean.into(),
            stats_std: snap.stats_std.into(),
            words: snap.words.into(),
            nnd: snap.nnd.into(),
            ngh: snap.ngh.into(),
            scratch: Vec::with_capacity(s),
            warm: snap.warm,
            pending: snap.pending,
            refreshes: snap.refreshes,
            total_calls: snap.total_calls,
        })
    }

    /// The auto-refresh cadence in points (`0` = manual).
    pub fn refresh_cadence(&self) -> usize {
        self.refresh_every
    }

    /// Points currently in the window.
    pub fn window_len(&self) -> usize {
        self.buf.len()
    }

    /// Maximum points the window holds (the `capacity` passed to
    /// [`new`](Self::new)).
    pub fn window_capacity(&self) -> usize {
        self.capacity
    }

    /// Global position of the window's first point.
    pub fn window_start(&self) -> u64 {
        self.start
    }

    /// Total points appended so far (the global clock).
    pub fn consumed(&self) -> u64 {
        self.start + self.buf.len() as u64
    }

    /// Complete sequences in the current window.
    pub fn num_sequences(&self) -> usize {
        let s = self.params.sax.s;
        if self.buf.len() >= s {
            self.buf.len() - s + 1
        } else {
            0
        }
    }

    /// Points appended since the last refresh (0 right after a refresh —
    /// callers flushing a final refresh should skip it when nothing new
    /// arrived, or they re-search an unchanged window).
    pub fn pending_points(&self) -> usize {
        self.pending
    }

    /// Refreshes performed so far.
    pub fn refreshes(&self) -> u64 {
        self.refreshes
    }

    /// Cumulative distance calls across all refreshes (exact accounting).
    pub fn distance_calls(&self) -> u64 {
        self.total_calls
    }

    /// Whether the next refresh starts from a carried (shifted) profile.
    pub fn is_warm(&self) -> bool {
        self.warm
    }

    /// A copy of the current window as a [`TimeSeries`] (what a cold
    /// batch search over this window would run on).
    pub fn window_series(&self) -> TimeSeries {
        TimeSeries::new(
            format!(
                "{}[{}..{})",
                self.name,
                self.start,
                self.start + self.buf.len() as u64
            ),
            self.buf.iter().copied().collect(),
        )
    }

    /// Append one point. Returns the update when this point completed an
    /// auto-refresh batch (see [`with_refresh_every`](Self::with_refresh_every)).
    pub fn append(&mut self, x: f64) -> Result<Option<StreamUpdate>> {
        self.ingest(x);
        self.pending += 1;
        if self.refresh_every > 0
            && self.pending >= self.refresh_every
            && self.num_sequences() >= 2
        {
            return Ok(Some(self.refresh()?));
        }
        Ok(None)
    }

    /// Append a batch of points; returns the updates of any auto-refreshes
    /// they triggered, in order.
    pub fn extend(&mut self, points: &[f64]) -> Result<Vec<StreamUpdate>> {
        let mut out = Vec::new();
        for &x in points {
            if let Some(u) = self.append(x)? {
                out.push(u);
            }
        }
        Ok(out)
    }

    /// Append a batch of points packed as little-endian f64 bytes — the
    /// payload of one binary `data` frame (see `service::frame`) —
    /// decoding straight into the window with no intermediate `Vec<f64>`.
    /// Byte-for-byte the same ingest as [`extend`](Self::extend): the
    /// decoded bit patterns are the sender's exactly, so the refresh
    /// schedule and every update are bit-identical to the JSON path fed
    /// the same points. Rejects a length that is not a multiple of 8
    /// (a truncated or corrupt payload must never silently drop a
    /// partial point).
    pub fn extend_from_le_bytes(
        &mut self,
        bytes: &[u8],
    ) -> Result<Vec<StreamUpdate>> {
        ensure!(
            bytes.len() % 8 == 0,
            "binary payload length {} is not a multiple of 8 \
             (whole little-endian f64 points required)",
            bytes.len()
        );
        let mut out = Vec::new();
        for chunk in bytes.chunks_exact(8) {
            let x = f64::from_le_bytes(chunk.try_into().unwrap());
            if let Some(u) = self.append(x)? {
                out.push(u);
            }
        }
        Ok(out)
    }

    /// Per-point maintenance: O(s) for the one new sequence's stats and
    /// word, O(1) eviction at the trailing edge.
    fn ingest(&mut self, x: f64) {
        let s = self.params.sax.s;
        self.buf.push_back(x);
        if self.buf.len() >= s {
            // exactly one new complete sequence ends at the new point
            self.scratch.clear();
            self.scratch.extend(self.buf.range(self.buf.len() - s..));
            let (m, sd) = window_stats(&self.scratch);
            let w = self.wb.word(&self.scratch, m, sd);
            self.stats_mean.push_back(m);
            self.stats_std.push_back(sd);
            self.words.push_back(w);
            self.nnd.push_back(NND_INIT);
            self.ngh.push_back(NO_STREAM_NEIGHBOR);
        }
        if self.buf.len() > self.capacity {
            self.buf.pop_front();
            self.start += 1;
            self.stats_mean.pop_front();
            self.stats_std.pop_front();
            self.words.pop_front();
            self.nnd.pop_front();
            self.ngh.pop_front();
        }
        debug_assert_eq!(self.stats_mean.len(), self.num_sequences());
    }

    /// Search the current window, reusing everything the stream has
    /// already paid for: seeded stats/index and the shifted warm profile.
    /// The discord set is bit-identical to a cold serial `hst` run over
    /// [`window_series`](Self::window_series) (see the module docs).
    pub fn refresh(&mut self) -> Result<StreamUpdate> {
        let s = self.params.sax.s;
        let n = self.num_sequences();
        ensure!(
            n >= 2,
            "window holds {n} complete sequences; need >= 2 (s = {s}, \
             window_len = {})",
            self.buf.len()
        );
        let kind = self.params.distance_kind();
        let allow = self.params.allow_self_match;

        let ctx = SearchContext::builder_owned(self.window_series())
            .kernel(self.kernel)
            .build();
        ctx.seed_stats(Arc::new(SeqStats {
            s,
            mean: self.stats_mean.iter().copied().collect(),
            std: self.stats_std.iter().copied().collect(),
        }));
        ctx.seed_index(
            self.params.sax,
            Arc::new(SaxIndex::from_words(self.words.iter().cloned().collect())),
        );
        let was_warm = self.warm;
        if was_warm {
            // Shift the carried profile into window coordinates. Entries
            // whose neighbor was evicted are reset to the ∞ sentinel: the
            // recorded distance no longer bounds the nnd over the smaller
            // neighbor set. Every surviving entry is an exactly-evaluated
            // pair distance between two still-admissible sequences, so it
            // remains a valid upper bound.
            let mut p = NndProfile::new(n);
            for i in 0..n {
                let g = self.ngh[i];
                if g != NO_STREAM_NEIGHBOR && g >= self.start {
                    p.nnd[i] = self.nnd[i];
                    p.ngh[i] = (g - self.start) as usize;
                }
            }
            ctx.store_warm_profile(s, kind, allow, p);
        }

        let report = crate::algo::hst::HstSearch::default()
            .run_serial(&ctx, &self.params, ENGINE_ID, true)?;

        // Carry the refined profile forward in global coordinates.
        let refined = ctx
            .warm_profile(s, kind, allow)
            .expect("the search always stores its profile");
        for i in 0..n {
            self.nnd[i] = refined.nnd[i];
            self.ngh[i] = match refined.ngh[i] {
                NO_NEIGHBOR => NO_STREAM_NEIGHBOR,
                g => self.start + g as u64,
            };
        }
        self.warm = true;
        self.pending = 0;
        self.refreshes += 1;
        self.total_calls += report.distance_calls;

        Ok(StreamUpdate {
            refresh: self.refreshes,
            window_start: self.start,
            window_len: self.buf.len(),
            n_sequences: n,
            warm: was_warm,
            distance_calls: report.distance_calls,
            prep_calls: report.prep_calls,
            discords: report
                .discords
                .iter()
                .map(|d| StreamDiscord {
                    position: self.start + d.position as u64,
                    nnd: d.nnd,
                    neighbor: self.start + d.neighbor as u64,
                })
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;

    fn monitor(s: usize, capacity: usize) -> StreamingMonitor {
        StreamingMonitor::new(SearchParams::new(s, 4, 4).with_seed(3), capacity)
            .unwrap()
    }

    #[test]
    fn capacity_is_respected_and_clock_advances() {
        let mut m = monitor(32, 200);
        m.extend(&generators::sine_with_noise(550, 0.3, 11)).unwrap();
        assert_eq!(m.window_len(), 200);
        assert_eq!(m.window_start(), 350);
        assert_eq!(m.consumed(), 550);
        assert_eq!(m.num_sequences(), 200 - 32 + 1);
        assert_eq!(m.window_series().n_total(), 200);
    }

    #[test]
    fn incremental_state_matches_cold_preparation() {
        // stats and words maintained point-by-point must equal a cold
        // compute over the window, bit for bit
        let mut m = monitor(32, 300);
        m.extend(&generators::ecg_like(700, 60, 1, 12)).unwrap();
        let ts = m.window_series();
        let cold = SeqStats::compute(&ts, 32);
        assert_eq!(m.stats_mean.len(), cold.len());
        for k in 0..cold.len() {
            assert_eq!(m.stats_mean[k].to_bits(), cold.mean[k].to_bits(), "k={k}");
            assert_eq!(m.stats_std[k].to_bits(), cold.std[k].to_bits(), "k={k}");
        }
        let idx = SaxIndex::build(&ts, &cold, &m.params.sax);
        let inc: Vec<SaxWord> = m.words.iter().cloned().collect();
        assert_eq!(inc, idx.words);
    }

    #[test]
    fn le_bytes_ingest_is_bit_identical_to_extend() {
        // the binary-frame path decodes the sender's exact bit
        // patterns, so updates must match extend() bitwise — including
        // awkward values JSON text would round-trip through Display
        let mut pts = generators::sine_with_noise(500, 0.3, 15);
        pts[7] = -0.0;
        pts[19] = f64::MIN_POSITIVE;
        pts[23] = 1e300;
        let bytes: Vec<u8> = pts.iter().flat_map(|x| x.to_le_bytes()).collect();

        let mut via_text = monitor(32, 300).with_refresh_every(120);
        let a = via_text.extend(&pts).unwrap();
        let mut via_bytes = monitor(32, 300).with_refresh_every(120);
        let b = via_bytes.extend_from_le_bytes(&bytes).unwrap();

        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        for (ua, ub) in a.iter().zip(&b) {
            assert_eq!(ua.to_json(), ub.to_json());
        }
        assert_eq!(
            via_text.window_series().points,
            via_bytes.window_series().points
        );

        // partial points are an error, never a silent truncation
        assert!(via_bytes.extend_from_le_bytes(&bytes[..12]).is_err());
    }

    #[test]
    fn refresh_requires_two_sequences() {
        let mut m = monitor(64, 200);
        m.extend(&generators::sine_with_noise(64, 0.1, 13)).unwrap();
        assert_eq!(m.num_sequences(), 1);
        assert!(m.refresh().is_err());
        m.extend(&generators::sine_with_noise(100, 0.1, 14)).unwrap();
        assert!(m.refresh().is_ok());
    }

    #[test]
    fn auto_refresh_cadence_fires() {
        let mut m = monitor(32, 400).with_refresh_every(150);
        let updates = m
            .extend(&generators::sine_with_noise(460, 0.3, 15))
            .unwrap();
        // 150-point batches: the first fires at 150 points (n >= 2 holds
        // from 33 points on), then 300, then 450
        assert_eq!(updates.len(), 3);
        assert_eq!(updates[0].refresh, 1);
        assert!(!updates[0].warm);
        assert!(updates[1].warm && updates[2].warm);
        assert_eq!(m.refreshes(), 3);
        assert!(m.distance_calls() > 0);
    }

    #[test]
    fn warm_refresh_is_cheaper_and_prep_free() {
        let mut m = monitor(64, 1_200);
        m.extend(&generators::ecg_like(1_200, 90, 1, 16)).unwrap();
        let cold = m.refresh().unwrap();
        assert!(!cold.warm);
        assert!(cold.prep_calls > 0);
        m.extend(&generators::ecg_like(120, 90, 0, 17)).unwrap();
        let warm = m.refresh().unwrap();
        assert!(warm.warm);
        assert_eq!(warm.prep_calls, 0, "shifted profile must serve prep");
        assert!(
            warm.distance_calls < cold.distance_calls,
            "warm {} !< cold {}",
            warm.distance_calls,
            cold.distance_calls
        );
    }

    #[test]
    fn discords_are_reported_in_global_coordinates() {
        let s = 48;
        let mut m = monitor(s, 800);
        let mut pts = generators::sine_with_noise(2_000, 0.05, 18);
        let mut rng = crate::util::rng::Rng64::new(5);
        generators::inject(&mut pts, 1_600, s, generators::Anomaly::Bump, &mut rng);
        m.extend(&pts).unwrap();
        let u = m.refresh().unwrap();
        assert_eq!(u.window_start, 1_200);
        let top = &u.discords[0];
        assert!(top.position >= u.window_start);
        assert!(top.position < u.window_start + u.window_len as u64);
        assert!(
            top.position.abs_diff(1_600) <= 2 * s as u64,
            "discord at {} should sit near the injected bump at 1600",
            top.position
        );
        assert!(top.position.abs_diff(top.neighbor) >= s as u64);
        let j = u.to_json().to_string();
        assert!(j.contains("window_start"), "{j}");
    }

    #[test]
    fn rejects_window_smaller_than_two_sequences() {
        assert!(StreamingMonitor::new(SearchParams::new(64, 4, 4), 100).is_err());
        assert!(StreamingMonitor::new(SearchParams::new(64, 4, 4), 128).is_ok());
    }

    #[test]
    fn snapshot_restore_continues_bit_identically() {
        // one monitor runs uninterrupted; a twin is snapshotted mid-stream,
        // dropped, and rebuilt from the snapshot. Feeding both the same
        // tail must produce bit-identical refreshes, with the restored
        // monitor's warm profile sparing it all prep work.
        let pts = generators::ecg_like(1_400, 80, 1, 21);
        let (head, tail) = pts.split_at(900);

        let mut straight = monitor(48, 600).with_name("wal");
        straight.extend(head).unwrap();
        straight.refresh().unwrap();

        let mut doomed = monitor(48, 600).with_name("wal");
        doomed.extend(head).unwrap();
        doomed.refresh().unwrap();
        let snap = doomed.snapshot();
        drop(doomed);

        let mut revived = StreamingMonitor::from_snapshot(snap).unwrap();
        assert_eq!(revived.name(), "wal");
        assert_eq!(revived.window_start(), straight.window_start());
        assert_eq!(revived.consumed(), straight.consumed());
        assert!(revived.is_warm());
        assert_eq!(revived.refreshes(), straight.refreshes());

        straight.extend(tail).unwrap();
        revived.extend(tail).unwrap();
        let a = straight.refresh().unwrap();
        let b = revived.refresh().unwrap();
        assert!(b.warm);
        assert_eq!(b.prep_calls, 0, "restored warm state must serve prep");
        assert_eq!(a.distance_calls, b.distance_calls);
        assert_eq!(a.discords.len(), b.discords.len());
        for (da, db) in a.discords.iter().zip(&b.discords) {
            assert_eq!(da.position, db.position);
            assert_eq!(da.neighbor, db.neighbor);
            assert_eq!(da.nnd.to_bits(), db.nnd.to_bits());
        }
    }

    #[test]
    fn tampered_snapshot_is_refused() {
        let mut m = monitor(32, 200);
        m.extend(&generators::sine_with_noise(400, 0.3, 22)).unwrap();
        m.refresh().unwrap();
        let mut snap = m.snapshot();
        snap.nnd.pop(); // desync the per-sequence vectors
        let err = StreamingMonitor::from_snapshot(snap).unwrap_err();
        assert!(err.to_string().contains("`nnd`"), "{err}");
    }
}
