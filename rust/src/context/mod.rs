//! The search session layer: prepared state shared across engines and
//! queries.
//!
//! HST's entire speed story (paper Sec. 3) is *reuse* — the warm-up
//! profile, the SAX clusters, and the evolving nnd state persist across
//! the k-discord loop. A [`SearchContext`] extends that reuse across
//! *searches*: it is built once per series and owns everything that does
//! not depend on an individual query:
//!
//! * the rolling z-norm [`SeqStats`], cached per sequence length `s`;
//! * the [`SaxIndex`], cached per [`SaxParams`];
//! * warm [`NndProfile`]s left behind by profile-producing engines
//!   (HST, brute force, SCAMP, preSCRIMP), keyed by
//!   `(s, DistanceKind, allow_self_match)` — every entry is a valid
//!   upper bound of the exact nnd, so any later search may start from it;
//! * the distance backend choice ([`Backend`]): the scalar
//!   [`CountingDistance`] by default, the `pjrt`-gated XLA pair engine
//!   behind the same [`Distance`] trait on request;
//! * cross-cutting run controls: a [`CancellationToken`], an optional
//!   distance-call budget, a [`SearchObserver`] progress hook, and an
//!   optional span-shaped [`TraceSink`](crate::obs::TraceSink) that
//!   receives the full search → phase → pass event stream.
//!
//! Engines consume a context through
//! [`Algorithm::run_ctx`](crate::algo::Algorithm::run_ctx); the classic
//! [`Algorithm::run`](crate::algo::Algorithm::run) is a convenience
//! wrapper that builds a throwaway context. The service
//! [`Coordinator`](crate::service::Coordinator) keeps an LRU of contexts
//! so repeated jobs on the same dataset skip preparation entirely — the
//! same "precompute once, query many times" split SCAMP (Zimmerman et
//! al. 2019) and MERLIN (Nakamura et al. 2020) build their serving
//! stories on.
//!
//! ```
//! use hstime::prelude::*;
//!
//! let ts = generators::sine_with_noise(2_000, 0.1, 7).into_series("demo");
//! let ctx = SearchContext::builder(&ts).build();
//! let params = SearchParams::new(64, 4, 4);
//! let cold = algo::hst::HstSearch::default().run_ctx(&ctx, &params).unwrap();
//! let warm = algo::hst::HstSearch::default().run_ctx(&ctx, &params).unwrap();
//! assert!(cold.prep_calls > 0);
//! assert_eq!(warm.prep_calls, 0); // preparation served from the context
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::config::SaxParams;
use crate::discord::{Discord, NndProfile};
use crate::dist::{Backend, CountingDistance, Distance, DistanceKind, Kernel};
use crate::obs::{PassEvent, TraceSink};
use crate::sax::SaxIndex;
use crate::ts::{SeqStats, TimeSeries};

/// A cooperative cancellation flag shared between a [`SearchContext`] and
/// whoever may want to abort its searches (another thread, a deadline
/// watchdog, a service shutdown path). Cloning shares the flag.
#[derive(Debug, Clone, Default)]
pub struct CancellationToken {
    flag: Arc<AtomicBool>,
}

impl CancellationToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> CancellationToken {
        CancellationToken::default()
    }

    /// Request cancellation: every search on a context holding this token
    /// stops at its next checkpoint with an error.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    /// Has [`cancel`](Self::cancel) been called?
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

/// Progress hooks a [`SearchContext`] fans engine events out to.
///
/// All methods have no-op defaults; implement only what you need. Hooks
/// are called synchronously from the search thread, so they should be
/// cheap (push to a channel, bump a metric).
pub trait SearchObserver: Send + Sync {
    /// A search entered a named phase (`"prepare"`, `"search"`).
    fn on_phase(&self, _engine: &str, _phase: &str) {}

    /// A discord was confirmed (`rank` is 0-based).
    fn on_discord(&self, _rank: usize, _discord: &Discord) {}
}

/// The run-control checkpoint rule, shared by [`SearchContext::check`]
/// and the multivariate [`MdimContext`](crate::mdim::MdimContext)'s
/// checkpoints — one definition of "cancelled or over budget" so the two
/// session layers can never drift apart.
pub(crate) fn check_run_controls(
    cancel: &CancellationToken,
    budget: Option<u64>,
    distance_calls: u64,
) -> Result<()> {
    ensure!(!cancel.is_cancelled(), "search cancelled");
    if let Some(budget) = budget {
        ensure!(
            distance_calls <= budget,
            "distance-call budget exceeded: {distance_calls} calls > budget {budget}"
        );
    }
    Ok(())
}

/// Key of the warm-profile cache: profiles depend on the sequence length
/// and the distance protocol, not on the SAX discretization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ProfileKey {
    s: usize,
    kind: DistanceKind,
    allow_self_match: bool,
}

/// Builder for [`SearchContext`] (see [`SearchContext::builder`]).
pub struct ContextBuilder {
    ts: TimeSeries,
    backend: Backend,
    kernel: Kernel,
    cancel: CancellationToken,
    budget: Option<u64>,
    observer: Option<Arc<dyn SearchObserver>>,
    sink: Option<Arc<dyn TraceSink>>,
    prepare: Vec<SaxParams>,
}

impl ContextBuilder {
    /// Select the distance backend (default: [`Backend::Scalar`]). With
    /// [`Backend::XlaPjrt`] the context tries the XLA pair engine per
    /// session and silently falls back to the scalar backend when the
    /// `pjrt` feature is off or no artifacts are available.
    pub fn backend(mut self, backend: Backend) -> ContextBuilder {
        self.backend = backend;
        self
    }

    /// Pin the scalar-backend inner-loop [`Kernel`] (default:
    /// [`Kernel::active`], i.e. SIMD unless `HST_KERNEL=scalar`). The
    /// kernels are bit-identical, so this is a throughput knob only; the
    /// choice propagates to every session the context hands out —
    /// including parallel workers and multivariate channels.
    pub fn kernel(mut self, kernel: Kernel) -> ContextBuilder {
        self.kernel = kernel;
        self
    }

    /// Attach a cancellation token (clone it to keep a handle for
    /// cancelling from elsewhere).
    pub fn cancel_token(mut self, token: CancellationToken) -> ContextBuilder {
        self.cancel = token;
        self
    }

    /// Cap the distance calls any single search through this context may
    /// spend. The cap is enforced at the engines' outer-loop checkpoints,
    /// so a search may overshoot by up to one inner loop — and bounded
    /// preparation phases (HST's ~2N-call warm-up, one MERLIN length) run
    /// to completion before their next checkpoint — before erroring.
    pub fn distance_budget(mut self, max_calls: u64) -> ContextBuilder {
        self.budget = Some(max_calls);
        self
    }

    /// Attach a progress observer.
    pub fn observer(mut self, observer: Arc<dyn SearchObserver>) -> ContextBuilder {
        self.observer = Some(observer);
        self
    }

    /// Attach a span-shaped [`TraceSink`]. The sink receives the full
    /// search → phase → pass event stream (see
    /// [`obs::trace`](crate::obs::trace)); it only *reads* values the
    /// engines already maintain, so attaching one never changes results
    /// or call counts.
    pub fn trace_sink(mut self, sink: Arc<dyn TraceSink>) -> ContextBuilder {
        self.sink = Some(sink);
        self
    }

    /// Eagerly prepare stats + SAX index for `sax` at build time (useful
    /// when the context is built off the request path). Silently skipped
    /// when the series is shorter than `sax.s`.
    pub fn prepare(mut self, sax: SaxParams) -> ContextBuilder {
        self.prepare.push(sax);
        self
    }

    /// Finish the builder.
    pub fn build(self) -> SearchContext {
        let ctx = SearchContext {
            ts: self.ts,
            backend: self.backend,
            kernel: self.kernel,
            cancel: self.cancel,
            budget: self.budget,
            observer: self.observer,
            sink: self.sink,
            stats_cache: Mutex::new(HashMap::new()),
            index_cache: Mutex::new(HashMap::new()),
            profile_cache: Mutex::new(HashMap::new()),
            #[cfg(feature = "pjrt")]
            xla_unavailable: AtomicBool::new(false),
        };
        for sax in &self.prepare {
            if ctx.ts.num_sequences(sax.s) > 0 {
                let _ = ctx.prepared(sax);
            }
        }
        ctx
    }
}

/// Prepared per-series search state: the session every engine runs
/// through (see the [module docs](self)).
///
/// A context is `Send + Sync`; share it behind an `Arc` across worker
/// threads. All caches use interior mutability, so `&SearchContext` is
/// all an engine needs.
pub struct SearchContext {
    ts: TimeSeries,
    backend: Backend,
    kernel: Kernel,
    cancel: CancellationToken,
    budget: Option<u64>,
    observer: Option<Arc<dyn SearchObserver>>,
    sink: Option<Arc<dyn TraceSink>>,
    stats_cache: Mutex<HashMap<usize, Arc<SeqStats>>>,
    index_cache: Mutex<HashMap<SaxParams, Arc<SaxIndex>>>,
    profile_cache: Mutex<HashMap<ProfileKey, NndProfile>>,
    /// Once an XLA session fails to construct, stop probing the
    /// filesystem for artifacts on every later search.
    #[cfg(feature = "pjrt")]
    xla_unavailable: AtomicBool,
}

impl SearchContext {
    /// Start building a context over a copy of `ts`.
    pub fn builder(ts: &TimeSeries) -> ContextBuilder {
        SearchContext::builder_owned(ts.clone())
    }

    /// Start building a context that takes ownership of `ts` (avoids the
    /// copy when the caller materialized the series for this context).
    pub fn builder_owned(ts: TimeSeries) -> ContextBuilder {
        ContextBuilder {
            ts,
            backend: Backend::Scalar,
            kernel: Kernel::active(),
            cancel: CancellationToken::new(),
            budget: None,
            observer: None,
            sink: None,
            prepare: Vec::new(),
        }
    }

    /// The series this context prepares.
    pub fn series(&self) -> &TimeSeries {
        &self.ts
    }

    /// The distance backend this context selects.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The inner-loop [`Kernel`] sessions from this context run on.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The per-search distance-call budget, if any.
    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// A handle on the context's cancellation token.
    pub fn cancel_token(&self) -> CancellationToken {
        self.cancel.clone()
    }

    /// Rolling stats for sequence length `s`, computed once and cached.
    ///
    /// Panics when the series is shorter than `s` (engines guard with
    /// their `n >= 2` precondition before preparing).
    pub fn stats(&self, s: usize) -> Arc<SeqStats> {
        let mut cache = self.stats_cache.lock().unwrap();
        Arc::clone(
            cache
                .entry(s)
                .or_insert_with(|| Arc::new(SeqStats::compute(&self.ts, s))),
        )
    }

    /// SAX index for `sax`, computed once and cached.
    pub fn index(&self, sax: &SaxParams) -> Arc<SaxIndex> {
        let stats = self.stats(sax.s);
        let mut cache = self.index_cache.lock().unwrap();
        Arc::clone(
            cache
                .entry(*sax)
                .or_insert_with(|| Arc::new(SaxIndex::build(&self.ts, &stats, sax))),
        )
    }

    /// Stats and index for `sax` in one call (the common engine preamble).
    pub fn prepared(&self, sax: &SaxParams) -> (Arc<SeqStats>, Arc<SaxIndex>) {
        (self.stats(sax.s), self.index(sax))
    }

    /// Seed the stats cache with externally maintained rolling stats.
    ///
    /// Contract: `stats` must equal what [`SeqStats::compute`] over this
    /// context's series would produce for `stats.s`. The
    /// [`stream`](crate::stream) monitor satisfies it by construction —
    /// per-window stats are a pure function of the window
    /// ([`ts::window_stats`](crate::ts::window_stats)), so incrementally
    /// extended entries are bit-identical to a cold recompute. An existing
    /// cached entry for the same `s` is kept (it is the same data).
    pub fn seed_stats(&self, stats: Arc<SeqStats>) {
        self.stats_cache
            .lock()
            .unwrap()
            .entry(stats.s)
            .or_insert(stats);
    }

    /// Seed the index cache with an externally assembled SAX index.
    ///
    /// Contract: `index` must equal what [`SaxIndex::build`] over this
    /// context's series would produce for `sax` — guaranteed when it is
    /// materialized via [`SaxIndex::from_words`] from words produced by
    /// the shared [`WordBuilder`](crate::sax::WordBuilder) kernel. An
    /// existing cached entry for the same `sax` is kept.
    pub fn seed_index(&self, sax: SaxParams, index: Arc<SaxIndex>) {
        self.index_cache.lock().unwrap().entry(sax).or_insert(index);
    }

    /// Is the SAX index for `sax` already cached? (Diagnostics / tests.)
    pub fn is_prepared(&self, sax: &SaxParams) -> bool {
        self.index_cache.lock().unwrap().contains_key(sax)
    }

    /// A distance session over this context's series for one search.
    ///
    /// Each session carries its own call counter, so per-search
    /// accounting stays exact even when many searches share the context.
    /// The backend is chosen per the builder: scalar by default; with
    /// [`Backend::XlaPjrt`] under the `pjrt` feature, the XLA pair engine
    /// when artifacts load, the scalar fallback otherwise.
    pub fn distance<'a>(
        &'a self,
        stats: &'a SeqStats,
        kind: DistanceKind,
    ) -> Box<dyn Distance + 'a> {
        #[cfg(feature = "pjrt")]
        if self.backend == Backend::XlaPjrt
            && !self.xla_unavailable.load(Ordering::Relaxed)
        {
            match crate::dist::xla_engine::XlaPairDistance::try_new(
                &self.ts, stats, kind,
            ) {
                Ok(engine) => return Box::new(engine),
                Err(_) => self.xla_unavailable.store(true, Ordering::Relaxed),
            }
        }
        Box::new(CountingDistance::with_kernel(
            &self.ts,
            stats,
            kind,
            self.kernel,
        ))
    }

    /// Run-control checkpoint: engines call this once per outer-loop
    /// candidate with their session's current call count. Errors when the
    /// context was cancelled or the distance-call budget is exhausted.
    pub fn check(&self, distance_calls: u64) -> Result<()> {
        check_run_controls(&self.cancel, self.budget, distance_calls)
    }

    /// A warm nnd profile for `(s, kind, allow_self_match)`, if an earlier
    /// search left one behind. Every entry is a valid upper bound of the
    /// exact nnd, so engines may start minimizing from it directly.
    pub fn warm_profile(
        &self,
        s: usize,
        kind: DistanceKind,
        allow_self_match: bool,
    ) -> Option<NndProfile> {
        let key = ProfileKey { s, kind, allow_self_match };
        self.profile_cache.lock().unwrap().get(&key).cloned()
    }

    /// Store a profile for later searches. Callers must only store
    /// profiles whose entries upper-bound the exact nnds (every profile
    /// the engines maintain does, by construction). When an entry already
    /// exists for the key, the profiles are merged by pointwise minimum,
    /// so a looser profile can never displace a tighter one.
    pub fn store_warm_profile(
        &self,
        s: usize,
        kind: DistanceKind,
        allow_self_match: bool,
        profile: NndProfile,
    ) {
        let key = ProfileKey { s, kind, allow_self_match };
        let mut cache = self.profile_cache.lock().unwrap();
        match cache.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut entry) => {
                entry.get_mut().absorb(profile);
            }
            std::collections::hash_map::Entry::Vacant(slot) => {
                slot.insert(profile);
            }
        }
    }

    /// Enumerate the warm-profile cache: every
    /// `(s, kind, allow_self_match)` entry with a clone of its profile,
    /// sorted by key so the order is deterministic. This is the snapshot
    /// layer's export seam — the cache key type stays private, the warm
    /// state does not.
    pub fn warm_profiles(&self) -> Vec<(usize, DistanceKind, bool, NndProfile)> {
        let cache = self.profile_cache.lock().unwrap();
        let mut out: Vec<(usize, DistanceKind, bool, NndProfile)> = cache
            .iter()
            .map(|(k, p)| (k.s, k.kind, k.allow_self_match, p.clone()))
            .collect();
        out.sort_by_key(|(s, kind, allow, _)| {
            (*s, matches!(kind, DistanceKind::Raw), *allow)
        });
        out
    }

    /// Notify the observer and trace sink (if any) of a phase change.
    pub fn notify_phase(&self, engine: &str, phase: &str) {
        if let Some(obs) = &self.observer {
            obs.on_phase(engine, phase);
        }
        if let Some(sink) = &self.sink {
            sink.on_phase(engine, phase);
        }
    }

    /// Notify the observer and trace sink (if any) of a confirmed discord.
    pub fn notify_discord(&self, rank: usize, discord: &Discord) {
        if let Some(obs) = &self.observer {
            obs.on_discord(rank, discord);
        }
        if let Some(sink) = &self.sink {
            sink.on_discord(rank, discord);
        }
    }

    /// Is a trace sink attached? Engines may use this to skip assembling
    /// pass events entirely on untraced runs.
    pub fn has_trace(&self) -> bool {
        self.sink.is_some()
    }

    /// Open a search span on the trace sink (if any). Emitted by the
    /// provided [`Algorithm::run_ctx`](crate::algo::Algorithm::run_ctx)
    /// wrapper, not by engines.
    pub fn trace_search_start(&self, engine: &str, n: usize, s: usize, k: usize) {
        if let Some(sink) = &self.sink {
            sink.on_search_start(engine, n, s, k);
        }
    }

    /// Report a completed pass to the trace sink (if any). `pass.calls`
    /// is a *delta* — per span, the deltas must sum to the report's
    /// `distance_calls` (checked by
    /// [`validate_trace`](crate::obs::validate_trace)).
    pub fn trace_pass(&self, pass: &PassEvent<'_>) {
        if let Some(sink) = &self.sink {
            sink.on_pass(pass);
        }
    }

    /// Close a search span on the trace sink (if any) with the final
    /// call accounting.
    pub fn trace_search_end(&self, engine: &str, distance_calls: u64, prep_calls: u64) {
        if let Some(sink) = &self.sink {
            sink.on_search_end(engine, distance_calls, prep_calls);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ts::generators;
    use crate::ts::series::IntoSeries;

    fn series() -> TimeSeries {
        generators::sine_with_noise(1_000, 0.2, 11).into_series("ctx")
    }

    #[test]
    fn stats_and_index_are_cached_by_key() {
        let ts = series();
        let ctx = SearchContext::builder(&ts).build();
        let sax = SaxParams::new(64, 4, 4);
        let (s1, i1) = ctx.prepared(&sax);
        let (s2, i2) = ctx.prepared(&sax);
        assert!(Arc::ptr_eq(&s1, &s2), "stats must be computed once");
        assert!(Arc::ptr_eq(&i1, &i2), "index must be computed once");
        // a different s gets its own stats
        let s3 = ctx.stats(32);
        assert!(!Arc::ptr_eq(&s1, &s3));
        assert!(ctx.is_prepared(&sax));
        assert!(!ctx.is_prepared(&SaxParams::new(32, 4, 4)));
    }

    #[test]
    fn eager_prepare_warms_the_index() {
        let ts = series();
        let sax = SaxParams::new(50, 5, 4);
        let ctx = SearchContext::builder(&ts).prepare(sax).build();
        assert!(ctx.is_prepared(&sax));
        // too-long s is skipped, not a panic
        let long = SaxParams::new(4_000, 4, 4);
        let ctx = SearchContext::builder(&ts).prepare(long).build();
        assert!(!ctx.is_prepared(&long));
    }

    #[test]
    fn seeding_populates_the_caches_without_recompute() {
        let ts = series();
        let ctx = SearchContext::builder(&ts).build();
        let sax = SaxParams::new(64, 4, 4);
        let stats = Arc::new(SeqStats::compute(&ts, 64));
        let idx = Arc::new(SaxIndex::build(&ts, &stats, &sax));
        ctx.seed_stats(Arc::clone(&stats));
        ctx.seed_index(sax, Arc::clone(&idx));
        assert!(ctx.is_prepared(&sax));
        let (s2, i2) = ctx.prepared(&sax);
        assert!(Arc::ptr_eq(&stats, &s2), "seeded stats must be served");
        assert!(Arc::ptr_eq(&idx, &i2), "seeded index must be served");
        // seeding on top of an existing entry keeps the first one
        let other = Arc::new(SeqStats::compute(&ts, 64));
        ctx.seed_stats(Arc::clone(&other));
        assert!(Arc::ptr_eq(&ctx.stats(64), &stats));
    }

    #[test]
    fn distance_sessions_have_independent_counters() {
        let ts = series();
        let ctx = SearchContext::builder(&ts).build();
        let stats = ctx.stats(64);
        let a = ctx.distance(&stats, DistanceKind::Znorm);
        let b = ctx.distance(&stats, DistanceKind::Znorm);
        let _ = a.dist(0, 500);
        let _ = a.dist(1, 501);
        assert_eq!(a.calls(), 2);
        assert_eq!(b.calls(), 0, "sessions must not share counters");
    }

    #[test]
    fn kernel_choice_is_carried_and_bit_neutral() {
        let ts = series();
        let sc = SearchContext::builder(&ts).kernel(Kernel::Scalar).build();
        let si = SearchContext::builder(&ts).kernel(Kernel::Simd).build();
        assert_eq!(sc.kernel(), Kernel::Scalar);
        assert_eq!(si.kernel(), Kernel::Simd);
        let stats_sc = sc.stats(64);
        let stats_si = si.stats(64);
        let a = sc.distance(&stats_sc, DistanceKind::Znorm);
        let b = si.distance(&stats_si, DistanceKind::Znorm);
        for (i, j) in [(0usize, 500), (7, 321), (100, 800)] {
            assert_eq!(
                a.dist(i, j).to_bits(),
                b.dist(i, j).to_bits(),
                "kernels must be bit-identical through the context seam"
            );
        }
    }

    #[test]
    fn check_enforces_cancellation_and_budget() {
        let ts = series();
        let token = CancellationToken::new();
        let ctx = SearchContext::builder(&ts)
            .cancel_token(token.clone())
            .distance_budget(100)
            .build();
        assert!(ctx.check(0).is_ok());
        assert!(ctx.check(100).is_ok(), "budget is inclusive");
        assert!(ctx.check(101).is_err(), "over budget");
        token.cancel();
        let err = ctx.check(0).unwrap_err().to_string();
        assert!(err.contains("cancelled"), "{err}");
    }

    #[test]
    fn warm_profiles_are_keyed_by_protocol() {
        let ts = series();
        let ctx = SearchContext::builder(&ts).build();
        let n = ts.num_sequences(64);
        let mut p = NndProfile::new(n);
        p.observe(0, 200, 1.5);
        ctx.store_warm_profile(64, DistanceKind::Znorm, false, p);
        let got = ctx.warm_profile(64, DistanceKind::Znorm, false).unwrap();
        assert_eq!(got.nnd[0], 1.5);
        assert!(ctx.warm_profile(64, DistanceKind::Raw, false).is_none());
        assert!(ctx.warm_profile(64, DistanceKind::Znorm, true).is_none());
        assert!(ctx.warm_profile(32, DistanceKind::Znorm, false).is_none());
    }

    #[test]
    fn storing_a_looser_profile_keeps_the_tighter_entries() {
        let ts = series();
        let ctx = SearchContext::builder(&ts).build();
        let n = ts.num_sequences(64);
        let mut tight = NndProfile::new(n);
        tight.observe(0, 200, 1.0);
        tight.observe(1, 300, 2.0);
        ctx.store_warm_profile(64, DistanceKind::Znorm, false, tight);
        // a later, mostly-unset profile must not displace the tight bounds
        let mut loose = NndProfile::new(n);
        loose.observe(0, 400, 5.0);
        loose.observe(2, 500, 0.5);
        ctx.store_warm_profile(64, DistanceKind::Znorm, false, loose);
        let got = ctx.warm_profile(64, DistanceKind::Znorm, false).unwrap();
        assert_eq!(got.nnd[0], 1.0, "tighter bound survives");
        assert_eq!(got.nnd[1], 2.0);
        assert_eq!(got.nnd[2], 0.5, "new information is merged in");
    }

    #[test]
    fn warm_profiles_enumerates_every_entry_in_key_order() {
        let ts = series();
        let ctx = SearchContext::builder(&ts).build();
        assert!(ctx.warm_profiles().is_empty());
        let n64 = ts.num_sequences(64);
        let n32 = ts.num_sequences(32);
        ctx.store_warm_profile(64, DistanceKind::Znorm, false, NndProfile::new(n64));
        ctx.store_warm_profile(32, DistanceKind::Raw, true, NndProfile::new(n32));
        ctx.store_warm_profile(32, DistanceKind::Znorm, false, NndProfile::new(n32));
        let all = ctx.warm_profiles();
        let keys: Vec<(usize, DistanceKind, bool)> =
            all.iter().map(|(s, k, a, _)| (*s, *k, *a)).collect();
        assert_eq!(
            keys,
            vec![
                (32, DistanceKind::Znorm, false),
                (32, DistanceKind::Raw, true),
                (64, DistanceKind::Znorm, false),
            ],
            "enumeration must be deterministic and complete"
        );
        assert_eq!(all[2].3.len(), n64);
    }

    #[test]
    fn context_is_shareable_across_threads() {
        let ts = series();
        let ctx = Arc::new(SearchContext::builder(&ts).build());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let ctx = Arc::clone(&ctx);
            handles.push(std::thread::spawn(move || {
                let stats = ctx.stats(64);
                let dist = ctx.distance(&stats, DistanceKind::Znorm);
                dist.dist(t as usize, 500 + t as usize)
            }));
        }
        for h in handles {
            assert!(h.join().unwrap().is_finite());
        }
    }
}
