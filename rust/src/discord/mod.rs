//! Discord definitions: results, exclusion zones, and the nnd profile.
//!
//! A *discord* is the sequence with the highest nearest-neighbor distance
//! (nnd) under the non-self-match condition |i − j| >= s; the k-th discord
//! additionally must not overlap any of the previous k−1 (paper Sec. 2.2).

pub mod significance;

use crate::util::json::Json;

/// One discovered discord.
#[derive(Debug, Clone, PartialEq)]
pub struct Discord {
    /// Start position of the sequence.
    pub position: usize,
    /// Its exact nearest-neighbor distance.
    pub nnd: f64,
    /// Position of its nearest neighbor.
    pub neighbor: usize,
}

impl Discord {
    /// Serialize for reports and the service protocol.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("position", self.position)
            .set("nnd", self.nnd)
            .set("neighbor", self.neighbor)
    }
}

/// An ordered set of discords (1st, 2nd, … k-th).
pub type DiscordSet = Vec<Discord>;

/// Tracks the exclusion zones created by already-found discords: a
/// candidate for the k-th discord may not overlap any previous discord.
#[derive(Debug, Clone, Default)]
pub struct ExclusionZones {
    /// (start, s) of each found discord.
    zones: Vec<(usize, usize)>,
}

impl ExclusionZones {
    /// No zones yet (before the first discord is found).
    pub fn new() -> ExclusionZones {
        ExclusionZones { zones: Vec::new() }
    }

    /// Exclude the sequence of length `s` starting at `position`.
    pub fn add(&mut self, position: usize, s: usize) {
        self.zones.push((position, s));
    }

    /// May sequence `i` (length `s`) still become a discord?
    /// Overlap means |i − z| < s (sequences share at least one point).
    #[inline]
    pub fn allowed(&self, i: usize, s: usize) -> bool {
        self.zones.iter().all(|&(z, zs)| {
            let sep = if i >= z { i - z } else { z - i };
            sep >= s.max(zs)
        })
    }

    /// Number of recorded zones.
    pub fn len(&self) -> usize {
        self.zones.len()
    }

    /// Whether no zone has been recorded.
    pub fn is_empty(&self) -> bool {
        self.zones.is_empty()
    }
}

/// The evolving approximate nnd profile HST maintains: for each sequence,
/// the best-so-far (smallest) distance seen and the neighbor achieving it.
/// Values are *upper bounds* of the exact nnds by construction.
#[derive(Debug, Clone)]
pub struct NndProfile {
    /// Approximate nnd per sequence (init: +inf-like sentinel).
    pub nnd: Vec<f64>,
    /// Neighbor achieving `nnd` (usize::MAX = none yet).
    pub ngh: Vec<usize>,
}

/// Initialization sentinel ("99999999.9" in the paper's Listing 2).
pub const NND_INIT: f64 = f64::INFINITY;

/// "no neighbor yet" marker.
pub const NO_NEIGHBOR: usize = usize::MAX;

impl NndProfile {
    /// Fresh profile: every entry at the ∞ sentinel, no neighbors.
    pub fn new(n: usize) -> NndProfile {
        NndProfile {
            nnd: vec![NND_INIT; n],
            ngh: vec![NO_NEIGHBOR; n],
        }
    }

    /// Number of sequences covered.
    pub fn len(&self) -> usize {
        self.nnd.len()
    }

    /// Whether the profile covers no sequences.
    pub fn is_empty(&self) -> bool {
        self.nnd.is_empty()
    }

    /// Record an observed distance d(i, j), updating both endpoints
    /// (every distance call upper-bounds *two* nnds — Sec. 3.2).
    #[inline]
    pub fn observe(&mut self, i: usize, j: usize, d: f64) {
        if d < self.nnd[i] {
            self.nnd[i] = d;
            self.ngh[i] = j;
        }
        if d < self.nnd[j] {
            self.nnd[j] = d;
            self.ngh[j] = i;
        }
    }

    /// Record for `i` only (when d may be an abandoned upper bound for the
    /// pair but is still a valid bound for i's minimization target — not
    /// used for j whose bound quality is unknown).
    #[inline]
    pub fn observe_one(&mut self, i: usize, j: usize, d: f64) {
        if d < self.nnd[i] {
            self.nnd[i] = d;
            self.ngh[i] = j;
        }
    }

    /// Merge `other` into `self` by pointwise minimum, keeping the
    /// neighbor that achieves each minimum. The min of two valid
    /// upper-bound profiles is itself a valid upper-bound profile, so
    /// merging never loses tightness (used by the parallel workers and
    /// the [`SearchContext`](crate::context::SearchContext) warm-profile
    /// cache).
    pub fn merge_min(&mut self, other: &NndProfile) {
        debug_assert_eq!(self.len(), other.len());
        for i in 0..self.nnd.len().min(other.nnd.len()) {
            if other.nnd[i] < self.nnd[i] {
                self.nnd[i] = other.nnd[i];
                self.ngh[i] = other.ngh[i];
            }
        }
    }

    /// Cache-merge rule shared by the warm-profile stores (the
    /// univariate [`SearchContext`] and the multivariate `MdimContext`):
    /// pointwise-min merge when the lengths match (a looser profile can
    /// never displace a tighter one), replacement otherwise. One
    /// definition so the two caches can never drift apart.
    ///
    /// [`SearchContext`]: crate::context::SearchContext
    pub fn absorb(&mut self, incoming: NndProfile) {
        if self.len() == incoming.len() {
            self.merge_min(&incoming);
        } else {
            *self = incoming;
        }
    }

    /// Moving average over a centered window of s+1 entries (paper Eq. 6);
    /// borders keep the raw values. Entries still at the init sentinel are
    /// treated as missing and skipped (a raw +inf would poison the window).
    pub fn smeared(&self, s: usize) -> Vec<f64> {
        let n = self.nnd.len();
        let half = s / 2;
        let mut out = self.nnd.clone();
        for (i, o) in out.iter_mut().enumerate() {
            if i < half || i + half >= n {
                continue; // border: keep raw value
            }
            let mut acc = 0.0;
            let mut cnt = 0usize;
            for &v in &self.nnd[i - half..=i + half] {
                if v.is_finite() {
                    acc += v;
                    cnt += 1;
                }
            }
            if cnt > 0 {
                *o = acc / cnt as f64;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exclusion_zone_overlap_rules() {
        let mut ez = ExclusionZones::new();
        assert!(ez.allowed(50, 10));
        ez.add(100, 10);
        assert!(!ez.allowed(100, 10));
        assert!(!ez.allowed(95, 10), "overlaps by 5");
        assert!(!ez.allowed(109, 10), "overlaps by 1");
        assert!(ez.allowed(110, 10), "adjacent, no shared point");
        assert!(ez.allowed(90, 10));
        assert!(!ez.allowed(91, 10));
    }

    #[test]
    fn observe_updates_both_endpoints() {
        let mut p = NndProfile::new(10);
        p.observe(2, 7, 1.5);
        assert_eq!(p.nnd[2], 1.5);
        assert_eq!(p.ngh[2], 7);
        assert_eq!(p.nnd[7], 1.5);
        assert_eq!(p.ngh[7], 2);
        // worse distance does not overwrite
        p.observe(2, 3, 9.0);
        assert_eq!(p.nnd[2], 1.5);
        assert_eq!(p.nnd[3], 9.0);
    }

    #[test]
    fn observe_one_leaves_j_untouched() {
        let mut p = NndProfile::new(5);
        p.observe_one(1, 4, 2.0);
        assert_eq!(p.nnd[1], 2.0);
        assert_eq!(p.nnd[4], NND_INIT);
    }

    #[test]
    fn merge_min_takes_pointwise_minimum_with_neighbors() {
        let mut a = NndProfile::new(4);
        a.observe(0, 2, 1.0);
        a.observe(1, 3, 5.0);
        let mut b = NndProfile::new(4);
        b.observe(0, 3, 2.0);
        b.observe(1, 2, 3.0);
        a.merge_min(&b);
        assert_eq!(a.nnd[0], 1.0);
        assert_eq!(a.nnd[1], 3.0);
        assert_eq!(a.ngh[1], 2, "neighbor follows the winning bound");
        // entries only one side knows about survive
        assert_eq!(a.nnd[3], 2.0);
    }

    #[test]
    fn smear_averages_window_and_keeps_borders() {
        let mut p = NndProfile::new(9);
        p.nnd = vec![1.0, 1.0, 1.0, 1.0, 9.0, 1.0, 1.0, 1.0, 1.0];
        let sm = p.smeared(4); // window of 5
        assert_eq!(sm[0], 1.0, "border untouched");
        assert_eq!(sm[1], 1.0, "border untouched");
        assert!((sm[4] - (9.0 + 4.0) / 5.0).abs() < 1e-12, "spike averaged");
        assert!(sm[4] < 9.0);
    }

    #[test]
    fn smear_skips_unset_entries() {
        let mut p = NndProfile::new(7);
        p.nnd = vec![1.0, 1.0, NND_INIT, 1.0, 1.0, 1.0, 1.0];
        let sm = p.smeared(4);
        assert!(sm[3].is_finite(), "window containing inf stays finite");
        assert!((sm[3] - 1.0).abs() < 1e-12);
    }
}
