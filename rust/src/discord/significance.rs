//! Significant discords (paper Sec. 4.5, after Avogadro, Palonca &
//! Dominoni 2020).
//!
//! Every time series has O(N/s) discords — they are just the maxima of
//! the matrix profile — but only those whose nnd is an *outlier* with
//! respect to the profile's bulk distribution mark real anomalies. The
//! paper uses this to argue that computing hundreds of discords (where
//! SCAMP would shine) is rarely useful: e.g. ECG 300 has only 5
//! significant discords of length 300.
//!
//! The significance test is the classic Tukey fence over the finite values
//! of the nnd profile: a discord is significant when
//! `nnd > Q3 + k_fence · IQR` (k_fence = 3.0 — "far out" — by default).

use crate::discord::{Discord, NndProfile};
use crate::util::stats::percentile_sorted;

/// Significance classifier built from an nnd profile.
#[derive(Debug, Clone)]
pub struct SignificanceTest {
    /// Third quartile of the profile values.
    pub q3: f64,
    /// Interquartile range.
    pub iqr: f64,
    /// Fence multiplier (Tukey: 1.5 = "outside", 3.0 = "far out").
    pub k_fence: f64,
}

impl SignificanceTest {
    /// Fit the fences on every finite value of `profile`.
    pub fn fit(profile: &NndProfile, k_fence: f64) -> SignificanceTest {
        let mut vals: Vec<f64> = profile
            .nnd
            .iter()
            .copied()
            .filter(|v| v.is_finite())
            .collect();
        assert!(!vals.is_empty(), "profile has no finite values");
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q1 = percentile_sorted(&vals, 0.25);
        let q3 = percentile_sorted(&vals, 0.75);
        SignificanceTest {
            q3,
            iqr: (q3 - q1).max(0.0),
            k_fence,
        }
    }

    /// Default "far out" fence.
    pub fn fit_default(profile: &NndProfile) -> SignificanceTest {
        Self::fit(profile, 3.0)
    }

    /// The significance threshold.
    pub fn threshold(&self) -> f64 {
        self.q3 + self.k_fence * self.iqr
    }

    /// Is this discord a significant anomaly?
    pub fn is_significant(&self, d: &Discord) -> bool {
        d.nnd > self.threshold()
    }

    /// Partition a discord set into (significant, ordinary).
    pub fn split<'a>(
        &self,
        discords: &'a [Discord],
    ) -> (Vec<&'a Discord>, Vec<&'a Discord>) {
        discords.iter().partition(|d| self.is_significant(d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{scamp::Scamp, Algorithm};
    use crate::config::SearchParams;
    use crate::ts::series::IntoSeries;
    use crate::ts::{generators, SeqStats};

    #[test]
    fn injected_anomaly_is_significant_background_is_not() {
        // smooth sine + one strong bump: exactly one significant discord
        let mut pts = generators::sine_with_noise(3_000, 0.02, 500);
        let mut rng = crate::util::rng::Rng64::new(1);
        generators::inject(&mut pts, 1_500, 96, generators::Anomaly::Bump, &mut rng);
        let ts = pts.into_series("bump");
        let s = 96;
        let stats = SeqStats::compute(&ts, s);
        let (profile, _) = Scamp::matrix_profile(&ts, &stats);
        let test = SignificanceTest::fit_default(&profile);

        let params = SearchParams::new(s, 4, 4).with_discords(8);
        let rep = Scamp.run(&ts, &params).unwrap();
        let (sig, ord) = test.split(&rep.discords);
        assert!(
            !sig.is_empty(),
            "the injected bump must be significant (threshold {:.3})",
            test.threshold()
        );
        assert!(
            sig.len() <= 2,
            "background repeats must not be significant: {} flagged",
            sig.len()
        );
        assert!(!ord.is_empty());
        // the top discord is the significant one
        assert!(test.is_significant(&rep.discords[0]));
    }

    #[test]
    fn pure_noise_has_few_significant_discords() {
        let ts = generators::random_walk(2_000, 1.0, 501).into_series("rw");
        let s = 64;
        let stats = SeqStats::compute(&ts, s);
        let (profile, _) = Scamp::matrix_profile(&ts, &stats);
        let test = SignificanceTest::fit_default(&profile);
        let params = SearchParams::new(s, 4, 4).with_discords(10);
        let rep = Scamp.run(&ts, &params).unwrap();
        let (sig, _) = test.split(&rep.discords);
        assert!(
            sig.len() <= 3,
            "random walk should have mostly ordinary discords, {} flagged",
            sig.len()
        );
    }

    #[test]
    fn threshold_monotone_in_fence() {
        let ts = generators::ecg_like(1_500, 100, 1, 502).into_series("e");
        let stats = SeqStats::compute(&ts, 80);
        let (profile, _) = Scamp::matrix_profile(&ts, &stats);
        let loose = SignificanceTest::fit(&profile, 1.5);
        let strict = SignificanceTest::fit(&profile, 3.0);
        assert!(strict.threshold() >= loose.threshold());
    }
}
