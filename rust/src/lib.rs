//! # hstime — HOT SAX Time (HST) discord search framework
//!
//! A production-grade reproduction of *"A fast algorithm for complex discord
//! searches in time series: HOT SAX Time"* (Avogadro & Dominoni, 2021).
//!
//! The crate is the **Layer-3 Rust coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the discord-search engines (HST and its
//!   sharded-parallel `hst-par`, the incremental `hst-stream`, the
//!   multivariate `brute-md`/`hst-md` of the [`mdim`] subsystem, the
//!   variable-length work-sharing `hst-vl` of the [`vl`] subsystem, HOT
//!   SAX, brute force, DADD/DRAG, RRA, SCAMP/STOMP serial and parallel),
//!   the [`exec`] worker-pool subsystem, the [`stream`] sliding-window
//!   monitor, the SAX substrate, dataset generators, the batch-search
//!   service coordinator, metrics (cost per sequence, D-/T-speedups), and
//!   the benchmark harness that regenerates every table and figure of the
//!   paper. The layer map and warm-profile dataflow are described in
//!   `docs/ARCHITECTURE.md` at the repository root.
//! * **L2 (python/compile/model.py, build-time only)** — JAX compute graphs
//!   (batched z-normalized distance, matrix-profile tiles) AOT-lowered to
//!   HLO text artifacts.
//! * **L1 (python/compile/kernels/, build-time only)** — Pallas kernels for
//!   the distance hot-spot, lowered (interpret=True) into the same HLO.
//!
//! With the off-by-default **`pjrt`** cargo feature, the [`runtime`] module
//! loads the AOT artifacts via the PJRT C API (`xla` crate) so that Python
//! is never on the search path; the default build is pure Rust and always
//! falls back to the scalar [`dist::CountingDistance`] backend.
//!
//! ## Quickstart
//!
//! Prepare a [`context::SearchContext`] once per series — it owns the
//! rolling stats, the SAX index cache, the distance backend, and any warm
//! nnd profiles — then drive any engine through it:
//!
//! ```
//! use hstime::prelude::*;
//!
//! let ts = generators::sine_with_noise(4_000, 0.1, 42).into_series("demo");
//! let ctx = SearchContext::builder(&ts).build();
//! let params = SearchParams::new(120, 4, 4).with_discords(1);
//! let report = algo::hst::HstSearch::default().run_ctx(&ctx, &params).unwrap();
//! let top = &report.discords[0];
//! println!("discord @ {} nnd={:.4} calls={}",
//!          top.position, top.nnd, report.distance_calls);
//! assert!(top.nnd > 0.0);
//! assert!(report.distance_calls > 0);
//!
//! // The context keeps the prepared state warm: a second search skips
//! // the stats/index/warm-up work entirely.
//! let warm = algo::hst::HstSearch::default().run_ctx(&ctx, &params).unwrap();
//! assert!(report.prep_calls > 0);
//! assert_eq!(warm.prep_calls, 0);
//! ```
//!
//! For one-shot searches, [`algo::Algorithm::run`] still works — it is a
//! convenience wrapper that builds a throwaway context.
#![warn(missing_docs)]

pub mod algo;
pub mod bench;
pub mod config;
pub mod context;
pub mod discord;
pub mod dist;
pub mod exec;
pub mod mdim;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod sax;
pub mod service;
pub mod snapshot;
pub mod stream;
pub mod tables;
pub mod ts;
pub mod util;
pub mod vl;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::algo::{self, Algorithm, SearchReport};
    pub use crate::config::{LengthRange, SaxParams, SearchParams};
    pub use crate::context::{
        CancellationToken, ContextBuilder, SearchContext, SearchObserver,
    };
    pub use crate::discord::{Discord, DiscordSet, NndProfile};
    pub use crate::dist::{
        Backend, CountingDistance, Distance, DistanceKind, ZnormStats,
    };
    pub use crate::exec::ExecPolicy;
    pub use crate::mdim::{MdimAlgorithm, MdimContext, MdimParams, MdimReport};
    pub use crate::metrics::{
        self, cps, cps_per_channel, d_speedup, length_normalized_nnd,
        t_speedup,
    };
    pub use crate::obs::{
        JsonlTraceWriter, PassEvent, Registry, TraceSink, TRACE_SCHEMA,
    };
    pub use crate::sax::{SaxIndex, SaxWord};
    pub use crate::snapshot::{ContextSnapshot, MonitorSnapshot, SnapshotError};
    pub use crate::stream::{HstStream, StreamDiscord, StreamUpdate, StreamingMonitor};
    pub use crate::ts::series::IntoSeries;
    pub use crate::ts::{generators, MultiSeries, TimeSeries};
    pub use crate::util::rng::Rng64;
    pub use crate::vl::{HstVl, VlContext, VlReport};
}
